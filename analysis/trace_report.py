"""Trace analysis: summary tables + timeline export for obs traces.

Consumes the Chrome ``trace_event`` JSON the ``repro.obs`` tracer
exports (or any live ``Tracer``) and renders the ops view: per-lane
busy/occupancy, span duration percentiles by name, instant counts, and
the ``validate_trace`` invariant check. Two entry points:

  * CLI over an existing trace file::

        PYTHONPATH=src python -m analysis.trace_report TRACE.json [--json PATH]

  * registered benchmark (``benchmarks.run`` benches dict): runs a small
    traced chaos demo (2-of-3 replica fleet, kill + rejoin), validates
    the trace, and reports the tables::

        PYTHONPATH=src python -m benchmarks.run --only trace_report

The demo doubles as the end-to-end acceptance path: the exported trace
covers admission -> prefill -> decode -> completion including hedge
cancels and the fault instants, with zero invariant violations.
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict
from typing import Any, Dict, List, Optional

import numpy as np

DEFAULT_OUT = "BENCH_trace_report.json"


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Read a trace file; accepts the ``{"traceEvents": [...]}`` wrapper
    or a bare event list."""
    with open(path) as f:
        data = json.load(f)
    return data["traceEvents"] if isinstance(data, dict) else data


def _pct(sorted_vals: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(sorted_vals), q))


def span_table(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Duration stats per event name: complete ("X") events use ``dur``;
    async span pairs use ``end.ts - begin.ts``. Sorted by total time, so
    the first row is where the virtual clock actually went."""
    durs: Dict[tuple, List[float]] = defaultdict(list)
    open_: Dict[tuple, Dict[str, Any]] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            durs[(ev["name"], "X")].append(float(ev.get("dur", 0.0)))
        elif ph == "b":
            open_[(ev["pid"], ev.get("cat"), ev.get("id"))] = ev
        elif ph == "e":
            b = open_.pop((ev["pid"], ev.get("cat"), ev.get("id")), None)
            if b is not None:
                durs[(b["name"], "span")].append(
                    float(ev["ts"]) - float(b["ts"])
                )
    rows = []
    for (name, kind), ds in durs.items():
        ds.sort()
        n = len(ds)
        rows.append({
            "name": name, "kind": kind, "count": n,
            "total_us": round(sum(ds), 3),
            "mean_us": round(sum(ds) / n, 3),
            "p50_us": round(_pct(ds, 50), 3),
            "p99_us": round(_pct(ds, 99), 3),
            "max_us": round(ds[-1], 3),
        })
    rows.sort(key=lambda r: (-r["total_us"], r["name"]))
    return rows


def lane_table(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per-process (= per virtual clock) rollup: event counts, busy time
    (sum of "X" durations), and the lane's virtual time extent."""
    names: Dict[int, str] = {}
    agg: Dict[int, Dict[str, float]] = defaultdict(
        lambda: {"events": 0, "spans": 0, "busy_us": 0.0,
                 "t0_us": float("inf"), "t1_us": float("-inf")}
    )
    for ev in events:
        pid = ev.get("pid")
        if ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                names[pid] = ev["args"]["name"]
            continue
        a = agg[pid]
        a["events"] += 1
        if ev["ph"] == "b":
            a["spans"] += 1
        if ev["ph"] == "X":
            a["busy_us"] += float(ev.get("dur", 0.0))
        ts = float(ev.get("ts", 0.0))
        a["t0_us"] = min(a["t0_us"], ts)
        a["t1_us"] = max(a["t1_us"], ts + float(ev.get("dur", 0.0)))
    rows = []
    for pid in sorted(agg):
        a = agg[pid]
        extent = a["t1_us"] - a["t0_us"]
        rows.append({
            "pid": pid,
            "lane": names.get(pid, f"pid {pid}"),
            "events": int(a["events"]),
            "spans": int(a["spans"]),
            "busy_us": round(a["busy_us"], 3),
            "extent_us": round(extent, 3) if extent >= 0 else 0.0,
            "utilization": round(a["busy_us"] / extent, 4) if extent > 0 else 0.0,
        })
    return rows


def instant_table(events: List[Dict[str, Any]]) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i":
            counts[ev["name"]] += 1
    return dict(sorted(counts.items()))


def report(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    from repro.obs import validate_trace

    return {
        "n_events": len(events),
        "errors": validate_trace(events),
        "lanes": lane_table(events),
        "spans": span_table(events),
        "instants": instant_table(events),
    }


def print_report(rep: Dict[str, Any]) -> None:
    print(f"{rep['n_events']} events, "
          f"{len(rep['errors'])} invariant violations")
    for err in rep["errors"][:10]:
        print(f"  VIOLATION: {err}")
    print(f"\n{'lane':>16s} {'events':>7s} {'spans':>6s} {'busy ms':>9s} "
          f"{'extent ms':>10s} {'util':>6s}")
    for r in rep["lanes"]:
        print(f"{r['lane']:>16s} {r['events']:7d} {r['spans']:6d} "
              f"{r['busy_us'] / 1e3:9.3f} {r['extent_us'] / 1e3:10.3f} "
              f"{r['utilization']:6.2f}")
    print(f"\n{'name':>16s} {'kind':>5s} {'count':>6s} {'total ms':>9s} "
          f"{'p50 us':>9s} {'p99 us':>9s} {'max us':>9s}")
    for r in rep["spans"]:
        print(f"{r['name']:>16s} {r['kind']:>5s} {r['count']:6d} "
              f"{r['total_us'] / 1e3:9.3f} {r['p50_us']:9.1f} "
              f"{r['p99_us']:9.1f} {r['max_us']:9.1f}")
    if rep["instants"]:
        print("\ninstants: " + "  ".join(
            f"{k}={v}" for k, v in rep["instants"].items()))


def _demo_trace(fast: bool = True):
    """Traced 3-replica chaos run (kill one mid-flight, rejoin later) —
    the same plane perf_replicas measures, sized down to a smoke run."""
    import jax

    from repro.configs import get_config
    from repro.core.delay_models import SimplifiedDelayModel
    from repro.models import build_model
    from repro.obs import Observability
    from repro.runtime.faults import FaultEvent
    from repro.serve import Frontend, Replica

    cfg = get_config("smollm").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_requests = 6 if fast else 16
    rng = np.random.default_rng(3)
    reqs = []
    t = 0.0
    for _ in range(n_requests):
        p_len = int(rng.integers(4, 16))
        n_new = int(rng.integers(4, 24))
        t += float(rng.exponential(1.0 / 60.0))
        reqs.append((rng.integers(0, cfg.vocab_size, size=p_len).astype(np.int32),
                     n_new, t))

    obs = Observability()
    fleet = [
        Replica(i, model, params, n_slots=4, max_len=64, block_size=8, obs=obs)
        for i in range(3)
    ]
    fe = Frontend(
        fleet, SimplifiedDelayModel(lambda_y=2.0), cost_per_replica=0.05,
        events=[FaultEvent(step=8, kind="fail", worker=1),
                FaultEvent(step=40, kind="rejoin", worker=1)],
        obs=obs,
    )
    for p, m, a in reqs:
        fe.submit(p, m, arrival=a)
    fe.run()
    return obs, fe


def run(fast: bool = True, out: Optional[str] = None,
        trace_out: Optional[str] = None) -> dict:
    obs, fe = _demo_trace(fast)
    if trace_out:
        obs.tracer.export(trace_out)
        print(f"wrote {trace_out}")
    rep = report(obs.tracer.events)
    print_report(rep)
    assert not rep["errors"], f"trace invariant violations: {rep['errors'][:5]}"
    assert not obs.tracer.open_spans, "spans leaked"
    payload = {
        "benchmark": "trace_report",
        "mode": "fast" if fast else "full",
        "completed": int(fe.summary()["completed"]),
        "trace_valid": True,
        "report": rep,
    }
    if out is not None:
        from benchmarks.common import write_bench_json

        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="?", default=None,
                    help="trace JSON to analyze; omit to run the traced "
                         "chaos demo instead")
    ap.add_argument("--full", action="store_true",
                    help="larger demo workload (demo mode only)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write the report payload as JSON")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="export the demo's trace JSON (demo mode only)")
    args = ap.parse_args()

    if args.trace is not None:
        rep = report(load_trace(args.trace))
        print_report(rep)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rep, f, indent=2)
            print(f"wrote {args.json}")
        raise SystemExit(1 if rep["errors"] else 0)

    run(fast=not args.full, out=args.json, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
