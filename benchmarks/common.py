"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time

import numpy as np

from repro.core import LinregProblem, simulate_batch

PAPER_GRID = (0.2, 0.4, 0.6, 0.8, 1.0)   # the paper's beta set
PAPER_TARGET = 2e-2                        # the paper's quoted readout gap


def mean_curves(
    problem: LinregProblem,
    cfg_factory,
    model,
    *,
    seeds: int,
    max_iters: int,
    t_max: float,
    n_grid: int = 1200,
    oracle_switch_times=None,
):
    """Average (gap, comp, comm) over seeds on a common time grid — the
    paper's error E is an EXPECTATION; single-run gaps are far too noisy.

    All seeds run in one ``simulate_batch`` call (lane ``i`` == the old
    per-seed ``simulate(seed=i)`` run), so raising seed counts is cheap:
    a batch of S lanes costs roughly one scalar run, not S.
    """
    tgrid = np.linspace(0.0, t_max, n_grid)
    batch = simulate_batch(
        problem,
        cfg_factory(),
        model,
        seeds=seeds,
        max_iters=max_iters,
        eval_every=10,
        oracle_switch_times=oracle_switch_times,
    )
    gs, cps, cms = [], [], []
    for r in batch:
        gs.append(np.interp(tgrid, r.times, r.gaps))
        cps.append(np.interp(tgrid, r.times, r.comp_at_eval))
        cms.append(np.interp(tgrid, r.times, r.comm_at_eval))
    return tgrid, np.mean(gs, 0), np.mean(cps, 0), np.mean(cms, 0)


def crossing(tgrid, gaps, target) -> int:
    idx = np.nonzero(gaps <= target)[0]
    return int(idx[0]) if idx.size else -1


def report_at_target(tgrid, g, cp, cm, target=PAPER_TARGET):
    i = crossing(tgrid, g, target)
    if i < 0:
        return np.inf, np.inf, np.inf
    return float(tgrid[i]), float(cp[i]), float(cm[i])


class Timer:
    """Monotonic wall-clock context manager (``time.perf_counter``)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
