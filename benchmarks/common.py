"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import datetime
import glob
import hashlib
import json
import os
import subprocess
import time

import numpy as np

from repro.core import LinregProblem, simulate_batch

PAPER_GRID = (0.2, 0.4, 0.6, 0.8, 1.0)   # the paper's beta set
PAPER_TARGET = 2e-2                        # the paper's quoted readout gap

#: bump when the shape of any BENCH_*.json payload changes incompatibly.
BENCH_SCHEMA_VERSION = 1


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
        return out or "unknown"
    except Exception:
        return "unknown"


def bench_meta(payload: dict) -> dict:
    """Provenance block stamped into every ``BENCH_*.json``: schema
    version, git sha, UTC timestamp, and a config hash over the
    payload's top-level scalar fields (arch, mode, pool geometry, ...) —
    cross-PR tooling can tell a perf change from a config change."""
    scalars = {
        k: v for k, v in payload.items()
        if isinstance(v, (str, int, float, bool)) and not isinstance(v, type(None))
    }
    blob = json.dumps(scalars, sort_keys=True).encode()
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "config_hash": hashlib.sha256(blob).hexdigest()[:16],
    }


def write_bench_json(path: str, payload: dict) -> dict:
    """Stamp ``payload`` with a ``meta`` provenance block and write it.
    The single seam every benchmark's ``--out`` goes through, so the
    BENCH_* corpus stays uniformly machine-readable across PRs."""
    payload = dict(payload)
    payload["meta"] = bench_meta(payload)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return payload


def write_bench_index(
    directory: str = ".", out: str = "BENCH_index.json",
    required: tuple = (),
) -> dict:
    """Aggregate every ``BENCH_*.json`` in ``directory`` into one index:
    benchmark name, mode, and provenance meta per file. Returns the
    index payload (written to ``out`` inside ``directory``).

    ``required`` names BENCH files (e.g. ``("BENCH_prefix.json",)``)
    that MUST be present and parseable: a registered benchmark whose
    JSON is missing or corrupt raises ``RuntimeError`` instead of being
    silently dropped from the manifest — a bench that stops emitting
    its file should fail the run, not vanish from the index."""
    entries = []
    problems = []
    seen = set()
    for path in sorted(glob.glob(os.path.join(directory, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name == out:
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            if name in required:
                problems.append(f"{name}: unreadable ({e})")
            continue
        seen.add(name)
        entries.append({
            "file": name,
            "benchmark": data.get("benchmark"),
            "mode": data.get("mode"),
            "meta": data.get("meta"),
        })
    missing = [name for name in required if name not in seen]
    problems += [f"{name}: missing" for name in missing
                 if not any(p.startswith(name) for p in problems)]
    if problems:
        raise RuntimeError(
            "bench index: required BENCH files absent or corrupt — "
            + "; ".join(sorted(problems)))
    index = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "generated": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "git_sha": _git_sha(),
        "benchmarks": entries,
    }
    with open(os.path.join(directory, out), "w") as f:
        json.dump(index, f, indent=2)
    return index


def mean_curves(
    problem: LinregProblem,
    cfg_factory,
    model,
    *,
    seeds: int,
    max_iters: int,
    t_max: float,
    n_grid: int = 1200,
    oracle_switch_times=None,
):
    """Average (gap, comp, comm) over seeds on a common time grid — the
    paper's error E is an EXPECTATION; single-run gaps are far too noisy.

    All seeds run in one ``simulate_batch`` call (lane ``i`` == the old
    per-seed ``simulate(seed=i)`` run), so raising seed counts is cheap:
    a batch of S lanes costs roughly one scalar run, not S.
    """
    tgrid = np.linspace(0.0, t_max, n_grid)
    batch = simulate_batch(
        problem,
        cfg_factory(),
        model,
        seeds=seeds,
        max_iters=max_iters,
        eval_every=10,
        oracle_switch_times=oracle_switch_times,
    )
    gs, cps, cms = [], [], []
    for r in batch:
        gs.append(np.interp(tgrid, r.times, r.gaps))
        cps.append(np.interp(tgrid, r.times, r.comp_at_eval))
        cms.append(np.interp(tgrid, r.times, r.comm_at_eval))
    return tgrid, np.mean(gs, 0), np.mean(cps, 0), np.mean(cms, 0)


def crossing(tgrid, gaps, target) -> int:
    idx = np.nonzero(gaps <= target)[0]
    return int(idx[0]) if idx.size else -1


def report_at_target(tgrid, g, cp, cm, target=PAPER_TARGET):
    i = crossing(tgrid, g, target)
    if i < 0:
        return np.inf, np.inf, np.inf
    return float(tgrid[i]), float(cp[i]), float(cm[i])


class Timer:
    """Monotonic wall-clock context manager (``time.perf_counter``)."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
