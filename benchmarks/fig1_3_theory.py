"""Paper Figs. 1-3: theoretical comparison over the (lambda_y, x) grid.

For n=50 workers, L=2, sigma^2=10, c=1 and target error 1e-3 (the paper's
setting), roll the analytic schedules of adaptive-(k,beta) [ours] and
adaptive-k [39] via Thm. 2 + Cor. 4 and report, per grid point:
  Fig.1  runtime improvement   (1 - T_ours / T_ak)
  Fig.2  communication overhead (comm_ours / comm_ak - 1)
  Fig.3  computation reduction  (1 - comp_ours / comp_ak)

Claims validated here (printed at the bottom):
  * runtime strictly <= adaptive-k on the whole grid,
  * largest gains where computation dominates (small x, small lambda_y),
  * ~17% comm overhead in the most-beneficial regime,
  * computation reduced everywhere gains exist.
"""

from __future__ import annotations

import numpy as np

from repro.core import SGDHyperParams, SimplifiedDelayModel, StrategyConfig, evaluate_schedule


def run(fast: bool = True):
    n, s = 50, 20
    hp = SGDHyperParams(eta=0.01, L=2.0, sigma_grad2=10.0, c=1.0, s=s)
    e0, target = 10.0, 1e-3
    grid = np.geomspace(0.05, 20.0, 5 if fast else 9)

    print("lambda_y      x   | runtime_gain  comm_overhead  comp_reduction")
    best = None
    worst_gain = np.inf
    results = {}
    for lam in grid:
        for x in grid:
            m = SimplifiedDelayModel(lambda_y=float(lam), x=float(x))
            ours = evaluate_schedule(
                StrategyConfig("adaptive_kbeta", n=n, s=s), m, hp,
                e0=e0, target=target,
            )
            ak = evaluate_schedule(
                StrategyConfig("adaptive_k", n=n, s=s), m, hp,
                e0=e0, target=target,
            )
            gain = 1 - ours.runtime / ak.runtime
            ovh = ours.comm_cost / ak.comm_cost - 1
            red = 1 - ours.comp_cost / ak.comp_cost
            results[(lam, x)] = (gain, ovh, red)
            worst_gain = min(worst_gain, gain)
            if best is None or gain > best[0]:
                best = (gain, ovh, red, lam, x)
            print(
                f"{lam:8.3f} {x:8.3f} |    {gain:8.2%}     {ovh:8.2%}      {red:8.2%}"
            )

    gain, ovh, red, lam, x = best
    print("\n-- claims --")
    print(f"fig1: runtime never worse: min gain = {worst_gain:.2%} (paper: strictly smaller)")
    print(f"fig1: best regime lambda_y={lam:.3f} x={x:.3f} (computation-dominated) gain={gain:.2%}")
    print(f"fig2: comm overhead in best regime = {ovh:.2%} (paper: ~17%)")
    print(f"fig3: comp reduction in best regime = {red:.2%} (paper: large)")
    assert worst_gain >= -1e-9, "ours must never be slower in theory"
    return {"fig1_best_gain": gain, "fig2_best_ovh": ovh, "fig3_best_red": red}


if __name__ == "__main__":
    run(fast=False)
