"""Paper Fig. 4: linear-regression simulation (n=20, v=400, lambda_y=1,
x=0.01, k<=10, beta in {0.2,...,1.0}).

Three rows per strategy pair:
  theory       — analytic schedules (Thm. 2 switching; zero detection cost)
  sim+oracle   — event simulation with the analytic switch TIMES
  sim+diag     — event simulation with run-time stationarity diagnostics
                 (the paper's own operating mode)

Paper claims at gap 2e-2: runtime 'roughly halved', computation -59.9%,
communication +15.7%. The paper does not state (d, eta, diagnostic
details); we calibrate eta so the analytic model reproduces the paper's
numbers (see DESIGN.md §8 / EXPERIMENTS.md §Paper) and report all three
rows so the diagnostic sensitivity is visible rather than hidden.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    DiagnosticConfig,
    LinregProblem,
    SGDHyperParams,
    SimplifiedDelayModel,
    StrategyConfig,
    evaluate_schedule,
)

from .common import PAPER_GRID, PAPER_TARGET, mean_curves, report_at_target


def _calibrated_hp(problem: LinregProblem) -> SGDHyperParams:
    lam = np.linalg.eigvalsh(2.0 * problem.X.T @ problem.X / problem.v)
    c = float(2.0 * lam.min())
    # Empirical floor calibration: floor(phi=1) ~ 0.1846 at eta=9.284e-6
    # scales linearly in eta (measured; see EXPERIMENTS.md §Paper).
    fl1 = 0.1846 * problem.eta / 9.284e-6
    L = 2.0
    sigma2 = fl1 * 2 * c * problem.s / (problem.eta * L)
    return SGDHyperParams(eta=problem.eta, L=L, sigma_grad2=sigma2, c=c,
                          s=problem.s)


def run(fast: bool = True):
    problem = LinregProblem.generate(v=400, d=10, n_workers=20, seed=1)
    model = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    hp = _calibrated_hp(problem)
    e0 = problem.gap(np.zeros(problem.d))
    # The batched engine prices a batch of S lanes at roughly one scalar
    # run, so even fast mode affords the paper-scale seed count.
    seeds = 24 if fast else 64
    max_iters = 20_000 if fast else 60_000

    def cfg(strategy, diag=None):
        kw = dict(n=20, s=20, k_max=10, beta_grid=PAPER_GRID)
        if diag is not None:
            kw["diagnostic"] = diag
        return StrategyConfig(strategy, **kw)

    # --- theory row ------------------------------------------------------
    theory = {}
    for strat in ("adaptive_kbeta", "adaptive_k"):
        theory[strat] = evaluate_schedule(
            cfg(strat), model, hp, e0=e0, target=PAPER_TARGET
        )
    to, ta = theory["adaptive_kbeta"], theory["adaptive_k"]
    print("row          | T_ours  T_ak   runtime_ratio  comp_red  comm_ovh")
    print(
        f"theory       | {to.runtime:7.1f} {ta.runtime:7.1f} "
        f"{to.runtime / ta.runtime:10.3f} {1 - to.comp_cost / ta.comp_cost:9.1%} "
        f"{to.comm_cost / ta.comm_cost - 1:9.1%}"
    )

    out = {"theory": (to.runtime / ta.runtime,
                      1 - to.comp_cost / ta.comp_cost,
                      to.comm_cost / ta.comm_cost - 1)}

    # --- sim + oracle switching -----------------------------------------
    t_max = ta.runtime * 2.5
    rows = {}
    for strat in ("adaptive_kbeta", "adaptive_k"):
        times = [st.t_end for st in theory[strat].stages[:-1]]
        tg, g, cp, cm = mean_curves(
            problem, lambda s=strat: cfg(s), model,
            seeds=seeds, max_iters=max_iters, t_max=t_max,
            oracle_switch_times=times,
        )
        rows[strat] = report_at_target(tg, g, cp, cm)
    (T1, C1, M1), (T2, C2, M2) = rows["adaptive_kbeta"], rows["adaptive_k"]
    print(
        f"sim+oracle   | {T1:7.1f} {T2:7.1f} {T1 / T2:10.3f} "
        f"{1 - C1 / C2:9.1%} {M1 / M2 - 1:9.1%}"
    )
    out["sim_oracle"] = (T1 / T2, 1 - C1 / C2, M1 / M2 - 1)

    # --- sim + run-time diagnostics --------------------------------------
    diag = DiagnosticConfig(kind="distance", threshold=1.0, ratio=1.4,
                            min_iters=8, consecutive=2)
    for strat in ("adaptive_kbeta", "adaptive_k"):
        tg, g, cp, cm = mean_curves(
            problem, lambda s=strat: cfg(s, diag), model,
            seeds=seeds, max_iters=max_iters, t_max=t_max,
        )
        rows[strat] = report_at_target(tg, g, cp, cm)
    (T1, C1, M1), (T2, C2, M2) = rows["adaptive_kbeta"], rows["adaptive_k"]
    print(
        f"sim+diag     | {T1:7.1f} {T2:7.1f} {T1 / T2:10.3f} "
        f"{1 - C1 / C2:9.1%} {M1 / M2 - 1:9.1%}"
    )
    out["sim_diag"] = (T1 / T2, 1 - C1 / C2, M1 / M2 - 1)

    print(
        "\npaper claims | runtime 'roughly halves' (ratio ~0.5), "
        "comp -59.9%, comm +15.7%"
    )
    return out


if __name__ == "__main__":
    run(fast=False)
