"""Paper Figs. 5-7 (Appendix D): generalized delay model (Def. 2) regimes.

Three regimes, error-over-time for four schemes: ours (adaptive-k,beta),
adaptive-k [39], and fastest-k [38] at (k,beta) in {(1,0.2),(5,1),(10,1)}:

  Fig.5  computation dominates   (lambda_y = 1,   lambda_x = 100)
  Fig.6  comparable              (lambda_y = 20,  lambda_x = 5/3)
  Fig.7  communication dominates (lambda_y = 100, lambda_x = 1)

Claims: largest speedup over adaptive-k in regime 1, notable in regime 2,
none in regime 3.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    DiagnosticConfig,
    GeneralizedDelayModel,
    LinregProblem,
    StrategyConfig,
)

from .common import PAPER_GRID, PAPER_TARGET, mean_curves, report_at_target

REGIMES = {
    "fig5_comp_dominates": GeneralizedDelayModel(lambda_x=100.0, lambda_y=1.0),
    "fig6_comparable": GeneralizedDelayModel(lambda_x=5.0 / 3.0, lambda_y=20.0),
    "fig7_comm_dominates": GeneralizedDelayModel(lambda_x=1.0, lambda_y=100.0),
}

SCHEMES = {
    "ours": ("adaptive_kbeta", {}),
    "adaptive_k": ("adaptive_k", {}),
    "fastest_k(1,0.2)": ("fastest_k", {"k0": 1, "beta0": 0.2}),
    "fastest_k(5,1)": ("fastest_k", {"k0": 5}),
    "fastest_k(10,1)": ("fastest_k", {"k0": 10}),
}


def run(fast: bool = True):
    problem = LinregProblem.generate(v=400, d=10, n_workers=20, seed=1)
    seeds = 16 if fast else 48
    max_iters = 15_000 if fast else 50_000
    diag = DiagnosticConfig(kind="distance", threshold=1.0, ratio=1.4,
                            min_iters=8, consecutive=2)

    out = {}
    for regime, model in REGIMES.items():
        t_scale = 1.0 / model.lambda_x + 1.0 / model.lambda_y
        t_max = 12_000 * t_scale if "comm" in regime else 4_000 * t_scale
        print(f"\n== {regime}: lambda_x={model.lambda_x:.3g} "
              f"lambda_y={model.lambda_y:.3g} ==")
        times = {}
        for name, (strategy, kw) in SCHEMES.items():
            def factory(strategy=strategy, kw=kw):
                base = dict(n=20, s=20, k_max=10, beta_grid=PAPER_GRID,
                            diagnostic=diag)
                if strategy == "fastest_k":
                    base["k0"] = kw.get("k0", 1)
                    if "beta0" in kw:
                        # fixed (k, beta) baseline from [38]
                        return StrategyConfig("fastest_k", n=20, s=20,
                                              k0=kw["k0"], beta0=kw["beta0"],
                                              beta_grid=PAPER_GRID)
                return StrategyConfig(strategy, **base)

            tg, g, cp, cm = mean_curves(
                problem, factory, model, seeds=seeds,
                max_iters=max_iters, t_max=t_max,
            )
            T, C, M = report_at_target(tg, g, cp, cm)
            times[name] = (T, C, M)
            print(f"  {name:18s} T(2e-2)={T:9.1f} comp={C:9.0f} comm={M:9.0f}")
        out[regime] = times
        if np.isfinite(times["ours"][0]) and np.isfinite(times["adaptive_k"][0]):
            print(f"  -> ours/adaptive_k runtime ratio: "
                  f"{times['ours'][0] / times['adaptive_k'][0]:.3f}")
    return out


if __name__ == "__main__":
    run(fast=False)
