"""Paper Figs. 8-9: communication / computation cost vs achieved error.

Regime 3 of Appendix D (communication dominates, lambda_y=100,
lambda_x=1), all schemes. Claim: ours has HIGHER communication cost and
LOWER computation cost than [38]/[39] at every error level (the paper's
explicit trade-off), with costs clipped at the paper's plot limits.
"""

from __future__ import annotations

import numpy as np

from repro.core import DiagnosticConfig, GeneralizedDelayModel, LinregProblem, StrategyConfig

from .common import PAPER_GRID, mean_curves

ERROR_LEVELS = (0.5, 0.2, 0.1, 0.05, 2e-2)


def run(fast: bool = True):
    problem = LinregProblem.generate(v=400, d=10, n_workers=20, seed=1)
    model = GeneralizedDelayModel(lambda_x=1.0, lambda_y=100.0)
    seeds = 16 if fast else 48
    max_iters = 15_000 if fast else 50_000
    diag = DiagnosticConfig(kind="distance", threshold=1.0, ratio=1.4,
                            min_iters=8, consecutive=2)
    t_max = 4_000 * (1.0 / model.lambda_x + 1.0 / model.lambda_y) * 3

    schemes = {
        "ours": StrategyConfig("adaptive_kbeta", n=20, s=20, k_max=10,
                               beta_grid=PAPER_GRID, diagnostic=diag),
        "adaptive_k": StrategyConfig("adaptive_k", n=20, s=20, k_max=10,
                                     diagnostic=diag),
        "fastest_k(5,1)": StrategyConfig("fastest_k", n=20, s=20, k0=5),
    }

    curves = {}
    for name, cfg in schemes.items():
        tg, g, cp, cm = mean_curves(
            problem, lambda cfg=cfg: cfg, model, seeds=seeds,
            max_iters=max_iters, t_max=t_max,
        )
        curves[name] = (tg, g, cp, cm)

    print("error | " + " | ".join(f"{n}: comp,comm" for n in schemes))
    out = {}
    for lvl in ERROR_LEVELS:
        row = []
        for name, (tg, g, cp, cm) in curves.items():
            idx = np.nonzero(g <= lvl)[0]
            if idx.size:
                row.append((name, float(cp[idx[0]]), float(cm[idx[0]])))
            else:
                row.append((name, np.inf, np.inf))
        out[lvl] = row
        print(f"{lvl:5.2f} | " + " | ".join(
            f"{c:9.0f},{m:9.0f}" for (_, c, m) in row))

    # Claim check at the finest level all schemes reached.
    for lvl in ERROR_LEVELS:
        vals = {n: (c, m) for n, c, m in out[lvl]}
        if all(np.isfinite(v[0]) for v in vals.values()):
            ours_c, ours_m = vals["ours"]
            ak_c, ak_m = vals["adaptive_k"]
            print(
                f"\nclaim at err={lvl}: comp ours<{'=' if ours_c <= ak_c else '!'}ak "
                f"({ours_c:.0f} vs {ak_c:.0f}); comm ours>{'=' if ours_m >= ak_m else '!'}ak "
                f"({ours_m:.0f} vs {ak_m:.0f})"
            )
    return out


if __name__ == "__main__":
    run(fast=False)
