"""Paged vs contiguous KV cache under mixed request lengths.

Drives two ``repro.serve.ServeEngine`` instances — the contiguous slot
pool and the paged block-table pool — over an identical mixed-length
workload (Poisson arrivals, prompt/generation budgets spread wide) and
writes ``BENCH_paged.json``. What paging buys:

  * **memory**: the contiguous pool reserves ``n_slots * max_len`` rows
    forever; the paged arena's high-water mark is proportional to LIVE
    tokens (each request reserves only ``ceil(budget/block)`` blocks at
    admission and returns them the instant it finishes). Reported as
    reserved-bytes high-water (incl. the NULL sink block) over the
    contiguous stripe bytes — the paper's adapt-the-load move applied to
    serving memory. The paged engine here also runs under an explicit
    sub-capacity arena budget (admit-by-budget), proving the admission
    path, not just the layout.
  * **tokens/s**: must be a wash (within 5%) on the deterministic event
    clock — paging is a layout change, not a scheduling change — and the
    greedy token streams must stay byte-identical.

Wall-clock numbers are reported as the usual sanity check; the CPU jnp
path pays a small gather/scatter indirection that the Pallas paged
kernel (``repro.kernels.decode_attention.paged_flash_decode``) removes
on TPU by walking only live blocks.

    PYTHONPATH=src python -m benchmarks.perf_paged [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import math
import platform
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import round_kv_len
from repro.serve import ServeEngine

from .common import write_bench_json

DEFAULT_OUT = "BENCH_paged.json"

ARCH = "smollm"
N_SLOTS = 4
MAX_LEN = 192
BLOCK_SIZE = 16
ARENA_FRAC = 0.75     # arena budget as a fraction of full contiguous rows
RATE = 200.0          # saturated arrivals: every slot stays busy
SEED = 11


def make_workload(
    n_requests: int, vocab: int, seed: int = SEED
) -> List[Tuple[np.ndarray, int, float]]:
    """Mixed request lengths: ~80% short chats (prompt 4-23, budget
    2-55) and ~20% long documents (prompt 64-99, budget 32-63). The pool
    must provision ``max_len`` rows per slot for the long tail, so the
    contiguous layout pays 192 rows for every request — exactly the
    wasted-work regime the paper prices, moved to serving memory."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for _ in range(n_requests):
        if rng.random() < 0.2:
            p_len = int(rng.integers(64, 100))
            n_new = int(rng.integers(32, 64))
        else:
            p_len = int(rng.integers(4, 24))
            n_new = int(rng.integers(2, 56))
        n_new = min(n_new, MAX_LEN - p_len)
        t += float(rng.exponential(1.0 / RATE))
        prompt = rng.integers(0, vocab, size=p_len).astype(np.int32)
        reqs.append((prompt, n_new, t))
    return reqs


def run_engine(model, params, reqs, **engine_kw):
    eng = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                      **engine_kw)
    for prompt, m, arr in reqs:
        eng.submit(prompt, m, arrival=arr)
    t0 = time.perf_counter()
    results = eng.run()
    wall = time.perf_counter() - t0
    lat = np.array([r.latency for r in results.values()])
    s = eng.stats
    return eng, {
        "decode_ticks": s.decode_ticks,
        "generated_tokens": s.generated_tokens,
        "tokens_per_vsec": round(s.tokens_per_vsec, 2),
        "tokens_per_wsec": round(s.generated_tokens / max(wall, 1e-9), 2),
        "latency_p50_vsec": round(float(np.percentile(lat, 50)), 5),
        "latency_p99_vsec": round(float(np.percentile(lat, 99)), 5),
    }, {rid: r.tokens for rid, r in results.items()}


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_requests = 16 if fast else 48
    reqs = make_workload(n_requests, cfg.vocab_size)

    rows = round_kv_len(MAX_LEN)
    arena_blocks = math.floor(ARENA_FRAC * N_SLOTS * rows / BLOCK_SIZE)

    # Warm both jit cache families (at the MEASURED arena geometry — the
    # compile cache keys on arena shape) so wall numbers are steady-state.
    for kw in ({}, {"block_size": BLOCK_SIZE, "arena_blocks": arena_blocks}):
        warm = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN, **kw)
        warm.submit(np.arange(5, dtype=np.int32), 3)
        warm.run()

    contig_eng, contig, contig_tokens = run_engine(model, params, reqs)
    paged_eng, paged, paged_tokens = run_engine(
        model, params, reqs, block_size=BLOCK_SIZE, arena_blocks=arena_blocks,
    )

    contig_bytes = contig_eng.pool.kv_bytes_contiguous()
    hw_bytes = paged_eng.pool.kv_bytes_high_water()
    arena_bytes = (arena_blocks + 1) * paged_eng.pool.kv_bytes_per_block()
    mgr = paged_eng.pool.manager
    contig["kv_bytes"] = contig_bytes
    paged.update(
        kv_bytes_high_water=hw_bytes,
        kv_bytes_arena_capacity=arena_bytes,
        blocks_high_water=mgr.used_high_water,
        arena_blocks=arena_blocks,
        block_size=BLOCK_SIZE,
    )

    payload = {
        "benchmark": "perf_paged",
        "mode": "fast" if fast else "full",
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "requests": n_requests,
        "arrival_rate_per_vsec": RATE,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "contiguous": contig,
        "paged": paged,
        "memory_high_water_ratio": round(hw_bytes / contig_bytes, 4),
        "arena_capacity_ratio": round(arena_bytes / contig_bytes, 4),
        "tokens_per_vsec_ratio": round(
            paged["tokens_per_vsec"] / max(contig["tokens_per_vsec"], 1e-12), 4
        ),
        "latency_p99_ratio": round(
            paged["latency_p99_vsec"] / max(contig["latency_p99_vsec"], 1e-12), 4
        ),
        "tokens_byte_identical": paged_tokens == contig_tokens,
    }

    print(f"{'':14s} {'tok/vs':>9s} {'tok/ws':>9s} {'p99 vs':>9s} {'KV bytes':>12s}")
    print(f"{'contiguous':14s} {contig['tokens_per_vsec']:9.1f} "
          f"{contig['tokens_per_wsec']:9.1f} {contig['latency_p99_vsec']:9.4f} "
          f"{contig_bytes:12d}")
    print(f"{'paged (hw)':14s} {paged['tokens_per_vsec']:9.1f} "
          f"{paged['tokens_per_wsec']:9.1f} {paged['latency_p99_vsec']:9.4f} "
          f"{hw_bytes:12d}")
    print(f"memory high-water ratio {payload['memory_high_water_ratio']:.3f}  "
          f"(arena capacity {payload['arena_capacity_ratio']:.3f})  "
          f"tok/vs ratio {payload['tokens_per_vsec_ratio']:.3f}  "
          f"byte-identical {payload['tokens_byte_identical']}")

    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="more requests")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
