"""Prefix sharing: COW adoption multiplies concurrent lanes per arena.

Drives two paged ``repro.serve.ServeEngine`` instances over the same
90%-shared-prompt workload (one 64-token system prefix + a short unique
suffix per request) and writes ``BENCH_prefix.json``. What sharing buys:

  * **effective slots**: commit-at-admission reserves every request's
    full block budget up front, so a 13-block arena admits only 2 lanes
    at a time. With ``prefix_sharing=True`` the admission path adopts
    the 4 full prefix blocks from the trie (refcount++, zero copies)
    and allocates unique suffix blocks lazily, so 4 lanes fit under the
    SAME arena — the paper's adapt-the-load move applied to KV memory.
    Reported as ``effective_slots_ratio`` = peak concurrent lanes
    shared / unshared, gated >= 2x in CI.
  * **latency**: more lanes in flight means the queue drains sooner on
    the deterministic event clock; the p99 ratio is gated <= 1.05x (it
    lands well below 1.0 in practice).
  * **correctness**: every stream — including any preempted-and-
    requeued request — must stay byte-identical to
    ``generate_offline``. A single flipped token fails the benchmark.

Wall-clock numbers are the usual sanity check; the event clock carries
the claim. Preemption counts are reported so a geometry change that
silently stops exercising the evict path is visible in the JSON.

    PYTHONPATH=src python -m benchmarks.perf_prefix [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Scheduler, ServeEngine, generate_offline

from .common import write_bench_json

DEFAULT_OUT = "BENCH_prefix.json"

ARCH = "smollm"
N_SLOTS = 4
MAX_LEN = 96
BLOCK_SIZE = 16
ARENA_BLOCKS = 13     # commits 2 full budgets; fits 4 adopted lanes
SHARED_LEN = 64       # 4 full blocks of shared system prefix
GEN_TOKENS = 16
SEED = 11


def make_workload(
    n_requests: int, vocab: int, seed: int = SEED
) -> List[Tuple[np.ndarray, int, float]]:
    """One 64-token shared prefix + 4-7 unique suffix tokens per
    request: each budget is ceil(~86/16) = 6 blocks, so the 13-block
    arena commits only 2 lanes up front, while adoption needs just
    2 unique blocks per lane on top of the 4 shared ones."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=SHARED_LEN).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        suf = rng.integers(
            0, vocab, size=int(rng.integers(4, 8))
        ).astype(np.int32)
        reqs.append((np.concatenate([shared, suf]), GEN_TOKENS, i * 0.002))
    return reqs


def run_engine(model, params, reqs, prefix_sharing: bool):
    eng = ServeEngine(
        model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        scheduler=Scheduler(N_SLOTS, prefill_chunk=16, decode_per_prefill=2),
        block_size=BLOCK_SIZE, arena_blocks=ARENA_BLOCKS,
        prefix_sharing=prefix_sharing,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    peak = 0
    t0 = time.perf_counter()
    while eng.has_work:
        eng.step()
        peak = max(peak, sum(r is not None for r in eng.pool.owner))
    wall = time.perf_counter() - t0
    results = {rid: eng.request(rid) for rid in rids}
    lat = np.array([r.latency for r in results.values()])
    s = eng.stats
    stats = {
        "peak_concurrent_lanes": peak,
        "decode_ticks": s.decode_ticks,
        "generated_tokens": s.generated_tokens,
        "prefix_hits": s.prefix_hits,
        "prefix_rows_shared": s.prefix_rows_shared,
        "preempted_requests": s.preempted_requests,
        "blocks_high_water": eng.pool.manager.used_high_water,
        "drain_vsec": round(float(eng.sched.clock.now), 5),
        "tokens_per_wsec": round(s.generated_tokens / max(wall, 1e-9), 2),
        "latency_p50_vsec": round(float(np.percentile(lat, 50)), 5),
        "latency_p99_vsec": round(float(np.percentile(lat, 99)), 5),
    }
    tokens = [results[rid].tokens for rid in rids]
    eng.pool.manager.check()           # arena invariants hold post-drain
    assert eng.pool.manager.n_free_blocks == ARENA_BLOCKS
    return stats, tokens


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_requests = 8 if fast else 24
    reqs = make_workload(n_requests, cfg.vocab_size)
    refs = [generate_offline(model, params, p, m, MAX_LEN)
            for p, m, _ in reqs]

    # Warm the jit cache at the measured arena geometry so wall numbers
    # are steady-state (the event clock is unaffected either way).
    warm = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                       block_size=BLOCK_SIZE, arena_blocks=ARENA_BLOCKS)
    warm.submit(np.arange(5, dtype=np.int32), 3)
    warm.run()

    unshared, unshared_tokens = run_engine(model, params, reqs, False)
    shared, shared_tokens = run_engine(model, params, reqs, True)

    byte_identical = (shared_tokens == refs) and (unshared_tokens == refs)
    payload = {
        "benchmark": "perf_prefix",
        "mode": "fast" if fast else "full",
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "arena_blocks": ARENA_BLOCKS,
        "shared_prefix_len": SHARED_LEN,
        "requests": n_requests,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "unshared": unshared,
        "shared": shared,
        "effective_slots_ratio": round(
            shared["peak_concurrent_lanes"]
            / max(unshared["peak_concurrent_lanes"], 1), 4
        ),
        "drain_vsec_ratio": round(
            shared["drain_vsec"] / max(unshared["drain_vsec"], 1e-12), 4
        ),
        "p99_latency_ratio": round(
            shared["latency_p99_vsec"]
            / max(unshared["latency_p99_vsec"], 1e-12), 4
        ),
        "prefix_hits": shared["prefix_hits"],
        "tokens_byte_identical": byte_identical,
    }

    print(f"{'':12s} {'lanes':>6s} {'hits':>6s} {'preempt':>8s} "
          f"{'drain vs':>9s} {'p99 vs':>9s}")
    for name, st in (("unshared", unshared), ("shared", shared)):
        print(f"{name:12s} {st['peak_concurrent_lanes']:6d} "
              f"{st['prefix_hits']:6d} {st['preempted_requests']:8d} "
              f"{st['drain_vsec']:9.4f} {st['latency_p99_vsec']:9.4f}")
    print(f"effective slots {payload['effective_slots_ratio']:.2f}x  "
          f"p99 ratio {payload['p99_latency_ratio']:.3f}  "
          f"byte-identical {payload['tokens_byte_identical']}")

    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="more requests")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
