"""Serving-plane chaos benchmark: replica kill/rejoin under saturation.

Drives the multi-replica ``Frontend`` (hedged dispatch, real loser
cancellation, deadline/retry, KV migration — DESIGN.md §13) over the
same deterministic virtual-time machinery as ``perf_serve``, and
measures what a mid-saturation replica failure costs:

  * ``fault_free``  — N replicas, no chaos: the latency baseline.
  * ``kill_rejoin`` — one replica fails once the plane is saturated and
    rejoins later; the router re-prices from the shrunken fleet, orphan
    requests requeue from their longest emitted prefix.
  * ``drain``       — the same interruption as a graceful decommission:
    in-flight requests migrate off via KV block handoff (no re-prefill).

Hard gates (enforced here AND by the serve-chaos CI job):

  * every request completes in every scenario — zero drops;
  * every token stream is byte-identical to the fault-free run (greedy
    determinism survives failover, requeue, and migration);
  * kill_rejoin p99 latency <= 1.5x the fault-free p99 (losing a third
    of the fleet degrades the tail, it must not collapse it).

    PYTHONPATH=src python -m benchmarks.perf_replicas [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.core.delay_models import SimplifiedDelayModel
from repro.models import build_model
from repro.runtime.faults import FaultEvent
from repro.serve import Frontend, Replica, generate_offline

from .common import write_bench_json

DEFAULT_OUT = "BENCH_replicas.json"

ARCH = "smollm"
N_REPLICAS = 3
N_SLOTS = 4
MAX_LEN = 96
BLOCK_SIZE = 8
SEED = 11
P99_GATE = 1.5


def make_workload(
    n_requests: int, rate: float, vocab: int, seed: int = SEED
) -> List[Tuple[np.ndarray, int, float]]:
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for _ in range(n_requests):
        p_len = int(rng.integers(4, 20))
        n_new = int(rng.integers(4, 32))
        t += float(rng.exponential(1.0 / rate))
        prompt = rng.integers(0, vocab, size=p_len).astype(np.int32)
        reqs.append((prompt, n_new, t))
    return reqs


def _fleet(model, params, obs=None):
    return [
        Replica(i, model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                block_size=BLOCK_SIZE, obs=obs)
        for i in range(N_REPLICAS)
    ]


def _run_plane(model, params, reqs, events=(), **kw):
    delay = SimplifiedDelayModel(lambda_y=2.0)
    obs = kw.pop("obs", None)
    fe = Frontend(
        _fleet(model, params, obs=obs), delay,
        cost_per_replica=kw.pop("cost_per_replica", 0.05),
        events=list(events), obs=obs, **kw,
    )
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    t0 = time.perf_counter()
    out = fe.run()
    wall = time.perf_counter() - t0
    streams = [out[g].tokens for g in gids]
    lats = np.array([out[g].latency for g in gids if out[g].done])
    s = fe.summary()
    return fe, {
        "completed": int(s["completed"]),
        "dropped": int(s["dropped"]),
        "retries": int(s["retries"]),
        "migrations": int(s["migrations"]),
        "cancelled_copies": int(s["cancelled_copies"]),
        "ticks": fe.ticks,
        "latency_p50_vsec": round(float(np.percentile(lats, 50)), 5),
        "latency_p99_vsec": round(float(np.percentile(lats, 99)), 5),
        "wall_seconds": round(wall, 3),
    }, streams


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_requests = 10 if fast else 28
    rate = 60.0
    reqs = make_workload(n_requests, rate, cfg.vocab_size)

    # Reference streams: per-request offline greedy decode (also warms
    # the jit caches before any wall clock starts).
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]

    # -- fault-free baseline -------------------------------------------------
    _, base, base_streams = _run_plane(model, params, reqs)
    assert base["dropped"] == 0 and base["completed"] == n_requests
    assert base_streams == refs, "fault-free streams must match offline"

    # Chaos timing derives from the measured fault-free plane length, so
    # the kill always lands mid-saturation regardless of workload size.
    t_fail = max(int(base["ticks"] * 0.3), 1)
    t_join = max(int(base["ticks"] * 0.7), t_fail + 1)

    # -- kill one replica at saturation, rejoin later ------------------------
    # The chaos run carries a live Observability so the benchmark's
    # metrics (hedge wins/cancels, fault counters, occupancy high-water)
    # land in the payload through the registry, and the trace invariants
    # hold under real failover.
    from repro.obs import Observability, validate_trace

    kill_obs = Observability()
    kill_events = [FaultEvent(step=t_fail, kind="fail", worker=1),
                   FaultEvent(step=t_join, kind="rejoin", worker=1)]
    _, kill, kill_streams = _run_plane(
        model, params, reqs, kill_events, obs=kill_obs
    )
    trace_errors = validate_trace(kill_obs.tracer.events)
    assert not trace_errors, f"trace invariant violations: {trace_errors[:5]}"
    assert not kill_obs.tracer.open_spans, "spans leaked across failover"
    assert kill["dropped"] == 0 and kill["completed"] == n_requests, (
        f"chaos run dropped requests: {kill}"
    )
    assert kill_streams == refs, "chaos streams must be byte-identical"
    p99_ratio = kill["latency_p99_vsec"] / max(base["latency_p99_vsec"], 1e-12)
    assert p99_ratio <= P99_GATE, (
        f"p99 under single-replica kill degraded {p99_ratio:.2f}x "
        f"(gate {P99_GATE}x)"
    )

    # -- graceful decommission: KV migration instead of request loss --------
    # Single-copy dispatch (high replica cost) so the drain MUST move
    # state — hedge copies can't cover it.
    drain_events = [FaultEvent(step=t_fail, kind="drain", worker=0),
                    FaultEvent(step=3 * t_join, kind="rejoin", worker=0)]
    _, drain, drain_streams = _run_plane(
        model, params, reqs, drain_events, cost_per_replica=10.0
    )
    assert drain["dropped"] == 0 and drain_streams == refs

    print(f"{'scenario':>12s} {'p50':>9s} {'p99':>9s} {'retries':>8s} "
          f"{'migr':>5s} {'cancelled':>10s}")
    for name, r in (("fault_free", base), ("kill_rejoin", kill),
                    ("drain", drain)):
        print(f"{name:>12s} {r['latency_p50_vsec']:9.4f} "
              f"{r['latency_p99_vsec']:9.4f} {r['retries']:8d} "
              f"{r['migrations']:5d} {r['cancelled_copies']:10d}")
    print(f"kill_rejoin p99 ratio: {p99_ratio:.3f}x (gate {P99_GATE}x)")

    payload = {
        "benchmark": "perf_replicas",
        "mode": "fast" if fast else "full",
        "arch": cfg.name,
        "n_replicas": N_REPLICAS,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "requests": n_requests,
        "arrival_rate_per_vsec": rate,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "fault_free": base,
        "kill_rejoin": kill,
        "drain": drain,
        "gates": {
            "zero_dropped": True,
            "byte_identical_streams": True,
            "p99_kill_ratio": round(p99_ratio, 3),
            "p99_gate": P99_GATE,
            "trace_valid": True,
            "no_span_leaks": True,
        },
        "obs": {
            "trace_events": len(kill_obs.tracer.events),
            "hedge_decisions": len(kill_obs.decisions.by_domain("serve.hedge")),
            "metrics": kill_obs.metrics.snapshot(),
        },
    }
    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more requests (slower, steadier percentiles)")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
