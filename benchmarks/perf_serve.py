"""Serving perf: static batching vs continuous batching.

Drives ``repro.serve.ServeEngine`` and the ``run_static`` baseline over
identical synthetic workloads (Poisson arrivals, mixed prompt lengths
and generation budgets, fixed seeds) on the smallest registered config
and writes ``BENCH_serve.json``. Two clocks are reported:

  * the deterministic event clock (``*_vsec``) — latency p50/p99 and the
    headline aggregate tokens/s comparison, exact and CI-stable (both
    engines run the same fixed-shape jit calls, so the cost model's
    per-call pricing is the honest comparison);
  * wall time (``*_wsec``) — the sanity check that the virtual win is
    real on the machine at hand.

Continuous batching wins by refilling freed slots immediately: static
batching burns decode ticks on lanes whose request already finished
while the longest one in the batch drags on, and the gap widens with the
arrival rate and with the spread of per-request token budgets.

    PYTHONPATH=src python -m benchmarks.perf_serve [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine, run_static

from .common import write_bench_json

DEFAULT_OUT = "BENCH_serve.json"

ARCH = "smollm"        # smallest registered config
N_SLOTS = 4
MAX_LEN = 96
SEED = 7


def make_workload(
    n_requests: int, rate: float, vocab: int, seed: int = SEED
) -> List[Tuple[np.ndarray, int, float]]:
    """Poisson arrivals at ``rate`` req/vsec; prompt len 4-23, generation
    budget 2-55 (wide spread — the regime where dead static lanes hurt)."""
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for _ in range(n_requests):
        p_len = int(rng.integers(4, 24))
        n_new = int(rng.integers(2, 56))
        n_new = min(n_new, MAX_LEN - p_len)
        t += float(rng.exponential(1.0 / rate))
        prompt = rng.integers(0, vocab, size=p_len).astype(np.int32)
        reqs.append((prompt, n_new, t))
    return reqs


def _latencies(results) -> np.ndarray:
    return np.array([r.latency for r in results.values()])


def measure_rate(model, params, rate: float, n_requests: int) -> dict:
    reqs = make_workload(n_requests, rate, model.cfg.vocab_size)

    eng = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN)
    for prompt, m, arr in reqs:
        eng.submit(prompt, m, arrival=arr)
    t0 = time.perf_counter()
    cont_results = eng.run()
    cont_wall = time.perf_counter() - t0
    cont = eng.stats

    t0 = time.perf_counter()
    stat_results, stat = run_static(
        model, params, reqs, n_slots=N_SLOTS, max_len=MAX_LEN
    )
    stat_wall = time.perf_counter() - t0

    lc, ls = _latencies(cont_results), _latencies(stat_results)
    return {
        "arrival_rate_per_vsec": rate,
        "requests": n_requests,
        "continuous": {
            "decode_ticks": cont.decode_ticks,
            "generated_tokens": cont.generated_tokens,
            "tokens_per_vsec": round(cont.tokens_per_vsec, 2),
            "tokens_per_wsec": round(cont.generated_tokens / max(cont_wall, 1e-9), 2),
            "latency_p50_vsec": round(float(np.percentile(lc, 50)), 5),
            "latency_p99_vsec": round(float(np.percentile(lc, 99)), 5),
        },
        "static": {
            "decode_ticks": stat.decode_ticks,
            "generated_tokens": stat.generated_tokens,
            "tokens_per_vsec": round(stat.tokens_per_vsec, 2),
            "tokens_per_wsec": round(stat.generated_tokens / max(stat_wall, 1e-9), 2),
            "latency_p50_vsec": round(float(np.percentile(ls, 50)), 5),
            "latency_p99_vsec": round(float(np.percentile(ls, 99)), 5),
        },
        "throughput_gain_vsec": round(
            cont.tokens_per_vsec / max(stat.tokens_per_vsec, 1e-12), 3
        ),
    }


def hedging_summary() -> dict:
    """Rider metric: what order-statistics hedging buys the router.

    Expected completion time of a single replica vs the priced optimal
    hedge, under the paper's simplified delay model (§10)."""
    from repro.core.delay_models import SimplifiedDelayModel
    from repro.serve import HedgedRouter

    model = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(model, 8, quorum=1, cost_per_replica=0.08)
    plan = router.choose_hedge()
    single = router.hedge_cost(1)
    return {
        "delay_model": "simplified(lambda_y=2.0, x=0.05)",
        "cost_per_replica": 0.08,
        "chosen_fanout": plan.n_h,
        "single_replica_cost": round(single, 4),
        "hedged_cost": round(plan.expected_cost, 4),
        "hedge_gain": round(single / plan.expected_cost, 3),
    }


def obs_overhead(model, params, n_requests: int) -> dict:
    """Evidence for the observability plane's cost contract: with obs
    left at the default (disabled ``NULL_OBS``) the instrumented engine
    prices tokens on the virtual clock exactly as before, and turning
    tracing+metrics ON must leave greedy token streams byte-identical —
    the only honest cost is wall time, reported as a ratio."""
    from repro.obs import Observability

    reqs = make_workload(n_requests, 80.0, model.cfg.vocab_size, seed=SEED + 1)

    def _go(obs):
        eng = ServeEngine(
            model, params, n_slots=N_SLOTS, max_len=MAX_LEN, obs=obs
        )
        for prompt, m, arr in reqs:
            eng.submit(prompt, m, arrival=arr)
        t0 = time.perf_counter()
        results = eng.run()
        wall = time.perf_counter() - t0
        streams = {rid: tuple(r.tokens) for rid, r in results.items()}
        return eng, streams, wall

    eng_off, s_off, w_off = _go(None)            # default: NULL_OBS
    eng_on, s_on, w_on = _go(Observability())    # tracer + metrics live
    off_tps = eng_off.stats.tokens_per_vsec
    on_tps = eng_on.stats.tokens_per_vsec
    return {
        "requests": n_requests,
        "disabled_tokens_per_vsec": round(off_tps, 2),
        "enabled_tokens_per_vsec": round(on_tps, 2),
        "tokens_per_vsec_ratio": round(on_tps / max(off_tps, 1e-12), 6),
        "disabled_wall_sec": round(w_off, 4),
        "enabled_wall_sec": round(w_on, 4),
        "wall_ratio": round(w_on / max(w_off, 1e-9), 3),
        "tokens_byte_identical": s_off == s_on,
        "trace_events": len(eng_on.obs.tracer.events),
        "metrics": eng_on.obs.metrics.snapshot(),
    }


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_requests = 12 if fast else 48
    rates = (20.0, 200.0) if fast else (20.0, 80.0, 400.0)

    # Warm the jit caches so wall numbers compare steady-state execution.
    warm = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN)
    warm.submit(np.arange(5, dtype=np.int32), 3)
    warm.run()

    points = []
    print(f"{'rate':>8s} {'cont tok/vs':>12s} {'stat tok/vs':>12s} {'gain':>6s} "
          f"{'cont p99':>9s} {'stat p99':>9s}")
    for rate in rates:
        r = measure_rate(model, params, rate, n_requests)
        points.append(r)
        c, s = r["continuous"], r["static"]
        print(f"{rate:8.0f} {c['tokens_per_vsec']:12.1f} {s['tokens_per_vsec']:12.1f} "
              f"{r['throughput_gain_vsec']:5.2f}x {c['latency_p99_vsec']:9.4f} "
              f"{s['latency_p99_vsec']:9.4f}")

    payload = {
        "benchmark": "perf_serve",
        "mode": "fast" if fast else "full",
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "points": points,
        "hedging": hedging_summary(),
        "obs_overhead": obs_overhead(model, params, n_requests),
    }
    oo = payload["obs_overhead"]
    print(f"obs overhead: tok/vs ratio {oo['tokens_per_vsec_ratio']:.4f} "
          f"wall ratio {oo['wall_ratio']:.3f} "
          f"byte-identical {oo['tokens_byte_identical']} "
          f"({oo['trace_events']} trace events)")
    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more requests and arrival rates")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
