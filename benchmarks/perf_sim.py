"""Perf trajectory of the simulation engines: scalar vs batched.

Times ``repro.core.simulation.simulate`` (per-seed reference oracle)
against ``repro.core.vector_sim.simulate_batch`` at several
(n, seeds, iters) points and writes the measurements to
``BENCH_sim.json`` — the repo's perf record for its hottest path. The
headline point is the paper's Fig. 4 configuration (n=20, 24 seeds,
20k iterations); the acceptance floor there is a 20x speedup on CPU
(EXPERIMENTS.md §Perf tracks the measured numbers per machine).

    PYTHONPATH=src python -m benchmarks.perf_sim [--full] [--out PATH]

Fast mode (the default, used by the CI smoke step) runs scaled-down
points; ``--full`` runs the acceptance configuration.
"""

from __future__ import annotations

import argparse
import dataclasses
import platform
from typing import Optional, Tuple

import numpy as np

from repro.core import (
    LinregProblem,
    SimplifiedDelayModel,
    StrategyConfig,
    simulate,
    simulate_batch,
)

from .common import PAPER_GRID, Timer, write_bench_json

DEFAULT_OUT = "BENCH_sim.json"


@dataclasses.dataclass(frozen=True)
class PerfPoint:
    name: str
    n: int          # workers (s stays 20 samples/worker as in Fig. 4)
    seeds: int
    iters: int
    strategy: str

    @property
    def v(self) -> int:
        return self.n * 20


# Fig. 4 runs both adaptive strategies; time each separately so the
# beta<1 subsampling path (adaptive_kbeta) and the pure beta=1 path
# (adaptive_k) are both tracked.
FULL_POINTS = (
    PerfPoint("fig4_kbeta", n=20, seeds=24, iters=20_000, strategy="adaptive_kbeta"),
    PerfPoint("fig4_k", n=20, seeds=24, iters=20_000, strategy="adaptive_k"),
    PerfPoint("small_n", n=10, seeds=24, iters=20_000, strategy="adaptive_kbeta"),
    PerfPoint("large_n", n=50, seeds=24, iters=8_000, strategy="adaptive_kbeta"),
)

FAST_POINTS = (
    PerfPoint("fig4_kbeta_smoke", n=20, seeds=8, iters=2_000, strategy="adaptive_kbeta"),
    PerfPoint("fig4_k_smoke", n=20, seeds=8, iters=2_000, strategy="adaptive_k"),
)


def _setup(pt: PerfPoint) -> Tuple[LinregProblem, StrategyConfig, SimplifiedDelayModel]:
    problem = LinregProblem.generate(v=pt.v, d=10, n_workers=pt.n, seed=1)
    cfg = StrategyConfig(
        pt.strategy, n=pt.n, s=20, k_max=max(pt.n // 2, 1), beta_grid=PAPER_GRID
    )
    model = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    return problem, cfg, model


def measure_point(pt: PerfPoint, *, scalar_seeds: Optional[int] = None) -> dict:
    """Time scalar (per-seed loop) vs batched at one configuration.

    ``scalar_seeds`` caps how many scalar runs are actually timed (the
    per-seed cost is flat, so fast mode extrapolates from fewer seeds —
    recorded explicitly in the output as ``scalar_seeds_timed``).
    """
    problem, cfg, model = _setup(pt)
    n_scalar = pt.seeds if scalar_seeds is None else min(scalar_seeds, pt.seeds)

    with Timer() as tb:
        batch = simulate_batch(
            problem, cfg, model, seeds=pt.seeds, max_iters=pt.iters, eval_every=10
        )
    with Timer() as ts:
        for seed in range(n_scalar):
            simulate(
                problem, cfg, model, seed=seed, max_iters=pt.iters, eval_every=10
            )
    scalar_total = ts.elapsed * (pt.seeds / n_scalar)
    # Equivalence spot check rides along: lane 0 vs scalar seed 0.
    ref = simulate(problem, cfg, model, seed=0, max_iters=pt.iters, eval_every=10)
    lane = batch.lane(0)
    equal = bool(
        np.allclose(ref.gaps, lane.gaps, rtol=1e-7, atol=1e-10)
        and np.allclose(ref.times, lane.times, rtol=1e-7, atol=1e-10)
    )
    return {
        "name": pt.name,
        "n": pt.n,
        "seeds": pt.seeds,
        "iters": pt.iters,
        "strategy": pt.strategy,
        "scalar_seconds": round(scalar_total, 4),
        "scalar_seconds_per_seed": round(scalar_total / pt.seeds, 4),
        "scalar_seeds_timed": n_scalar,
        "batch_seconds": round(tb.elapsed, 4),
        "speedup": round(scalar_total / tb.elapsed, 2),
        "batch_us_per_iter": round(tb.elapsed / pt.iters * 1e6, 2),
        "lane0_matches_scalar": equal,
    }


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    points = FAST_POINTS if fast else FULL_POINTS
    scalar_seeds = 4 if fast else None
    results = []
    print(f"{'point':22s} {'scalar s':>9s} {'batch s':>8s} {'speedup':>8s}  lane0==scalar")
    for pt in points:
        r = measure_point(pt, scalar_seeds=scalar_seeds)
        results.append(r)
        print(
            f"{r['name']:22s} {r['scalar_seconds']:9.2f} {r['batch_seconds']:8.2f} "
            f"{r['speedup']:7.1f}x  {r['lane0_matches_scalar']}"
        )
    payload = {
        "benchmark": "perf_sim",
        "mode": "fast" if fast else "full",
        "machine": platform.machine(),
        "python": platform.python_version(),
        "numpy": np.__version__,
        "points": results,
    }
    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="acceptance configuration (Fig. 4: n=20, 24 seeds, "
                         "20k iters); fast mode runs scaled-down smoke points")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH",
                    help=f"JSON output path (default {DEFAULT_OUT})")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
