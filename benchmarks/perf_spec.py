"""Speculative vs plain continuous-batching decode (`BENCH_spec.json`).

Drives two ``repro.serve.ServeEngine`` instances over an identical
saturated mixed-length workload — one plain, one with a draft model
attached (DESIGN.md §12) — plus a fixed-gamma sweep, and writes
``BENCH_spec.json``:

  * **tokens/s (event clock)**: the headline. The draft is priced at
    ``CostModel.draft_ratio`` (default 0.3) of the target per action and
    the verify call at one decode tick plus a per-token term, so the
    gain is exactly what the deterministic cost model admits: fewer,
    wider actions win whenever acceptance clears the overhead. The
    adaptive controller's row should match or beat the best fixed gamma.
  * **accepted-prefix histogram**: how often lane-rounds (one entry per
    speculating slot per round) banked 0..gamma draft tokens — the
    k-outcome distribution the gamma pricing integrates over.
  * **byte identity**: speculative greedy tokens must equal the plain
    engine's exactly (which tests/test_serve.py pins to offline decode).

The draft here is the target architecture with small parameter noise —
a stand-in with a tunable agreement rate (the interesting operating
point for acceptance telemetry), priced at the configured cost ratio.
Where speculation LOSES (draft/target ratio near 1, or low acceptance),
the adaptive row degrades gracefully to ~the plain engine (gamma -> 0)
while the fixed-gamma rows pay full price — see the EXPERIMENTS.md
caveat.

    PYTHONPATH=src python -m benchmarks.perf_spec [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import platform
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Scheduler, ServeEngine, SpecController

from .common import write_bench_json

DEFAULT_OUT = "BENCH_spec.json"

ARCH = "smollm"
N_SLOTS = 4
MAX_LEN = 128
RATE = 200.0          # saturated arrivals: every slot stays busy
GAMMA_MAX = 6
DRAFT_NOISE = 3e-4    # draft = target params + noise at this scale
SEED = 11


def make_workload(
    n_requests: int, vocab: int, seed: int = SEED
) -> List[Tuple[np.ndarray, int, float]]:
    """Decode-heavy requests (prompt 4-23, generation 32-63): the regime
    where speculation matters — decode ticks dominate, prefill is a
    small constant on both sides."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for _ in range(n_requests):
        p_len = int(rng.integers(4, 24))
        n_new = int(rng.integers(32, 64))
        t += float(rng.exponential(1.0 / RATE))
        prompt = rng.integers(0, vocab, size=p_len).astype(np.int32)
        reqs.append((prompt, n_new, t))
    return reqs


def perturb(params, scale: float, seed: int = 7):
    import jax

    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(
        treedef,
        [l + scale * jax.random.normal(k, l.shape, l.dtype)
         for l, k in zip(leaves, keys)],
    )


def run_engine(model, params, reqs, *, draft=None, controller=None):
    eng = ServeEngine(
        model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
        scheduler=Scheduler(N_SLOTS, prefill_chunk=16, decode_per_prefill=2),
        draft_model=None if draft is None else draft[0],
        draft_params=None if draft is None else draft[1],
        gamma_max=GAMMA_MAX, spec_controller=controller,
    )
    for prompt, m, arr in reqs:
        eng.submit(prompt, m, arrival=arr)
    t0 = time.perf_counter()
    results = eng.run()
    wall = time.perf_counter() - t0
    s = eng.stats
    point = {
        "tokens_per_vsec": round(s.tokens_per_vsec, 2),
        "tokens_per_wsec": round(s.generated_tokens / max(wall, 1e-9), 2),
        "generated_tokens": s.generated_tokens,
        "spec_rounds": s.spec_rounds,
        "draft_ticks": s.draft_ticks,
        "accepted_draft_tokens": s.spec_accepted,
    }
    if eng.spec is not None:
        point["accept_hist"] = eng.spec.hist.tolist()
        point["p_ewma"] = round(float(eng.spec.p), 4)
    return point, {rid: r.tokens for rid, r in results.items()}


class _FixedGamma(SpecController):
    """Ablation: pin gamma (skip the adaptive pricing)."""

    def __init__(self, gamma: int):
        super().__init__(gamma_max=max(gamma, 1))
        self._fixed = gamma

    def choose_gamma(self, cost):
        plan = super().choose_gamma(cost)  # keeps telemetry/probe clocks
        from repro.serve.speculative import GammaPlan, expected_round_tokens
        toks = expected_round_tokens(self._fixed, self.p_effective)
        c = self.round_cost(self._fixed, cost)
        return GammaPlan(self._fixed, toks, c, c / toks)


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft_model = build_model(cfg)
    draft_params = perturb(params, DRAFT_NOISE)
    n_requests = 16 if fast else 48
    reqs = make_workload(n_requests, cfg.vocab_size)

    # Warm both jit families so wall numbers are steady-state.
    for kw in ({}, {"draft": (draft_model, draft_params)}):
        warm, _ = run_engine(model, params,
                             [(np.arange(5, dtype=np.int32), 8, 0.0)], **kw)

    plain, plain_tokens = run_engine(model, params, reqs)
    gammas = [2, 4, GAMMA_MAX] if fast else [1, 2, 3, 4, 5, GAMMA_MAX]
    sweep = {}
    for g in gammas:
        sweep[g], toks = run_engine(
            model, params, reqs,
            draft=(draft_model, draft_params), controller=_FixedGamma(g),
        )
        sweep[g]["byte_identical"] = toks == plain_tokens
    adaptive, adaptive_tokens = run_engine(
        model, params, reqs, draft=(draft_model, draft_params),
    )
    adaptive["byte_identical"] = adaptive_tokens == plain_tokens

    ratio = adaptive["tokens_per_vsec"] / max(plain["tokens_per_vsec"], 1e-12)
    payload = {
        "benchmark": "perf_spec",
        "mode": "fast" if fast else "full",
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "requests": n_requests,
        "arrival_rate_per_vsec": RATE,
        "gamma_max": GAMMA_MAX,
        "draft_cost_ratio": Scheduler(1).clock.cost.draft_ratio,
        "draft_noise": DRAFT_NOISE,
        "machine": platform.machine(),
        "python": platform.python_version(),
        "plain": plain,
        "fixed_gamma": {str(g): v for g, v in sweep.items()},
        "adaptive": adaptive,
        "tokens_per_vsec_ratio": round(ratio, 4),
        "tokens_byte_identical": bool(
            adaptive["byte_identical"]
            and all(v["byte_identical"] for v in sweep.values())
        ),
    }

    print(f"{'engine':14s} {'tok/vs':>9s} {'tok/ws':>9s} {'rounds':>7s} "
          f"{'accepted':>9s} {'identical':>10s}")
    print(f"{'plain':14s} {plain['tokens_per_vsec']:9.1f} "
          f"{plain['tokens_per_wsec']:9.1f} {'-':>7s} {'-':>9s} {'ref':>10s}")
    for g, v in sweep.items():
        print(f"{f'gamma={g}':14s} {v['tokens_per_vsec']:9.1f} "
              f"{v['tokens_per_wsec']:9.1f} {v['spec_rounds']:7d} "
              f"{v['accepted_draft_tokens']:9d} {str(v['byte_identical']):>10s}")
    v = adaptive
    print(f"{'adaptive':14s} {v['tokens_per_vsec']:9.1f} "
          f"{v['tokens_per_wsec']:9.1f} {v['spec_rounds']:7d} "
          f"{v['accepted_draft_tokens']:9d} {str(v['byte_identical']):>10s}")
    print(f"adaptive tok/vs ratio {ratio:.3f}x  accept hist "
          f"{adaptive.get('accept_hist')}")

    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="more requests")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
