"""Live adaptive-(k, beta) training vs the baselines (`BENCH_train_adaptive.json`).

Runs the REAL gradient path (`repro.runtime.train_loop`: jitted train
steps, masked fastest-k aggregation, censored telemetry) under four
strategies on an identical tiny LM + delay model, and reports
sim-time-to-target-loss — the paper's Fig. 4 comparison executed live
instead of simulated:

  * ``naive``          — synchronous SGD: wait for all n at beta = 1;
  * ``fastest_k``      — fixed (k0, 1), the [32]-style baseline;
  * ``adaptive_k``     — k = 1, 2, ... at beta = 1 (arXiv 2002.11005's
    gradually-increasing-k family);
  * ``adaptive_kbeta`` — THE PAPER: grow beta along the grid, then raise
    k and drop beta to the Cor. 4 optimum.

Honesty constraints:
  * the controller gets NO oracle delay model (``oracle_to_controller=
    False``): every (k, beta) decision is priced off the censored MLE
    fitted from the k order statistics the loop actually waited for;
  * all strategies share the same data stream, model init, and response
    time RNG (the loop samples the full fleet each step regardless of k);
  * the target loss is set so every strategy reaches it (1.02x the
    worst strategy's best smoothed loss), then each strategy is charged
    the sim-time at its first crossing.

    PYTHONPATH=src python -m benchmarks.perf_train_adaptive [--full] [--out PATH]
"""

from __future__ import annotations

import argparse
import platform
from typing import Optional

import numpy as np

from .common import write_bench_json

DEFAULT_OUT = "BENCH_train_adaptive.json"

N_WORKERS = 8
K_MAX = 4
GLOBAL_BATCH = 32
SEQ_LEN = 32
BETA_GRID = (0.25, 0.5, 0.75, 1.0)
LR = 3e-3
EWMA_ALPHA = 0.2
DELAY_LAMBDA = 1.0   # mean comp time beta/lambda_y at beta=1
DELAY_X = 0.05       # constant communication time
SEED = 0


def _strategies():
    from repro.core import DiagnosticConfig, StrategyConfig

    diag = DiagnosticConfig(kind="loss", rel_tol=0.02, min_iters=6,
                            consecutive=2)
    s = len(BETA_GRID)
    return {
        "naive": StrategyConfig("naive", n=N_WORKERS, s=s),
        "fastest_k": StrategyConfig("fastest_k", n=N_WORKERS, s=s, k0=2),
        "adaptive_k": StrategyConfig(
            "adaptive_k", n=N_WORKERS, s=s, k0=1, k_max=K_MAX, diagnostic=diag
        ),
        "adaptive_kbeta": StrategyConfig(
            "adaptive_kbeta", n=N_WORKERS, s=s, k0=1, k_max=K_MAX,
            beta_grid=BETA_GRID, diagnostic=diag,
        ),
    }


def _run_strategy(name, strategy, total_steps):
    import jax

    from repro.configs import get_config
    from repro.core import SimplifiedDelayModel
    from repro.data import StagedBatcher, TokenStream
    from repro.models import build_model
    from repro.optim.optimizers import get_optimizer
    from repro.runtime.train_loop import TrainLoopConfig, train

    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=256, max_seq_len=SEQ_LEN,
    )
    model = build_model(cfg)
    delay = SimplifiedDelayModel(lambda_y=DELAY_LAMBDA, x=DELAY_X)
    batcher = StagedBatcher(TokenStream(cfg.vocab_size, seed=SEED),
                            n_workers=N_WORKERS, global_batch=GLOBAL_BATCH,
                            seq_len=SEQ_LEN)
    out = train(
        model, get_optimizer("adamw"), strategy, delay, batcher,
        TrainLoopConfig(
            total_steps=total_steps, lr=LR, log_every=0, seed=SEED,
            estimate_model=True, oracle_to_controller=False,
        ),
    )
    return out


def _ewma(losses):
    out = np.empty(len(losses))
    acc = losses[0]
    for i, v in enumerate(losses):
        acc += EWMA_ALPHA * (v - acc)
        out[i] = acc
    return out


def _time_to(ewma, times, target):
    idx = np.nonzero(ewma <= target)[0]
    if idx.size == 0:
        return None, None
    return float(times[idx[0]]), int(idx[0])


def run(fast: bool = True, out: Optional[str] = None) -> dict:
    total_steps = 140 if fast else 400

    runs = {}
    for name, strategy in _strategies().items():
        print(f"-- {name}: {total_steps} live steps ...", flush=True)
        o = _run_strategy(name, strategy, total_steps)
        hist = o["history"]
        runs[name] = {
            "ewma": _ewma([h["loss"] for h in hist]),
            "times": np.array([h["sim_time"] for h in hist]),
            "stages": [(h["k"], h["beta"]) for h in hist],
            "sim_time_total": float(o["sim_time"]),
            "controller": o["controller"],
        }

    # Target every strategy reaches: 1.02x the worst best-smoothed-loss.
    target = 1.02 * max(float(r["ewma"].min()) for r in runs.values())

    points = {}
    for name, r in runs.items():
        t, step = _time_to(r["ewma"], r["times"], target)
        stages = sorted(set(r["stages"]), key=r["stages"].index)
        ctrl = r["controller"]
        fitted = ctrl.current_model()
        points[name] = {
            "time_to_target": None if t is None else round(t, 3),
            "steps_to_target": step,
            "sim_time_total": round(r["sim_time_total"], 3),
            "final_loss_ewma": round(float(r["ewma"][-1]), 4),
            "stages_visited": [[k, b] for k, b in stages],
            "fitted_lambda_y": (
                None if fitted is None else round(fitted.lambda_y, 4)
            ),
            "fitted_shift": None if fitted is None else round(fitted.shift, 4),
            "censored_samples": len(ctrl._rt_samples),
            "censored_total": round(float(np.sum(ctrl._rt_censored)), 1),
        }

    t_kbeta = points["adaptive_kbeta"]["time_to_target"]
    ratios = {}
    for name in ("naive", "fastest_k", "adaptive_k"):
        t = points[name]["time_to_target"]
        ratios[f"vs_{name}"] = (
            None if (t is None or t_kbeta is None)
            else round(t / t_kbeta, 3)
        )

    payload = {
        "benchmark": "perf_train_adaptive",
        "mode": "fast" if fast else "full",
        "n_workers": N_WORKERS,
        "k_max": K_MAX,
        "global_batch": GLOBAL_BATCH,
        "seq_len": SEQ_LEN,
        "beta_grid": list(BETA_GRID),
        "total_steps": total_steps,
        "delay_model": {"lambda_y": DELAY_LAMBDA, "x": DELAY_X},
        "controller_oracle": False,
        "target_loss_ewma": round(target, 4),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "points": points,
        "speedup": ratios,
    }

    print(f"\ntarget loss (EWMA): {target:.4f}")
    print(f"{'strategy':16s} {'t->target':>10s} {'steps':>6s} "
          f"{'t total':>9s} {'stages':>28s} {'fitted lam':>10s}")
    for name, p in points.items():
        t = "never" if p["time_to_target"] is None else f"{p['time_to_target']:.1f}"
        st = "->".join(f"({k},{b:g})" for k, b in p["stages_visited"])
        lam = "-" if p["fitted_lambda_y"] is None else f"{p['fitted_lambda_y']:.2f}"
        print(f"{name:16s} {t:>10s} {str(p['steps_to_target']):>6s} "
              f"{p['sim_time_total']:9.1f} {st:>28s} {lam:>10s}")
    print(f"adaptive_kbeta speedups: {ratios}")

    if out is not None:
        payload = write_bench_json(out, payload)
        print(f"wrote {out}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="more steps")
    ap.add_argument("--out", type=str, default=DEFAULT_OUT, metavar="PATH")
    args = ap.parse_args()
    run(fast=not args.full, out=args.out)


if __name__ == "__main__":
    main()
