"""Roofline report: three terms per (arch x shape x mesh) from dry-run
artifacts (benchmarks counterpart of EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

from pathlib import Path

from repro.analysis.roofline import format_table, load_rows

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run(fast: bool = True, mesh: str = "pod16x16"):
    rows = load_rows(ARTIFACTS, mesh=mesh, variant="baseline")
    if not rows:
        print(f"(no dry-run artifacts found under {ARTIFACTS} — run "
              f"`python -m repro.launch.dryrun --all` first)")
        return {}
    print(format_table(rows))
    print("\nper-cell dominant-term notes:")
    for r in rows:
        print(f"  {r.arch} × {r.shape}: {r.note}")
    worst = min(rows, key=lambda r: r.useful_ratio)
    most_coll = max(rows, key=lambda r: r.collective_s / max(r.compute_s, 1e-12))
    print(f"\nworst useful-compute cell : {worst.cell} ({worst.useful_ratio:.1%})")
    print(f"most collective-bound cell: {most_coll.cell} "
          f"(coll/compute = {most_coll.collective_s / max(most_coll.compute_s, 1e-12):.2f})")
    return {r.cell: r.useful_ratio for r in rows}


if __name__ == "__main__":
    run(fast=False)
