"""Benchmark runner: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a `name,seconds,status` CSV at the end.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale seeds/grids (slow)")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    fast = not args.full

    from . import fig1_3_theory, fig4_simulation, fig5to7_general_model
    from . import fig8to9_costs, roofline_report

    benches = {
        "fig1_3_theory": fig1_3_theory.run,
        "fig4_simulation": fig4_simulation.run,
        "fig5to7_general_model": fig5to7_general_model.run,
        "fig8to9_costs": fig8to9_costs.run,
        "roofline_report": roofline_report.run,
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    summary = []
    failed = 0
    for name, fn in benches.items():
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            fn(fast=fast)
            summary.append((name, time.time() - t0, "ok"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            summary.append((name, time.time() - t0, f"FAIL: {e}"))
            failed += 1

    print("\nname,seconds,status")
    for name, secs, status in summary:
        print(f"{name},{secs:.1f},{status}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
