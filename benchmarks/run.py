"""Benchmark runner: one module per paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] [--json PATH]

Prints a `name,seconds,status` CSV at the end; ``--json PATH`` also
writes the summary plus each figure's key metrics as machine-readable
JSON (consumed by the CI benchmark-smoke artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _jsonable(obj):
    """Best-effort conversion of benchmark return values to JSON types."""
    import numpy as np

    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return _jsonable(float(obj))
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, float):
        if obj != obj:
            return "nan"
        if obj in (float("inf"), float("-inf")):
            return "inf" if obj > 0 else "-inf"
        return obj
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    return str(obj)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale seeds/grids (slow)")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write name,seconds,status summary + per-figure "
                         "key metrics as JSON")
    args = ap.parse_args()
    fast = not args.full

    from analysis import trace_report

    from . import fig1_3_theory, fig4_simulation, fig5to7_general_model
    from . import fig8to9_costs, perf_paged, perf_prefix, perf_replicas
    from . import perf_serve, perf_sim, perf_spec, perf_train_adaptive
    from . import roofline_report

    benches = {
        "fig1_3_theory": fig1_3_theory.run,
        "fig4_simulation": fig4_simulation.run,
        "fig5to7_general_model": fig5to7_general_model.run,
        "fig8to9_costs": fig8to9_costs.run,
        "perf_sim": perf_sim.run,
        "perf_serve": perf_serve.run,
        "perf_paged": perf_paged.run,
        "perf_prefix": perf_prefix.run,
        "perf_replicas": perf_replicas.run,
        "perf_spec": perf_spec.run,
        "perf_train_adaptive": perf_train_adaptive.run,
        "roofline_report": roofline_report.run,
        "trace_report": trace_report.run,
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    summary = []
    metrics = {}
    failed = 0
    for name, fn in benches.items():
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.perf_counter()
        try:
            metrics[name] = fn(fast=fast)
            summary.append((name, time.perf_counter() - t0, "ok"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            summary.append((name, time.perf_counter() - t0, f"FAIL: {e}"))
            failed += 1

    print("\nname,seconds,status")
    for name, secs, status in summary:
        print(f"{name},{secs:.1f},{status}")

    # Index the BENCH_*.json files in the working directory (from
    # standalone `python -m benchmarks.perf_*` runs) so CI uploads one
    # manifest with per-file provenance meta. Every perf_* bench that
    # ran here is REQUIRED: a registered bench whose JSON is missing or
    # corrupt fails the run instead of silently dropping out of the
    # index. (Skipped under --only, which runs a subset by design.)
    from .common import write_bench_index

    required = ()
    if not args.only:
        from . import perf_paged, perf_prefix, perf_replicas, perf_serve
        from . import perf_sim, perf_spec, perf_train_adaptive

        required = tuple(sorted(
            m.DEFAULT_OUT for m in (
                perf_paged, perf_prefix, perf_replicas, perf_serve,
                perf_sim, perf_spec, perf_train_adaptive,
            )
        ))
    index = write_bench_index(".", required=required)
    if index["benchmarks"]:
        print(f"indexed {len(index['benchmarks'])} BENCH files "
              f"-> BENCH_index.json")

    if args.json:
        payload = {
            "mode": "fast" if fast else "full",
            "summary": [
                {"name": name, "seconds": round(secs, 3), "status": status}
                for name, secs, status in summary
            ],
            "figures": _jsonable(metrics),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
