"""Fault-tolerance demo: worker failure, straggler demotion, resume.

1. Train with the adaptive controller; at step 60 worker 0 dies — its
   gradient mask goes to zero permanently and the controller reprices all
   order statistics with n-1 workers.
2. A persistent straggler (worker 1, 6x slower) is demoted by the
   telemetry EWMA tracker.
3. Training checkpoints asynchronously; we then kill the loop and resume
   from the latest checkpoint, verifying step/stage state round-trips.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import tempfile

import numpy as np

from repro.configs import get_config
from repro.core import DiagnosticConfig, SimplifiedDelayModel, StrategyConfig
from repro.data import StagedBatcher, TokenStream
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, vocab_size=256, max_seq_len=64
    )
    model = build_model(cfg)
    optimizer = get_optimizer("adamw")
    n = 8
    strategy = StrategyConfig(
        "adaptive_kbeta", n=n, s=4, k_max=4, beta_grid=(0.5, 1.0),
        diagnostic=DiagnosticConfig(kind="loss", rel_tol=0.02, min_iters=8,
                                    consecutive=2),
    )
    delay = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    batcher = StagedBatcher(TokenStream(cfg.vocab_size), n_workers=n,
                            global_batch=32, seq_len=64)

    with tempfile.TemporaryDirectory() as ckdir:
        print("== phase 1: run 100 steps with failure injection at step 60 ==")
        out = train(
            model, optimizer, strategy, delay, batcher,
            TrainLoopConfig(
                total_steps=100, checkpoint_dir=ckdir, checkpoint_every=40,
                log_every=25, fail_worker_at=60, fail_worker_id=0,
                demote_after_ewma=5.0,
            ),
        )
        ctrl = out["controller"]
        print(f"workers remaining in controller: n={ctrl.cfg.n} (started {n})")
        assert ctrl.cfg.n == n - 1, "failed worker must be removed"

        print("\n== phase 2: resume from the latest checkpoint ==")
        out2 = train(
            model, optimizer, strategy, delay, batcher,
            TrainLoopConfig(
                total_steps=130, checkpoint_dir=ckdir, checkpoint_every=40,
                log_every=25,
            ),
        )
        steps = [h["step"] for h in out2["history"]]
        print(f"resumed at step {steps[0]} (checkpointed at 80), "
              f"ran to {steps[-1]}")
        assert steps[0] == 80, "must resume from the saved step"
        print("\nfault-tolerance demo OK")


if __name__ == "__main__":
    main()
