"""Chaos demo: workers slowing, dying, and REJOINING mid-run, with
exact resume from an async checkpoint.

Timeline (one adaptive-(k, beta) run, n = 8 workers):

  step 12 — worker 1 turns persistently slow (8x). The censoring-aware
            telemetry never *observes* its times (it stops making the
            fastest k); its time-on-test estimate grows from censor
            levels alone until the demotion test fires -> n -= 1.
  step 30 — worker 0 dies outright (fail event) -> n -= 1.
  step 70 — worker 0 rejoins healthy: ``Controller.add_worker`` restores
            n (and k_max up to its cap), telemetry history is reset so
            stale slowness cannot re-demote it.

Training checkpoints asynchronously throughout; we then rerun from the
latest checkpoint and verify EXACT resume: the resumed history must be
identical to the uninterrupted run's tail — same losses, same stages,
same sim-time — because the checkpoint round-trips the full controller
state, tracker state, fleet membership, and both RNG streams.

Reporting goes through ``repro.obs``: the per-step lines and the demo's
own milestones are echoes of structured ``StructuredLog`` records (the
assertions read the records), and the chaos phase is traced — pass
``--log PATH`` to export the record stream as JSON.

    PYTHONPATH=src python examples/elastic_failover.py [--log PATH]
"""

import argparse
import tempfile

import numpy as np

from repro.configs import get_config
from repro.core import DiagnosticConfig, SimplifiedDelayModel, StrategyConfig
from repro.data import StagedBatcher, TokenStream
from repro.models import build_model
from repro.obs import Observability
from repro.optim.optimizers import get_optimizer
from repro.runtime.train_loop import FaultEvent, TrainLoopConfig, train

TOTAL = 100
CKPT_EVERY = 40  # async checkpoints at steps 40 and 80


def build():
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, vocab_size=256, max_seq_len=64
    )
    model = build_model(cfg)
    optimizer = get_optimizer("adamw")
    n = 8
    strategy = StrategyConfig(
        "adaptive_kbeta", n=n, s=4, k_max=4, beta_grid=(0.5, 1.0),
        diagnostic=DiagnosticConfig(kind="loss", rel_tol=0.02, min_iters=8,
                                    consecutive=2),
    )
    delay = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    batcher = StagedBatcher(TokenStream(cfg.vocab_size), n_workers=n,
                            global_batch=32, seq_len=64)
    return model, optimizer, strategy, delay, batcher


def loop_cfg(ckdir):
    return TrainLoopConfig(
        total_steps=TOTAL, checkpoint_dir=ckdir, checkpoint_every=CKPT_EVERY,
        log_every=25, demote_after_ewma=5.0,
        events=[
            FaultEvent(step=12, kind="slow", worker=1, factor=8.0),
            FaultEvent(step=30, kind="fail", worker=0),
            FaultEvent(step=70, kind="rejoin", worker=0),
        ],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--log", type=str, default=None, metavar="PATH",
                    help="export the structured record stream as JSON")
    args = ap.parse_args()

    obs = Observability(log_echo=True)
    log = obs.log

    model, optimizer, strategy, delay, batcher = build()
    n = strategy.n

    with tempfile.TemporaryDirectory() as ckdir:
        log.emit("phase", name="chaos", steps=TOTAL,
                 chaos="slow@12,fail@30,rejoin@70")
        out = train(model, optimizer, strategy, delay, batcher, loop_cfg(ckdir),
                    obs=obs)
        ctrl, hist = out["controller"], out["history"]

        n_by_step = {h["step"]: h["n_workers"] for h in hist}
        log.emit("fleet_size", start=n_by_step[0], after_fail=n_by_step[35],
                 after_rejoin=n_by_step[75], final_n=ctrl.cfg.n)
        assert n_by_step[0] == n
        assert n_by_step[35] <= n - 1, "failed worker must be removed"
        assert min(n_by_step.values()) <= n - 2, \
            "persistent straggler must be demoted by telemetry"
        assert n_by_step[75] == n_by_step[69] + 1, \
            "rejoined worker must grow n by one"
        assert not out["alive"][1], "the demoted straggler stays out"
        assert out["alive"][0], "the rejoined worker is back"

        log.emit("phase", name="exact_resume", from_step=80)
        # Fresh model/optimizer/batcher objects: everything live must come
        # back from the checkpoint, not from leftover Python state.
        model2, optimizer2, strategy2, delay2, batcher2 = build()
        out2 = train(model2, optimizer2, strategy2, delay2, batcher2,
                     loop_cfg(ckdir), obs=obs)
        steps2 = [h["step"] for h in out2["history"]]
        assert steps2[0] == 80, "must resume from the saved step"

        tail = [h for h in hist if h["step"] >= 80]
        assert len(tail) == len(out2["history"])
        for a, b in zip(tail, out2["history"]):
            assert a == b, f"resume diverged at step {a['step']}:\n{a}\n{b}"
        log.emit("resume_check", resumed_at=steps2[0], ran_to=steps2[-1],
                 identical_steps=len(tail),
                 note="loss, stage, sim-time, workers all match the "
                      "uninterrupted run")

        assert out2["controller"].cfg.n == ctrl.cfg.n
        np.testing.assert_array_equal(out2["alive"], out["alive"])
        log.emit("verdict", ok=True,
                 stage_decisions=len(obs.decisions.by_domain("train.stage")),
                 note="chaos + exact-resume demo OK")
        if args.log:
            log.export(args.log)


if __name__ == "__main__":
    main()
