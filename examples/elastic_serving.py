"""Chaos demo: a 3-replica serving plane losing a node mid-saturation.

Timeline (one hedged-dispatch run, 3 engine replicas x 2 slots, paged
KV, deterministic virtual time):

  step 12 — replica 1 FAILS with requests in flight. Hedge copies on
            the surviving replicas cover most of them; any request
            whose only copy died requeues from its longest emitted
            prefix (greedy decode is deterministic, so every partial is
            a prefix of the same stream). The router marks the replica
            out and re-prices dispatch from the 2-node fleet.
  step 40 — replica 2 turns SLOW (6x). Nothing is told to the router —
            it just starts seeing slower completions and censored
            hedge losers, and the EWMA telemetry re-prices it toward
            the back of the dispatch order.
  step 90 — replica 1 REJOINS healthy at the fleet's time frontier.
            Its telemetry history is reset: it prices at the neutral
            prior and its first real completion seeds its estimate
            directly (no crawl-up from zero).

The demo asserts the plane's two hard guarantees, the same gates CI's
serve-chaos job enforces via benchmarks/perf_replicas.py:

  * ZERO dropped requests — every submission completes despite the
    failure;
  * BYTE-IDENTICAL tokens — each request's stream equals a per-request
    offline greedy decode, fault or no fault.

    PYTHONPATH=src python examples/elastic_serving.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SimplifiedDelayModel
from repro.models import build_model
from repro.runtime.faults import FaultEvent
from repro.serve import Frontend, Replica, generate_offline

MAX_LEN = 64
N_REPLICAS = 3
N_SLOTS = 2


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(5)
    reqs = []
    for i in range(10):
        p = int(rng.integers(4, 16))
        m = int(rng.integers(6, 14))
        prompt = rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
        reqs.append((prompt, m, i * 0.002))

    print("offline reference decode (byte-identity oracle)...")
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]

    events = [
        FaultEvent(step=12, kind="fail", worker=1),
        FaultEvent(step=40, kind="slow", worker=2, factor=6.0),
        FaultEvent(step=90, kind="rejoin", worker=1),
    ]
    replicas = [
        Replica(i, model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                block_size=8)
        for i in range(N_REPLICAS)
    ]
    fe = Frontend(
        replicas, SimplifiedDelayModel(lambda_y=2.0),
        cost_per_replica=0.001, events=events,
        deadline=0.5, retry_budget=3,
    )
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    print(f"dispatching {len(gids)} requests over {N_REPLICAS} replicas "
          f"with chaos: fail@12, slow@40, rejoin@90 ...")
    out = fe.run()

    s = fe.summary()
    print(f"\ncompleted={s['completed']} dropped={s['dropped']} "
          f"retries={s['retries']} cancelled_copies={s['cancelled_copies']} "
          f"p99={s['p99_latency']:.4f}vs")
    slow = fe.router._slowdowns()
    print("router slowdown estimates:",
          np.array2string(slow, precision=2))

    assert s["dropped"] == 0, "chaos must not drop requests"
    streams = [out[g].tokens for g in gids]
    assert streams == refs, "streams must be byte-identical to offline"
    # The slowed replica's telemetry reflects what the router observed.
    assert slow[2] >= slow[0], "slow replica should not price first"
    print("\nOK: zero drops, byte-identical streams under fail/slow/rejoin")


if __name__ == "__main__":
    main()
