"""Chaos demo: a 3-replica serving plane losing a node mid-saturation.

Timeline (one hedged-dispatch run, 3 engine replicas x 2 slots, paged
KV, deterministic virtual time):

  step 12 — replica 1 FAILS with requests in flight. Hedge copies on
            the surviving replicas cover most of them; any request
            whose only copy died requeues from its longest emitted
            prefix (greedy decode is deterministic, so every partial is
            a prefix of the same stream). The router marks the replica
            out and re-prices dispatch from the 2-node fleet.
  step 40 — replica 2 turns SLOW (6x). Nothing is told to the router —
            it just starts seeing slower completions and censored
            hedge losers, and the EWMA telemetry re-prices it toward
            the back of the dispatch order.
  step 90 — replica 1 REJOINS healthy at the fleet's time frontier.
            Its telemetry history is reset: it prices at the neutral
            prior and its first real completion seeds its estimate
            directly (no crawl-up from zero).

The demo asserts the plane's two hard guarantees, the same gates CI's
serve-chaos job enforces via benchmarks/perf_replicas.py:

  * ZERO dropped requests — every submission completes despite the
    failure;
  * BYTE-IDENTICAL tokens — each request's stream equals a per-request
    offline greedy decode, fault or no fault.

Reporting goes through ``repro.obs``: every line printed is the echo of
a structured ``StructuredLog`` record (the assertions below read the
records, not the text), and the whole run is traced — pass ``--trace
PATH`` to export the Chrome/Perfetto timeline, ``--log PATH`` for the
record stream as JSON.

    PYTHONPATH=src python examples/elastic_serving.py [--trace PATH] [--log PATH]
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import SimplifiedDelayModel
from repro.models import build_model
from repro.obs import Observability, validate_trace
from repro.runtime.faults import FaultEvent
from repro.serve import Frontend, Replica, generate_offline

MAX_LEN = 64
N_REPLICAS = 3
N_SLOTS = 2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", type=str, default=None, metavar="PATH",
                    help="export the run's Chrome trace JSON")
    ap.add_argument("--log", type=str, default=None, metavar="PATH",
                    help="export the structured record stream as JSON")
    args = ap.parse_args()

    obs = Observability(log_echo=True)
    log = obs.log

    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(5)
    reqs = []
    for i in range(10):
        p = int(rng.integers(4, 16))
        m = int(rng.integers(6, 14))
        prompt = rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
        reqs.append((prompt, m, i * 0.002))

    log.emit("reference_decode", requests=len(reqs),
             note="offline greedy oracle for byte-identity")
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]

    events = [
        FaultEvent(step=12, kind="fail", worker=1),
        FaultEvent(step=40, kind="slow", worker=2, factor=6.0),
        FaultEvent(step=90, kind="rejoin", worker=1),
    ]
    replicas = [
        Replica(i, model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                block_size=8, obs=obs)
        for i in range(N_REPLICAS)
    ]
    fe = Frontend(
        replicas, SimplifiedDelayModel(lambda_y=2.0),
        cost_per_replica=0.001, events=events,
        deadline=0.5, retry_budget=3, obs=obs,
    )
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    log.emit("dispatch_begin", requests=len(gids), replicas=N_REPLICAS,
             chaos="fail@12,slow@40,rejoin@90")
    out = fe.run()

    s = fe.summary()
    log.emit("plane_summary", t=fe._frontier(),
             completed=int(s["completed"]), dropped=int(s["dropped"]),
             retries=int(s["retries"]),
             cancelled_copies=int(s["cancelled_copies"]),
             p99_latency=float(s["p99_latency"]))
    slow = fe.router._slowdowns()
    log.emit("router_slowdowns",
             estimates=[round(float(x), 2) for x in slow])

    # Assertions read the records, not the printed text.
    summary = log.last("plane_summary").fields
    assert summary["dropped"] == 0, "chaos must not drop requests"
    streams = [out[g].tokens for g in gids]
    assert streams == refs, "streams must be byte-identical to offline"
    # The slowed replica's telemetry reflects what the router observed.
    assert slow[2] >= slow[0], "slow replica should not price first"

    errors = validate_trace(obs.tracer.events)
    assert not errors, f"trace invariant violations: {errors[:5]}"
    assert not obs.tracer.open_spans, "spans leaked across chaos"
    log.emit("verdict", ok=True, trace_events=len(obs.tracer.events),
             note="zero drops, byte-identical streams, valid trace "
                  "under fail/slow/rejoin")

    if args.trace:
        obs.tracer.export(args.trace)
        log.emit("artifact", artifact="trace", path=args.trace)
    if args.log:
        log.export(args.log)


if __name__ == "__main__":
    main()
