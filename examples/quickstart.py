"""Quickstart: the paper in ~60 seconds on CPU.

Reproduces the core claims of Egger, Kas Hanna & Bitar (2023):
adaptive-(k, beta) distributed SGD vs the adaptive-k baseline [39] on the
paper's linear-regression setting (n=20 workers, v=400 samples,
lambda_y=1, x=0.01, beta grid {0.2..1}, k <= 10).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    LinregProblem,
    SGDHyperParams,
    SimplifiedDelayModel,
    StrategyConfig,
    evaluate_schedule,
    simulate,
)

GRID = (0.2, 0.4, 0.6, 0.8, 1.0)


def main():
    print(__doc__)
    problem = LinregProblem.generate(v=400, d=10, n_workers=20, seed=1)
    model = SimplifiedDelayModel(lambda_y=1.0, x=0.01)

    # --- analytic schedules (Thm. 2 + Cor. 4) ---------------------------
    lam = np.linalg.eigvalsh(2.0 * problem.X.T @ problem.X / problem.v)
    c = float(2.0 * lam.min())
    fl1 = 0.1846 * problem.eta / 9.284e-6
    hp = SGDHyperParams(
        eta=problem.eta, L=2.0,
        sigma_grad2=fl1 * 2 * c * problem.s / (problem.eta * 2.0),
        c=c, s=problem.s,
    )
    e0 = problem.gap(np.zeros(problem.d))
    res = {}
    for strat in ("adaptive_kbeta", "adaptive_k"):
        cfg = StrategyConfig(strat, n=20, s=20, k_max=10, beta_grid=GRID)
        res[strat] = evaluate_schedule(cfg, model, hp, e0=e0, target=2e-2)
    ours, ak = res["adaptive_kbeta"], res["adaptive_k"]
    print("analytic schedule (paper's theory):")
    print(f"  runtime ratio ours/adaptive-k : {ours.runtime / ak.runtime:.3f}  (paper: ~0.5)")
    print(f"  computation reduction         : {1 - ours.comp_cost / ak.comp_cost:.1%}  (paper: 59.9%)")
    print(f"  communication overhead        : {ours.comm_cost / ak.comm_cost - 1:.1%}  (paper: 15.7%)")
    print("\n  ours stage path:",
          " -> ".join(f"(k={s.k},b={s.beta:.1f})" for s in ours.stages[:8]),
          "...")

    # --- one live simulated run per strategy -----------------------------
    print("\nevent-driven simulation (single seed, stationarity diagnostics):")
    for strat in ("adaptive_kbeta", "adaptive_k"):
        cfg = StrategyConfig(strat, n=20, s=20, k_max=10, beta_grid=GRID)
        r = simulate(problem, cfg, model, seed=0, max_iters=20_000,
                     target_gap=2e-2, eval_every=10)
        print(f"  {strat:15s}: T(gap<=2e-2) = {r.time_to_gap(2e-2):8.1f}  "
              f"stages: {len(r.stage_log)}  final (k={r.stage_log[-1][1].k}, "
              f"beta={r.stage_log[-1][1].beta:.1f})")


if __name__ == "__main__":
    main()
