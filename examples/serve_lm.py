"""Serving demo: continuous-batching engine over the slot-pooled caches.

Submits a stream of staggered requests to ``repro.serve.ServeEngine``,
which admits each one with the real batched cache-writing prefill
(``model.prefill_with_cache`` via ``make_slot_prefill_step`` — one
projection for the whole prompt, not a token-by-token loop) and decodes
all live slots in a single fixed-shape jit call per tick. Works for
every registered causal arch family (attention KV caches, MLA latent
caches, SSM/xLSTM recurrent states).

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm --tokens 32
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split long prompts into chunks this size "
                         "(bounds how long one admission stalls decoding)")
    ap.add_argument("--paged", action="store_true",
                    help="page the KV cache into a block arena with "
                         "admit-by-budget (DESIGN.md §11); greedy tokens "
                         "are byte-identical to the contiguous pool")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged mode: cache rows per block")
    ap.add_argument("--speculative", action="store_true",
                    help="attach a draft model for draft-then-verify "
                         "decoding (DESIGN.md §12); greedy tokens are "
                         "byte-identical, throughput is the only change")
    ap.add_argument("--draft", type=str, default=None, metavar="CFG",
                    help="draft arch (default: the target arch with "
                         "freshly initialized params — a deliberately "
                         "weak draft; watch the controller back off)")
    ap.add_argument("--gamma-max", type=int, default=4,
                    help="speculation: max draft tokens per round")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    draft_model = draft_params = None
    if args.speculative:
        draft_cfg = get_config(args.draft).reduced() if args.draft else cfg
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise SystemExit("--draft must share the target's vocabulary")
        draft_model = build_model(draft_cfg)
        draft_params = draft_model.init(jax.random.PRNGKey(1))

    max_len = args.prompt_len + args.tokens + 1
    engine = ServeEngine(
        model, params, n_slots=args.slots, max_len=max_len,
        scheduler=Scheduler(args.slots, prefill_chunk=args.prefill_chunk),
        block_size=args.block_size if args.paged else None,
        draft_model=draft_model, draft_params=draft_params,
        gamma_max=args.gamma_max,
    )

    host_rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(host_rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1))
        prompt = host_rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        ntok = int(host_rng.integers(max(args.tokens // 2, 1), args.tokens + 1))
        engine.submit(prompt, ntok, arrival=i * 1e-3)

    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    s = engine.stats
    mode = f"paged(block={args.block_size})" if args.paged else "contiguous"
    print(f"arch={cfg.name} slots={args.slots} requests={args.requests} "
          f"max_len={max_len} kv={mode}")
    if engine.pool.paged:
        mgr = engine.pool.manager
        print(f"kv arena: {mgr.used_high_water}/{mgr.num_blocks} blocks "
              f"high-water ({engine.pool.kv_bytes_high_water()} B vs "
              f"{engine.pool.kv_bytes_contiguous()} B contiguous)")
    print(f"prefill: {s.prefill_calls} calls / {s.prefill_tokens} tokens; "
          f"decode: {s.decode_ticks} ticks")
    if engine.speculative:
        print(f"speculation: {s.spec_rounds} rounds, {s.draft_ticks} draft "
              f"ticks, {s.spec_accepted} draft tokens accepted "
              f"(p_ewma={engine.spec.p:.3f}, accept hist "
              f"{engine.spec.hist.tolist()})")
    print(f"generated {s.generated_tokens} tokens in {wall:.2f}s wall "
          f"({s.generated_tokens / max(wall, 1e-9):.1f} tok/s on CPU) — "
          f"{s.tokens_per_vsec:.1f} tok/s virtual")
    for rid in sorted(results)[:2]:
        r = results[rid]
        print(f"  req{rid}: prompt={r.prompt_len} new={len(r.tokens)} "
              f"latency={r.latency:.4f}v  {r.tokens[:12]} ...")


if __name__ == "__main__":
    main()
