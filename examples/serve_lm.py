"""Serving demo: batched autoregressive decode with KV/SSM caches.

Runs prefill on a batch of prompts then decodes N tokens per sequence,
exercising the same decode_step the dry-run lowers at 32k/500k. Works for
every registered arch family (attention KV caches, MLA latent caches,
SSM/xLSTM recurrent states).

    PYTHONPATH=src python examples/serve_lm.py --arch xlstm --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.models.layers import init_from_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    B, P, N = args.batch, args.prompt_len, args.tokens
    max_len = P + N + 1
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab_size)
    caches = init_from_specs(rng, model.cache_specs(B, max_len))

    decode = jax.jit(model.decode_step)

    # Prefill by stepping the prompt through the decode path (fills the
    # caches exactly; the batched prefill kernel is the dry-run's job).
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, caches = decode(params, prompts[:, t : t + 1], caches, jnp.int32(t))
    t_prefill = time.time() - t0

    # Greedy decode.
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for t in range(P, P + N):
        logits, caches = decode(params, tok, caches, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} new_tokens={N}")
    print(f"prefill {t_prefill:.2f}s, decode {dt:.2f}s "
          f"({B * N / max(dt, 1e-9):.1f} tok/s on CPU interpret)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {list(map(int, gen[b][:16]))} ...")


if __name__ == "__main__":
    main()
