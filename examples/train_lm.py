"""End-to-end driver: train an LM with the adaptive-(k, beta) controller.

The full production path: synthetic token pipeline -> per-stage
beta-scaled batches -> masked fastest-k aggregation (simulated worker
delays) -> AdamW -> stationarity-diagnostic stage advancement -> async
checkpoints. Identical code path to a TPU run; on CPU use the default
tiny preset (visible learning in ~2 minutes).

    PYTHONPATH=src python examples/train_lm.py                 # tiny, CPU
    PYTHONPATH=src python examples/train_lm.py --preset smollm # ~135M (TPU)
    PYTHONPATH=src python examples/train_lm.py --resume        # restart test
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.core import DiagnosticConfig, SimplifiedDelayModel, StrategyConfig
from repro.data import StagedBatcher, TokenStream
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.runtime.train_loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "smollm"], default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-workers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--checkpoint-dir", type=str, default=None)
    ap.add_argument("--fail-worker-at", type=int, default=None,
                    help="inject a worker failure at this step")
    args = ap.parse_args()

    if args.preset == "smollm":
        cfg = get_config("smollm-135m")
        cfg = dataclasses.replace(cfg, max_seq_len=args.seq_len, remat="none",
                                  dtype="float32", scan_layers=True)
    else:
        cfg = get_config("smollm-135m").reduced(
            n_layers=4, d_model=128, vocab_size=512, max_seq_len=args.seq_len
        )
    model = build_model(cfg)
    optimizer = get_optimizer("adamw", weight_decay=0.01)

    n = args.n_workers
    strategy = StrategyConfig(
        "adaptive_kbeta",
        n=n,
        s=args.global_batch // n,
        k_max=n // 2,
        beta_grid=(0.25, 0.5, 0.75, 1.0),
        diagnostic=DiagnosticConfig(kind="loss", rel_tol=0.02, min_iters=10,
                                    consecutive=3),
    )
    delay_model = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    batcher = StagedBatcher(
        TokenStream(cfg.vocab_size, seed=0),
        n_workers=n,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
    )
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps,
        lr=3e-4,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=100,
        log_every=20,
        fail_worker_at=args.fail_worker_at,
    )
    out = train(model, optimizer, strategy, delay_model, batcher, loop_cfg)
    hist = out["history"]
    print(f"\nfinal loss {hist[-1]['loss']:.4f} (start {hist[0]['loss']:.4f})")
    print(f"stage path: {[(h['k'], h['beta']) for h in hist if 'switched_to' in h]}")
    print(f"compiled step shapes (one per beta): {out['compiled_shapes']}")
    print(f"simulated wall-clock: {out['sim_time']:.1f}")


if __name__ == "__main__":
    main()
