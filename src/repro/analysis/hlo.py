"""HLO text analysis: collective byte accounting for the roofline model.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled module text and sum operand sizes of every communication op:
all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute.

Byte convention (per §Roofline): for each collective op we count the
bytes of its OUTPUT buffer(s) on one device — the amount of data that
must cross links per device per step, up to the (regime-dependent,
O(1)-ish) algorithm factor which we fold into the achievable-bandwidth
constant. This makes deltas between variants directly comparable, which
is what the perf loop optimizes.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes_from_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  "bf16[16,1024,512]{2,1,0} all-gather(...)" — possibly inside a tuple.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum per-device output bytes of each collective kind. '-done' ops are
    skipped so async (start/done) pairs are not double counted."""
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        tuple_shapes, single_shape, kind = m.group(1), m.group(2), m.group(3)
        # Skip the -done halves of async pairs.
        tail = hlo_text[m.end() - 1 : m.end() + 1]
        full_match = m.group(0)
        if "-done(" in full_match:
            continue
        text = tuple_shapes if tuple_shapes is not None else single_shape
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(text or "")
        )
        out[kind] += nbytes
        counts[f"{kind}_count"] += 1
    result = dict(out)
    result.update(counts)
    return result
