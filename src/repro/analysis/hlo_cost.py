"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE, so any
scan-over-layers program under-reports FLOPs/bytes/collectives by ~the
layer count. This pass parses the compiled HLO text, builds the call
graph (ENTRY -> fusions/whiles/calls), reads each while's
``known_trip_count`` backend config, and accumulates:

  * flops            — 2*prod(out)*prod(contracting dims) per dot,
                       convolutions approximated from kernel shape;
  * hbm_bytes        — sum of operand+output bytes of top-level ops
                       (fusion internals excluded: fusions are the
                       materialization boundaries);
  * collective bytes — per-kind output bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
                       (async -start counted, -done skipped);

all multiplied by the product of enclosing loop trip counts. Everything
is PER DEVICE (the input is the SPMD-partitioned per-device module).

Validated against hand-computed matmul/scan examples in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
# op line: "%name = TYPE op-kind(operands...), attrs"  (ROOT optional)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_METADATA = re.compile(r'op_name="([^"]*)"')
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")
_FEATURE_GROUPS = re.compile(r"feature_group_count=(\d+)")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    out_text: str
    rest: str  # operand list + attrs


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    shapes: Dict[str, str]  # op name -> output type text


def _parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = _Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, out_text, kind, rest = m.groups()
            cur.ops.append(_Op(name, kind, out_text, rest))
            cur.shapes[name] = out_text
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    # collective bytes attributed to the originating jax op (metadata):
    collective_by_source: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    unknown_trip_counts: int = 0

    def top_collective_sources(self, n: int = 12):
        return sorted(
            self.collective_by_source.items(), key=lambda kv: -kv[1]
        )[:n]

    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "collective_bytes_total": self.total_collective_bytes(),
            "unknown_trip_counts": self.unknown_trip_counts,
        }


_CONTROL_KINDS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_shapes = _parse_shapes(op.out_text)
    out_elems = 0
    for _, shape in out_shapes:
        n = 1
        for d in shape:
            n *= d
        out_elems += n
    m = _CONTRACT.search(op.rest)
    contract = 1
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        # lhs operand: first %name inside the parens region.
        names = _OPERAND_NAME.findall(op.rest)
        if names:
            lhs_text = comp.shapes.get(names[0])
            if lhs_text:
                shapes = _parse_shapes(lhs_text)
                if shapes:
                    lhs_shape = shapes[0][1]
                    for d in dims:
                        if d < len(lhs_shape):
                            contract *= lhs_shape[d]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out_shapes = _parse_shapes(op.out_text)
    out_elems = sum(
        int(__import__("math").prod(s or (1,))) for _, s in out_shapes
    )
    names = _OPERAND_NAME.findall(op.rest)
    kernel_elems = 1
    if len(names) >= 2:
        ker_text = comp.shapes.get(names[1])
        if ker_text:
            shapes = _parse_shapes(ker_text)
            if shapes:
                k = 1
                for d in shapes[0][1]:
                    k *= d
                kernel_elems = k
    groups = 1
    g = _FEATURE_GROUPS.search(op.rest)
    if g:
        groups = int(g.group(1))
    # per output element: kernel_elems / (out_channels * groups)-ish; use a
    # safe approximation: 2 * out * kernel / out_channels… convs here are
    # tiny depthwise — approximate 2 * out_elems * kernel_spatial.
    out_ch = 1
    if out_shapes and out_shapes[0][1]:
        out_ch = out_shapes[0][1][-1]
    per_out = max(kernel_elems // max(out_ch, 1), 1) if groups > 1 else kernel_elems // max(out_ch, 1)
    return 2.0 * out_elems * max(per_out, 1)


def _op_bytes(op: _Op, comp: _Computation) -> int:
    # In-place buffer updates move only the update slice, not the buffer.
    if op.kind in ("dynamic-update-slice",):
        names = _OPERAND_NAME.findall(op.rest)
        if len(names) >= 2:
            upd = comp.shapes.get(names[1])
            if upd:
                return 2 * _nbytes(_parse_shapes(upd))  # read + write
    if op.kind in ("dynamic-slice",):
        return 2 * _nbytes(_parse_shapes(op.out_text))
    total = _nbytes(_parse_shapes(op.out_text))
    paren = op.rest
    # operands: only up to the closing paren; attrs may contain shapes too —
    # conservative: look up operand names in the symbol table instead.
    depth = 1
    end = 0
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operand_region = paren[:end]
    for name in _OPERAND_NAME.findall(operand_region):
        t = comp.shapes.get(name)
        if t:
            total += _nbytes(_parse_shapes(t))
    return total


def analyze_hlo(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)
    cost = HloCost()

    entry = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HEADER.match(s)
            if m:
                entry = m.group(1)
                break
    if entry is None or entry not in comps:
        raise ValueError("could not locate ENTRY computation")

    def visit(comp_name: str, mult: float, *, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                m = _TRIP.search(op.rest)
                if m:
                    trip = int(m.group(1))
                else:
                    trip = 1
                    cost.unknown_trip_counts += 1
                cb = _COND_BODY.search(op.rest)
                if cb:
                    visit(cb.group(1), mult * trip, count_bytes=count_bytes)
                    visit(cb.group(2), mult * trip, count_bytes=count_bytes)
                continue
            if kind in ("fusion", "call", "async-start"):
                m = _CALLS.search(op.rest)
                if m:
                    # fusion internals: flops yes, bytes no (registers).
                    visit(m.group(1), mult, count_bytes=False)
                if count_bytes and kind == "fusion":
                    cost.hbm_bytes += mult * _op_bytes(op, comp)
                continue
            if kind == "conditional":
                for name in _OPERAND_NAME.findall(op.rest):
                    if name in comps and name != comp.name:
                        visit(name, mult, count_bytes=count_bytes)
                continue
            if kind == "dot":
                cost.flops += mult * _dot_flops(op, comp)
                if count_bytes:
                    cost.hbm_bytes += mult * _op_bytes(op, comp)
                continue
            if kind == "convolution":
                cost.flops += mult * _conv_flops(op, comp)
                if count_bytes:
                    cost.hbm_bytes += mult * _op_bytes(op, comp)
                continue
            base = kind.replace("-start", "")
            if base in COLLECTIVES:
                if kind.endswith("-done"):
                    continue
                nbytes = _nbytes(_parse_shapes(op.out_text))
                cost.collective_bytes[base] += mult * nbytes
                cost.collective_counts[base] += mult
                md = _METADATA.search(op.rest)
                src = md.group(1) if md else "(unattributed)"
                # Collapse scan indices/uniquifiers for readable grouping.
                src = re.sub(r"\[\d+\]", "", src)
                cost.collective_by_source[f"{base}: {src}"] += mult * nbytes
                if count_bytes:
                    cost.hbm_bytes += mult * _op_bytes(op, comp)
                continue
            if kind in _CONTROL_KINDS:
                continue
            if count_bytes:
                cost.hbm_bytes += mult * _op_bytes(op, comp)

    visit(entry, 1.0, count_bytes=True)
    return cost
