"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python -m repro.analysis.report

Rewrites everything between the AUTOGEN markers in EXPERIMENTS.md
(§Dry-run table, §Roofline table) from artifacts/dryrun/*.json. The
narrative sections (§Paper, §Perf) are maintained by hand.
"""

from __future__ import annotations

import json
from pathlib import Path

from .roofline import analyze_artifact

ROOT = Path(__file__).resolve().parents[3]
ARTIFACTS = ROOT / "artifacts" / "dryrun"
EXPERIMENTS = ROOT / "EXPERIMENTS.md"

BEGIN = "<!-- AUTOGEN:{} BEGIN -->"
END = "<!-- AUTOGEN:{} END -->"


def _load(variant="baseline"):
    arts = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        a = json.loads(f.read_text())
        if a.get("variant", "baseline") == variant or a.get("status") == "SKIP":
            arts.append(a)
    return arts


def dryrun_table() -> str:
    arts = _load()
    lines = [
        "| arch | shape | mesh | status | GiB/dev | HLO TFLOPs/dev | "
        "HBM GB/dev | collective GiB/dev | accum |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    seen = set()
    for a in arts:
        key = a["cell"]
        if key in seen:
            continue
        seen.add(key)
        parts = key.split("__")
        arch, shape, mesh = parts[0], parts[1], parts[2]
        if a["status"] == "SKIP":
            lines.append(
                f"| {arch} | {shape} | {mesh} | SKIP | — | — | — | — | — |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {mesh} | OK | {mem:.1f} | {fl:.2f} | "
            "{hbm:.1f} | {coll:.2f} | {acc} |".format(
                arch=arch, shape=shape, mesh=mesh,
                mem=a["memory"]["peak_bytes"] / 2**30,
                fl=a["cost"]["flops"] / 1e12,
                hbm=a["cost"]["hbm_bytes"] / 1e9,
                coll=sum(a["collectives"].values()) / 2**30,
                acc=a.get("accum_steps", 1),
            )
        )
    return "\n".join(lines)


def roofline_table(mesh="pod16x16") -> str:
    arts = [a for a in _load() if a.get("status") == "OK" and a["mesh"] == mesh]
    lines = [
        "| arch × shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful % | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in arts:
        r = analyze_artifact(a)
        lines.append(
            f"| {r.arch} × {r.shape} | {r.compute_s:.3f} | {r.memory_s:.3f} | "
            f"{r.collective_s:.3f} | **{r.dominant}** | {r.model_flops:.2e} | "
            f"{r.useful_ratio:.1%} | {r.note} |"
        )
    return "\n".join(lines)


def inject(text: str, tag: str, content: str) -> str:
    b, e = BEGIN.format(tag), END.format(tag)
    if b not in text:
        return text + f"\n\n{b}\n{content}\n{e}\n"
    pre, rest = text.split(b, 1)
    _, post = rest.split(e, 1)
    return pre + b + "\n" + content + "\n" + e + post


def main():
    text = EXPERIMENTS.read_text() if EXPERIMENTS.exists() else "# EXPERIMENTS\n"
    text = inject(text, "dryrun", dryrun_table())
    text = inject(text, "roofline_pod1", roofline_table("pod16x16"))
    text = inject(text, "roofline_pod2", roofline_table("pod2x16x16"))
    EXPERIMENTS.write_text(text)
    print(f"wrote {EXPERIMENTS}")


if __name__ == "__main__":
    main()
