"""Three-term roofline model from dry-run artifacts (TPU v5e constants).

    compute    = HLO_FLOPs_per_device   / 197e12   [bf16 TFLOP/s]
    memory     = HLO_bytes_per_device   / 819e9    [HBM GB/s]
    collective = coll_bytes_per_device  / 50e9     [ICI GB/s/link]

All inputs are per-device (the dry-run artifacts store the loop-aware
per-device analysis of the SPMD module). The bottleneck is the max term;
the roofline fraction we report for the perf loop is

    fraction = max(compute_useful, memory, collective) / sum-estimate,

but more usefully we track MODEL_FLOPS / (global HLO FLOPs): how much of
the executed compute is 'algorithmically necessary' (6*N_active*D for
training, 2*N_active*D for prefill, 2*N_active*B for decode) — remat
recompute, attention replication, and capacity padding all show up here.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["RooflineRow", "analyze_artifact", "load_rows", "format_table"]

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link


@dataclasses.dataclass
class RooflineRow:
    cell: str
    arch: str
    shape: str
    kind: str
    mesh: str
    variant: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    mem_gib: float
    note: str

    def step_time_bound(self) -> float:
        """Lower bound on step time assuming perfect overlap of the
        three engines: the max term."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def model_flops_for(art: dict) -> float:
    """Algorithmically-necessary FLOPs for this cell (global, per step)."""
    n_active = art["params_active"]
    S, B = art["seq_len"], art["global_batch"]
    if art["kind"] == "train":
        return 6.0 * n_active * S * B
    if art["kind"] == "prefill":
        return 2.0 * n_active * S * B
    # decode: one token per sequence.
    return 2.0 * n_active * B


def _note(art: dict, dominant: str, useful: float) -> str:
    if dominant == "collective":
        return (
            "collective-bound: FSDP weight all-gathers dominate; cut by "
            "re-using gathered weights across accumulation microbatches or "
            "switching the FSDP axis to pure DP for this size"
        )
    if dominant == "memory":
        return (
            "HBM-bound: fuse normalization/rope (Pallas), keep attention "
            "tiles resident (flash kernel), and drop fp32 intermediates"
        )
    if useful < 0.25:
        return (
            "compute-bound but <25% useful: remat recompute and/or "
            "attention replicated over the model axis (kv heads not "
            "divisible by 16) — reshard attention or use selective remat"
        )
    return "compute-bound: push MXU utilization (layout, fusion, bf16 paths)"


def analyze_artifact(art: dict) -> Optional[RooflineRow]:
    if art.get("status") != "OK":
        return None
    flops_dev = art["cost"]["flops"]
    hbm_dev = art["cost"]["hbm_bytes"]
    coll_dev = sum(art["collectives"].values())
    n = art["n_devices"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hbm_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_for(art)
    hlo_global = flops_dev * n
    useful = mf / hlo_global if hlo_global else 0.0
    return RooflineRow(
        cell=art["cell"],
        arch=art["arch"],
        shape=art["shape"],
        kind=art["kind"],
        mesh=art["mesh"],
        variant=art["variant"],
        n_devices=n,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=useful,
        mem_gib=art["memory"]["peak_bytes"] / 2**30,
        note=_note(art, dominant, useful),
    )


def load_rows(
    artifacts_dir: Path, mesh: Optional[str] = None, variant: str = "baseline"
) -> List[RooflineRow]:
    rows = []
    for f in sorted(Path(artifacts_dir).glob("*.json")):
        art = json.loads(f.read_text())
        if art.get("status") != "OK":
            continue
        if mesh and art.get("mesh") != mesh:
            continue
        if variant and art.get("variant") != variant:
            continue
        row = analyze_artifact(art)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | GiB/dev |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} × {r.shape} ({r.mesh}) | {r.compute_s:.3f} | "
            f"{r.memory_s:.3f} | {r.collective_s:.3f} | **{r.dominant}** | "
            f"{r.model_flops:.2e} | {r.useful_ratio:.1%} | {r.mem_gib:.1f} |"
        )
    return hdr + "\n".join(lines)
