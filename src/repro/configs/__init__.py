"""Config registry: assigned architectures + shape presets."""

from .base import MLAConfig, ModelConfig, MoEConfig, SSMConfig, XLSTMConfig
from .registry import ALIASES, ARCHS, get_config, list_archs
from .shapes import SHAPES, ShapeSpec, cell_status

__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig", "XLSTMConfig",
    "ALIASES", "ARCHS", "get_config", "list_archs",
    "SHAPES", "ShapeSpec", "cell_status",
]
