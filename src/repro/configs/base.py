"""Config system: one frozen dataclass tree per architecture.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
smoke variants are derived with ``ModelConfig.reduced()``. Shape presets
(train_4k / prefill_32k / decode_32k / long_500k) live in ``shapes.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "MLAConfig",
    "XLSTMConfig",
    "ModelConfig",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden size
    n_shared_experts: int = 0  # DeepSeek-style always-on shared expert(s)
    first_k_dense: int = 0     # leading layers that stay dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001  # load-balance loss weight
    d_shared: int = 0          # hidden size of the shared expert (0 = d_expert)
    dispatch: str = "data"     # dispatched-token sharding: data | model | grouped
    # Inference mode: capacity = the full token count, so no token is ever
    # dropped. Capacity-dropped routing makes logits depend on how many
    # tokens share one forward call — a training throughput concession that
    # breaks chunked-prefill/prefix-sharing byte-identity (a 27-token
    # prompt prefilled as 8+8+8+3 drops different tokens than one 27-token
    # call). Dropless routing is token-local and therefore chunk-invariant.
    dropless: bool = False


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64         # SSD head dim (nheads = expand*d_model/head_dim)
    n_groups: int = 1
    chunk: int = 128           # SSD chunk length


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block mix: mLSTM (matrix memory) + sLSTM (scalar memory)."""

    slstm_every: int = 8       # 1 sLSTM per this many blocks (paper's [7:1])
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333
    conv1d_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | encoder | xlstm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    causal: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    logit_scale: float = 1.0
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "silu"          # silu | gelu
    glu: bool = True           # gated FFN (SwiGLU/GeGLU); False = plain MLP
    tie_embeddings: bool = False
    parallel_block: bool = False  # attention and FFN in parallel (command-r)

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    attn_every: int = 0        # hybrid (zamba2): shared attn block period; 0 = off
    mtp: bool = False          # DeepSeek multi-token-prediction aux head
    mla_absorb: bool = False   # decode MLA in latent space (perf variant)

    input_kind: str = "tokens"  # tokens | frames (precomputed modality embeddings)
    max_seq_len: int = 8192

    # runtime knobs (overridable per experiment)
    dtype: str = "bfloat16"
    remat: str = "full"        # none | full | selective
    scan_layers: bool = True
    attn_chunk: int = 1024     # memory-efficient attention KV chunk
    use_pallas: bool = False   # route hot paths through Pallas kernels

    def __post_init__(self) -> None:
        if self.family not in (
            "dense", "moe", "ssm", "hybrid", "encoder", "xlstm", "vlm", "audio"
        ):
            raise ValueError(f"unknown family {self.family}")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    # -- derived -------------------------------------------------------------
    @property
    def is_encoder(self) -> bool:
        return self.family in ("encoder", "audio") or not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k shape? (SSM/hybrid/linear recurrent)"""
        return self.family in ("ssm", "hybrid", "xlstm")

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            max_seq_len=256,
            dtype="float32",
            remat="none",
            scan_layers=False,
            attn_chunk=64,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                n_experts=8,
                top_k=2,
                d_expert=64,
                first_k_dense=min(self.moe.first_k_dense, 1),
                d_shared=64 if self.moe.n_shared_experts else 0,
            )
        if self.ssm is not None:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=32
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                q_lora_rank=64,
                kv_lora_rank=32,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
        if self.attn_every:
            small["attn_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)

    # -- accounting ------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params_analytic  # lazy, avoids cycle

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)
