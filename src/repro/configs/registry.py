"""The 10 assigned architectures, exactly as specified (sources in brackets).

Every entry is selectable via ``--arch <id>`` in the launchers and is
exercised by the dry-run at all applicable shapes.
"""

from __future__ import annotations

from typing import Dict

from .base import MLAConfig, ModelConfig, MoEConfig, SSMConfig, XLSTMConfig

__all__ = ["ARCHS", "get_config", "list_archs"]


def _zamba2_1p2b() -> ModelConfig:
    # [hybrid] 38L d_model=2048 32H d_ff=8192 vocab=32000 ssm_state=64
    # Mamba2 backbone + shared attention block [arXiv:2411.15242]
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,      # shared block runs at width 2*d_model / 32 heads
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        attn_every=6,
        tie_embeddings=True,
        rope_theta=10000.0,
    )


def _hubert_xlarge() -> ModelConfig:
    # [audio] 48L d_model=1280 16H d_ff=5120 vocab=504 encoder-only
    # [arXiv:2106.07447]; frontend is a stub: precomputed frame embeddings.
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        norm="layernorm",
        act="gelu",
        glu=False,
        rope_theta=0.0,    # conv positional embedding instead
        input_kind="frames",
        tie_embeddings=True,  # head = output embedding table
    )


def _qwen3_moe_30b() -> ModelConfig:
    # [moe] 48L d_model=2048 32H (kv=4) d_ff(expert)=768 vocab=151936
    # 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]; head_dim=128, qk-norm.
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
    )


def _deepseek_v3() -> ModelConfig:
    # [moe] 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280
    # MLA, 1 shared + 256 routed top-8, first 3 dense (d_ff 18432), MTP
    # [arXiv:2412.19437]
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=192,      # qk_nope(128) + qk_rope(64)
        d_ff=18432,        # dense layers
        vocab_size=129280,
        rope_theta=10000.0,
        moe=MoEConfig(
            n_experts=256,
            top_k=8,
            d_expert=2048,
            n_shared_experts=1,
            first_k_dense=3,
        ),
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        mla_absorb=True,   # latent-space decode = DeepSeek's own deployment
        mtp=True,
    )


def _llama32_1b() -> ModelConfig:
    # [dense] 16L d_model=2048 32H (kv=8) d_ff=8192 vocab=128256
    # [hf:meta-llama/Llama-3.2-1B]
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
        tie_embeddings=True,
    )


def _qwen25_3b() -> ModelConfig:
    # [dense] 36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936, QKV bias
    # [hf:Qwen/Qwen2.5-3B]
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
    )


def _command_r_35b() -> ModelConfig:
    # [dense] 40L d_model=8192 64H (kv=8) d_ff=22528 vocab=256000
    # parallel attn+FFN block, LayerNorm, logit scaling, tied embeddings
    # [hf:CohereForAI/c4ai-command-r-v01]
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab_size=256000,
        norm="layernorm",
        parallel_block=True,
        logit_scale=0.0625,
        rope_theta=8000000.0,
        tie_embeddings=True,
    )


def _smollm_135m() -> ModelConfig:
    # [dense] 30L d_model=576 9H (kv=3) d_ff=1536 vocab=49152
    # [hf:HuggingFaceTB/SmolLM-135M]
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        rope_theta=10000.0,
        tie_embeddings=True,
    )


def _chameleon_34b() -> ModelConfig:
    # [vlm] 48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536
    # early-fusion VQ image tokens share the text vocab; qk-norm
    # [arXiv:2405.09818]. Frontend stub: fused token ids.
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        qk_norm=True,
        rope_theta=10000.0,
    )


def _xlstm_125m() -> ModelConfig:
    # [ssm] 12L d_model=768 4H d_ff=0 vocab=50304, sLSTM + mLSTM blocks
    # [arXiv:2405.04517] — xLSTM[7:1]-style mix; no separate FFN (d_ff=0,
    # the blocks carry their own up/down projections).
    return ModelConfig(
        name="xlstm-125m",
        family="xlstm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        head_dim=192,
        d_ff=0,
        vocab_size=50304,
        rope_theta=0.0,
        tie_embeddings=True,
        xlstm=XLSTMConfig(slstm_every=6),
    )


ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _zamba2_1p2b(),
        _hubert_xlarge(),
        _qwen3_moe_30b(),
        _deepseek_v3(),
        _llama32_1b(),
        _qwen25_3b(),
        _command_r_35b(),
        _smollm_135m(),
        _chameleon_34b(),
        _xlstm_125m(),
    ]
}

# Short aliases for --arch.
ALIASES = {
    "zamba2": "zamba2-1.2b",
    "hubert": "hubert-xlarge",
    "qwen3-moe": "qwen3-moe-30b-a3b",
    "deepseek-v3": "deepseek-v3-671b",
    "llama3.2": "llama3.2-1b",
    "qwen2.5": "qwen2.5-3b",
    "command-r": "command-r-35b",
    "smollm": "smollm-135m",
    "chameleon": "chameleon-34b",
    "xlstm": "xlstm-125m",
}


def get_config(name: str) -> ModelConfig:
    key = ALIASES.get(name, name)
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]


def list_archs():
    return sorted(ARCHS)
