"""Assigned input-shape presets and per-(arch, shape) applicability.

Shapes are (seq_len, global_batch) with a step kind:
  train_4k    : train_step    seq 4096,   batch 256
  prefill_32k : prefill_step  seq 32768,  batch 32
  decode_32k  : decode_step   1 new token, KV/state cache of 32768, batch 128
  long_500k   : decode_step   1 new token, cache of 524288, batch 1
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .base import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "cell_status"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_status(cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    spec = SHAPES[shape]
    if cfg.is_encoder and spec.kind == "decode":
        return "encoder-only arch has no autoregressive decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return (
            "pure full-attention arch; long_500k requires sub-quadratic "
            "attention (assignment directive; see DESIGN.md §6)"
        )
    return None
