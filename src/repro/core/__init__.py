"""The paper's contribution: adaptive-(k, beta) straggler-tolerant SGD.

Public surface:
  delay models (Def. 1/2)        -> repro.core.delay_models
  order statistics (Prop1/Thm5)  -> repro.core.order_stats
  error model (Eq. 1/10)         -> repro.core.error_model
  switching times (Thm. 2)       -> repro.core.switching
  optimal load beta* (Thm3/Cor4) -> repro.core.beta_opt
  strategies + run-time control  -> repro.core.controller
  stationarity diagnostics       -> repro.core.diagnostics
  analytic schedule roll-out     -> repro.core.schedule
  straggler simulation engine    -> repro.core.simulation
  batched (multi-seed) engine    -> repro.core.vector_sim
"""

from .beta_opt import beta_min_for, cor4_beta, numerical_beta, optimal_beta
from .controller import Controller, Stage, StrategyConfig, next_stage, stage_table
from .delay_models import (
    GeneralizedDelayModel,
    SimplifiedDelayModel,
    fit_generalized_mm,
    fit_simplified_mle,
    fit_simplified_mle_censored,
)
from .diagnostics import DiagnosticConfig, DistanceDiagnostic, PflugDiagnostic
from .error_model import SGDHyperParams, error_after, error_floor, time_to_error
from .order_stats import expected_kth, expected_kth_derivative, harmonic_tail
from .schedule import ScheduleResult, StageRecord, evaluate_schedule
from .simulation import LinregProblem, SimResult, simulate
from .switching import gap_at_switch, switching_interval
from .vector_sim import BatchSimResult, simulate_batch

__all__ = [
    "GeneralizedDelayModel",
    "SimplifiedDelayModel",
    "fit_simplified_mle",
    "fit_simplified_mle_censored",
    "fit_generalized_mm",
    "expected_kth",
    "expected_kth_derivative",
    "harmonic_tail",
    "SGDHyperParams",
    "error_floor",
    "error_after",
    "time_to_error",
    "switching_interval",
    "gap_at_switch",
    "beta_min_for",
    "cor4_beta",
    "numerical_beta",
    "optimal_beta",
    "Controller",
    "Stage",
    "StrategyConfig",
    "next_stage",
    "stage_table",
    "DiagnosticConfig",
    "DistanceDiagnostic",
    "PflugDiagnostic",
    "ScheduleResult",
    "StageRecord",
    "evaluate_schedule",
    "LinregProblem",
    "SimResult",
    "simulate",
    "BatchSimResult",
    "simulate_batch",
]
