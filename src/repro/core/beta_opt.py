"""Optimal next-stage load beta_{tau+1} when raising k (Thm. 3 / Cor. 4).

When beta has saturated at 1 and k must grow (k_next > k_cur), the paper
shows the next load should be *reduced* to the maximizer of

    O(beta) = (phi_next - phi_cur)
              / (phi_cur * phi_next * (mu_{k_next:n}(beta) - mu_cur)),

subject to beta in [beta_min, 1], beta a multiple of 1/s, and
phi_next = k_next * beta > phi_cur.

* Under Def. 1 the problem is concave with the closed-form roots of
  Cor. 4 (``cor4_beta``).
* Under Def. 2 we maximize O numerically over the feasible grid using the
  Thm. 5 order statistics (``numerical_beta``) — the paper prescribes a
  numerical solution for this model.
"""

from __future__ import annotations

import math
from typing import Tuple

from .delay_models import GeneralizedDelayModel, SimplifiedDelayModel
from .order_stats import DelayModel, expected_kth, harmonic_tail

__all__ = ["beta_min_for", "cor4_beta", "numerical_beta", "optimal_beta"]


def beta_min_for(k_cur: int, beta_cur: float, k_next: int, s: int) -> float:
    """Smallest feasible next load: beta_min = ceil(k_cur * beta_cur * s / k_next)/s.

    Paper statement uses beta_cur = 1 (k grows only once beta saturates):
    beta_min = ceil(k_cur s / k_next)/s. We keep the general form so the
    controller may raise k early (e.g. after worker loss).
    """
    phi_cur = k_cur * beta_cur
    bmin = math.ceil(phi_cur * s / k_next) / s
    # phi must STRICTLY grow; bump one grid step on exact equality.
    if k_next * bmin <= phi_cur + 1e-12:
        bmin += 1.0 / s
    return min(bmin, 1.0)


def _objective(
    model: DelayModel,
    n: int,
    k_cur: int,
    beta_cur: float,
    k_next: int,
    beta_next: float,
) -> float:
    """O(beta_next) from the proof of Thm. 3 (larger is better)."""
    phi_cur = k_cur * beta_cur
    phi_next = k_next * beta_next
    if phi_next <= phi_cur:
        return -math.inf
    mu_cur = expected_kth(model, n, k_cur, beta_cur)
    mu_next = expected_kth(model, n, k_next, beta_next)
    if mu_next <= mu_cur:
        # Strictly dominating stage; objective unbounded in the bound's
        # terms — treat as maximal preference.
        return math.inf
    return (phi_next - phi_cur) / (phi_cur * phi_next * (mu_next - mu_cur))


def _snap_to_grid(beta: float, s: int, bmin: float) -> float:
    """Round UP to a multiple of 1/s and clip to [bmin, 1] (paper's rule)."""
    b = math.ceil(beta * s - 1e-9) / s
    return max(bmin, min(1.0, b))


def cor4_beta(
    model: SimplifiedDelayModel,
    n: int,
    k_cur: int,
    beta_cur: float,
    k_next: int,
    s: int,
) -> float:
    """Closed-form beta_{tau+1} under Def. 1 (Corollary 4).

    beta_{1,2} = (phi/k_next) * (1 +- sqrt(1 - (k_next/k_cur) * mu'_cur/mu'_next))
    with mu'(beta) = H(n,k)/lambda_y, so the rate lambda_y cancels:
    the discriminant is 1 - (k_next * H(n,k_cur)) / (k_cur * H(n,k_next)).
    """
    if k_next <= k_cur:
        raise ValueError("Cor. 4 applies when k grows")
    phi_cur = k_cur * beta_cur
    disc = 1.0 - (k_next * harmonic_tail(n, k_cur)) / (
        k_cur * harmonic_tail(n, k_next)
    )
    # Concavity proof (Appendix B) guarantees disc in (0, 1).
    disc = max(disc, 0.0)
    root = math.sqrt(disc)
    cands = [
        phi_cur / k_next * (1.0 - root),
        phi_cur / k_next * (1.0 + root),
    ]
    bmin = beta_min_for(k_cur, beta_cur, k_next, s)
    best_b, best_o = 1.0, -math.inf
    for b in cands:
        b_snapped = _snap_to_grid(b, s, bmin)
        o = _objective(model, n, k_cur, beta_cur, k_next, b_snapped)
        # Tie-break toward the smaller beta: lower computation effort.
        if o > best_o or (o == best_o and b_snapped < best_b):
            best_o, best_b = o, b_snapped
    return best_b


def numerical_beta(
    model: DelayModel,
    n: int,
    k_cur: int,
    beta_cur: float,
    k_next: int,
    s: int,
) -> float:
    """Grid maximization of O over feasible multiples of 1/s (Def. 2 path).

    s is at most a few thousand in the paper's regimes; an exact scan of
    the feasible grid is both simpler and safer than golden-section on a
    function whose concavity is only proven for Def. 1.
    """
    bmin = beta_min_for(k_cur, beta_cur, k_next, s)
    best_b, best_o = 1.0, -math.inf
    steps = int(round((1.0 - bmin) * s)) + 1
    for i in range(steps):
        b = min(1.0, bmin + i / s)
        o = _objective(model, n, k_cur, beta_cur, k_next, b)
        if o > best_o + 1e-15:
            best_o, best_b = o, b
    return best_b


def optimal_beta(
    model: DelayModel,
    n: int,
    k_cur: int,
    beta_cur: float,
    k_next: int,
    s: int,
) -> float:
    """Dispatch: closed form for Def. 1, numerical for Def. 2."""
    if isinstance(model, SimplifiedDelayModel):
        return cor4_beta(model, n, k_cur, beta_cur, k_next, s)
    return numerical_beta(model, n, k_cur, beta_cur, k_next, s)
