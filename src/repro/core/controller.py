"""Stage controller: the paper's adaptive-(k, beta) strategy plus baselines.

A *stage* is a pair (k, beta): wait for the k fastest of n workers, each
computing on a fraction beta of its s local samples. The controller owns

  * the stage-advancement rule per strategy:
      - ``naive``          : k = n, beta = 1, single stage  [sync SGD]
      - ``fastest_k``      : fixed (k0, 1), single stage    [32]
      - ``adaptive_k``     : k = 1, 2, ..., k_max at beta=1 [39]
      - ``adaptive_kbeta`` : THE PAPER — grow beta along the grid first;
        when beta saturates, raise k and *drop* beta to the Cor. 4 / Thm. 3
        optimum (closed form under Def. 1, numerical under Def. 2);
  * the stationarity diagnostic that triggers advancement at run time;
  * response-time telemetry and (optionally) online delay-model fitting,
    so beta* can be computed without oracle knowledge of (lambda, x).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .beta_opt import beta_min_for, optimal_beta
from .delay_models import fit_simplified_mle_censored
from .diagnostics import DiagnosticConfig, make_diagnostic
from .order_stats import DelayModel, expected_kth

__all__ = ["StrategyConfig", "Stage", "Controller", "next_stage", "stage_table"]

STRATEGIES = ("naive", "fastest_k", "adaptive_k", "adaptive_kbeta")


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    strategy: str
    n: int                      # total workers
    s: int                      # samples per worker
    k0: int = 1
    beta0: Optional[float] = None   # default: grid minimum for the paper, 1 otherwise
    k_max: Optional[int] = None     # default: n
    k_step: int = 1
    beta_grid: Optional[Sequence[float]] = None  # default: multiples of 1/s
    diagnostic: DiagnosticConfig = dataclasses.field(default_factory=DiagnosticConfig)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")
        if self.beta_grid is not None:
            g = tuple(sorted(self.beta_grid))
            if not g or g[0] <= 0 or g[-1] > 1.0:
                raise ValueError("beta_grid must lie in (0, 1]")
            object.__setattr__(self, "beta_grid", g)

    @property
    def grid(self) -> Tuple[float, ...]:
        if self.beta_grid is not None:
            return tuple(self.beta_grid)
        return tuple((i + 1) / self.s for i in range(self.s))

    @property
    def kmax(self) -> int:
        return self.k_max if self.k_max is not None else self.n

    def initial_stage(self) -> "Stage":
        if self.strategy in ("naive",):
            return Stage(self.n, 1.0)
        if self.strategy == "fastest_k":
            # Fixed (k, beta) throughout — [38]-style baselines may pin a
            # reduced load (e.g. (1, 0.2) in the paper's appendix).
            return Stage(self.k0, self.beta0 if self.beta0 is not None else 1.0)
        if self.strategy == "adaptive_k":
            return Stage(self.k0, 1.0)
        beta0 = self.beta0 if self.beta0 is not None else self.grid[0]
        return Stage(self.k0, beta0)


@dataclasses.dataclass(frozen=True)
class Stage:
    k: int
    beta: float

    @property
    def phi(self) -> float:
        return self.k * self.beta


def _grid_next_above(grid: Sequence[float], value: float) -> Optional[float]:
    for g in grid:
        if g > value + 1e-12:
            return g
    return None


def _grid_ceil(grid: Sequence[float], value: float) -> Optional[float]:
    """Smallest grid point >= value."""
    for g in grid:
        if g >= value - 1e-12:
            return g
    return None


def next_stage(
    cfg: StrategyConfig, cur: Stage, model: Optional[DelayModel]
) -> Optional[Stage]:
    """The stage that follows ``cur`` under ``cfg.strategy`` (None = terminal)."""
    if cfg.strategy in ("naive", "fastest_k"):
        return None

    if cfg.strategy == "adaptive_k":
        k_next = min(cur.k + cfg.k_step, cfg.kmax)
        if k_next == cur.k:
            return None
        return Stage(k_next, 1.0)

    # adaptive_kbeta — the paper's scheme.
    grid = cfg.grid
    if cur.beta < 1.0 - 1e-12:
        b_next = _grid_next_above(grid, cur.beta)
        if b_next is not None:
            return Stage(cur.k, b_next)
        # Grid exhausted below 1 (custom grid not reaching 1): fall through.
    k_next = min(cur.k + cfg.k_step, cfg.kmax)
    if k_next == cur.k:
        return None
    if model is None:
        raise ValueError(
            "adaptive_kbeta needs a delay model (oracle or fitted) to pick beta"
        )
    b_opt = optimal_beta(model, cfg.n, cur.k, cur.beta, k_next, cfg.s)
    bmin = beta_min_for(cur.k, cur.beta, k_next, cfg.s)
    b_next = _grid_ceil(grid, max(b_opt, bmin))
    if b_next is None:
        b_next = 1.0
    # phi must strictly grow; climb the grid if rounding collapsed it.
    while k_next * b_next <= cur.phi + 1e-12:
        nb = _grid_next_above(grid, b_next)
        if nb is None:
            return Stage(k_next, 1.0) if k_next * 1.0 > cur.phi else None
        b_next = nb
    return Stage(k_next, b_next)


def stage_table(
    cfg: StrategyConfig, model: Optional[DelayModel]
) -> List[Stage]:
    """The full (k, beta) stage sequence of ``cfg.strategy``, precomputed.

    The grid walk in ``next_stage`` is deterministic given a fixed delay
    model, so a run-time controller only needs an *index* into this table
    plus its diagnostic state. The batched simulation engine
    (``repro.core.vector_sim``) tracks one such index per seed lane; the
    scalar ``Controller`` walks the same sequence incrementally.

    Termination is guaranteed: every strategy either has a single stage
    or strictly grows k (adaptive_k) / phi = k*beta (adaptive_kbeta) up
    to the bounded maximum.
    """
    stages = [cfg.initial_stage()]
    while True:
        nxt = next_stage(cfg, stages[-1], model)
        if nxt is None:
            return stages
        stages.append(nxt)


class Controller:
    """Run-time stage controller fed by per-iteration observations."""

    def __init__(
        self,
        cfg: StrategyConfig,
        *,
        model: Optional[DelayModel] = None,
        estimate_model: bool = False,
    ):
        self.cfg = cfg
        self.oracle_model = model
        self.estimate_model = estimate_model
        self.stage = cfg.initial_stage()
        self.stage_idx = 0
        self.diagnostic = make_diagnostic(cfg.diagnostic)
        self.stage_history: List[Tuple[int, Stage]] = [(0, self.stage)]
        self._iter = 0
        self._rt_samples: list[float] = []
        self._rt_betas: list[float] = []
        self._rt_censored: list[float] = []
        self._terminal = False
        # k_max ceiling from the original config: remove_worker clamps
        # k_max to the shrunken n, add_worker restores it up to this cap
        # (None = "track n", the StrategyConfig default).
        self._kmax_cap = cfg.k_max

    # -- telemetry ----------------------------------------------------------
    def observe(
        self,
        *,
        w: Optional[np.ndarray] = None,
        grad: Optional[np.ndarray] = None,
        loss: Optional[float] = None,
        response_times: Optional[np.ndarray] = None,
        n_unobserved: int = 0,
    ) -> None:
        """Feed one iteration of telemetry.

        ``response_times`` must contain only times that were actually
        observed. A fastest-k step observes the k smallest of n times and
        passes ``n_unobserved = n - k``: those workers are censored at
        the step's largest observed time (we only know they were slower),
        and ``current_model`` fits them with the censored MLE instead of
        pretending the k winners are an i.i.d. fleet sample.
        """
        self._iter += 1
        if grad is not None or w is not None or loss is not None:
            self.diagnostic.observe(w=w, grad=grad, loss=loss)
        if response_times is not None:
            rt = np.asarray(response_times, dtype=np.float64).ravel()
            if n_unobserved < 0:
                raise ValueError("n_unobserved must be >= 0")
            if rt.size:
                cens = np.zeros(rt.size)
                cens[int(np.argmax(rt))] = float(n_unobserved)
                self._rt_samples.extend(rt.tolist())
                self._rt_betas.extend([self.stage.beta] * rt.size)
                self._rt_censored.extend(cens.tolist())
            # Bound memory: keep the freshest 50k samples.
            if len(self._rt_samples) > 50_000:
                self._rt_samples = self._rt_samples[-50_000:]
                self._rt_betas = self._rt_betas[-50_000:]
                self._rt_censored = self._rt_censored[-50_000:]

    def current_model(self) -> Optional[DelayModel]:
        if not self.estimate_model:
            return self.oracle_model
        if len(self._rt_samples) >= 64:
            return fit_simplified_mle_censored(
                np.array(self._rt_samples),
                np.array(self._rt_betas),
                np.array(self._rt_censored),
            )
        return self.oracle_model

    # -- stage advancement ---------------------------------------------------
    def should_switch(self) -> bool:
        if self._terminal:
            return False
        if self.cfg.strategy in ("naive", "fastest_k"):
            return False
        return self.diagnostic.is_stationary()

    def advance(self) -> Optional[Stage]:
        try:
            nxt = next_stage(self.cfg, self.stage, self.current_model())
        except ValueError:
            # The next stage needs a delay model to price beta* but none
            # is available yet (live estimation, too little telemetry):
            # stay in the current stage and keep collecting. The
            # diagnostic stays stationary, so we retry next iteration.
            return None
        if nxt is None:
            self._terminal = True
            return None
        self.stage = nxt
        self.stage_idx += 1
        self.stage_history.append((self._iter, nxt))
        self.diagnostic.reset()
        return nxt

    def maybe_advance(self) -> Optional[Stage]:
        if self.should_switch():
            return self.advance()
        return None

    # -- pricing helpers -----------------------------------------------------
    def expected_iteration_time(self) -> Optional[float]:
        m = self.current_model()
        if m is None:
            return None
        return expected_kth(m, self.cfg.n, self.stage.k, self.stage.beta)

    # -- fault handling ------------------------------------------------------
    def _kmax_for(self, n: int) -> int:
        return n if self._kmax_cap is None else min(self._kmax_cap, n)

    def remove_worker(self) -> None:
        """A worker died: shrink n (order statistics reprice automatically)."""
        n_new = self.cfg.n - 1
        if n_new < 1:
            raise RuntimeError("all workers lost")
        self.cfg = dataclasses.replace(
            self.cfg, n=n_new, k_max=self._kmax_for(n_new)
        )
        if self.stage.k > n_new:
            self.stage = Stage(n_new, self.stage.beta)

    def add_worker(self) -> None:
        """A worker (re)joined: grow n and restore k_max up to the
        original cap — the inverse of ``remove_worker``. The current
        stage is left alone; the stage walk simply reprices against the
        larger fleet (more workers make every mu_{k:n} cheaper)."""
        n_new = self.cfg.n + 1
        self.cfg = dataclasses.replace(
            self.cfg, n=n_new, k_max=self._kmax_for(n_new)
        )

    # -- checkpoint round-trip ----------------------------------------------
    def state_dict(self) -> dict:
        """Full JSON-serializable control state for exact resume.

        Restoring only ``Stage(k, beta)`` is not enough: a resumed
        controller also needs the stage index, terminal flag, stage
        history, diagnostic state, telemetry buffers, and the mutated
        (n, k_max) from any worker removals — otherwise it re-walks
        stages from a wrong index with a cold diagnostic and a fleet
        size that no longer matches the loop's.
        """
        return {
            "n": self.cfg.n,
            "k_max": self.cfg.k_max,
            "kmax_cap": self._kmax_cap,
            "stage": [self.stage.k, self.stage.beta],
            "stage_idx": self.stage_idx,
            "terminal": self._terminal,
            "iter": self._iter,
            "stage_history": [
                [it, s.k, s.beta] for it, s in self.stage_history
            ],
            "rt_samples": list(self._rt_samples),
            "rt_betas": list(self._rt_betas),
            "rt_censored": list(self._rt_censored),
            "diagnostic": self.diagnostic.state_dict(),
        }

    def load_state_dict(self, d: dict) -> None:
        self.cfg = dataclasses.replace(
            self.cfg, n=int(d["n"]),
            k_max=None if d["k_max"] is None else int(d["k_max"]),
        )
        self._kmax_cap = (
            None if d["kmax_cap"] is None else int(d["kmax_cap"])
        )
        self.stage = Stage(int(d["stage"][0]), float(d["stage"][1]))
        self.stage_idx = int(d["stage_idx"])
        self._terminal = bool(d["terminal"])
        self._iter = int(d["iter"])
        self.stage_history = [
            (int(it), Stage(int(k), float(b)))
            for it, k, b in d["stage_history"]
        ]
        self._rt_samples = [float(v) for v in d["rt_samples"]]
        self._rt_betas = [float(v) for v in d["rt_betas"]]
        self._rt_censored = [float(v) for v in d["rt_censored"]]
        self.diagnostic.load_state_dict(d["diagnostic"])
