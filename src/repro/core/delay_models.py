"""Worker response-time models from the paper (Definitions 1 and 2).

A worker's response time is ``Z_i = X_i + Y_i`` where ``X_i`` is the
communication time and ``Y_i`` the computation time for a load fraction
``beta`` of the worker's ``s`` local samples.

* Definition 1 (simplified): ``X_i = x`` (constant),
  ``Y_i ~ y + Exp(rate = lambda_y / beta)`` (mean ``beta / lambda_y``).
* Definition 2 (generalized): ``X_i ~ x + Exp(rate = lambda_x)``,
  ``Y_i ~ y * beta + Exp(rate = lambda_y / beta)``.

Both models make the paper's key structural point explicit: the mean
computation time scales linearly with the load ``beta`` while the
communication time does not.

This module also provides maximum-likelihood estimation of the model
parameters from observed response times, so the production controller can
run from telemetry instead of oracle knowledge (DESIGN.md §2.4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = [
    "SimplifiedDelayModel",
    "GeneralizedDelayModel",
    "fit_simplified_mle",
    "fit_simplified_mle_censored",
    "fit_generalized_mm",
]


@dataclasses.dataclass(frozen=True)
class SimplifiedDelayModel:
    """Definition 1. ``Z = x + y + Exp(rate=lambda_y/beta)``."""

    lambda_y: float  # computation rate at beta = 1 (mean comp time = beta/lambda_y)
    x: float = 0.0   # constant communication time
    y: float = 0.0   # constant computation offset

    #: number of standard-exponential draws per worker needed by ``compose``
    n_exp_streams = 1

    def __post_init__(self) -> None:
        if self.lambda_y <= 0:
            raise ValueError(f"lambda_y must be > 0, got {self.lambda_y}")
        if self.x < 0 or self.y < 0:
            raise ValueError("shifts x, y must be >= 0")

    @property
    def shift(self) -> float:
        return self.x + self.y

    def comp_rate(self, beta: float) -> float:
        """Rate of the exponential computation component for load ``beta``."""
        _check_beta(beta)
        return self.lambda_y / beta

    def mean(self, beta: float) -> float:
        return self.shift + beta / self.lambda_y

    def sample(self, rng: np.random.Generator, n: int, beta: float) -> np.ndarray:
        """Draw ``n`` i.i.d. response times for load ``beta``."""
        _check_beta(beta)
        return self.shift + rng.exponential(scale=beta / self.lambda_y, size=n)

    def compose(self, E: np.ndarray, beta) -> np.ndarray:
        """Response times from pre-drawn standard exponentials.

        ``E`` has shape ``(..., n_exp_streams, n)``; ``beta`` is a scalar
        or an array broadcastable against the leading axes (one load per
        batch lane). Both simulation engines draw ``E`` in chunks and
        compose lazily, so scalar and batched runs consume identical RNG
        streams per lane regardless of the stage schedule.
        """
        _check_beta(beta)
        scale = np.asarray(beta) / self.lambda_y
        return self.shift + scale * E[..., 0, :]


@dataclasses.dataclass(frozen=True)
class GeneralizedDelayModel:
    """Definition 2. ``Z = (x + Exp(lambda_x)) + (y*beta + Exp(lambda_y/beta))``."""

    lambda_x: float  # communication rate
    lambda_y: float  # computation rate at beta = 1
    x: float = 0.0
    y: float = 0.0

    n_exp_streams = 2

    def __post_init__(self) -> None:
        if self.lambda_x <= 0 or self.lambda_y <= 0:
            raise ValueError("rates must be > 0")
        if self.x < 0 or self.y < 0:
            raise ValueError("shifts x, y must be >= 0")

    def shift(self, beta: float) -> float:
        _check_beta(beta)
        return self.x + self.y * beta

    def comp_rate(self, beta: float) -> float:
        _check_beta(beta)
        return self.lambda_y / beta

    def mean(self, beta: float) -> float:
        return self.shift(beta) + 1.0 / self.lambda_x + beta / self.lambda_y

    def sample(self, rng: np.random.Generator, n: int, beta: float) -> np.ndarray:
        _check_beta(beta)
        comm = rng.exponential(scale=1.0 / self.lambda_x, size=n)
        comp = rng.exponential(scale=beta / self.lambda_y, size=n)
        return self.shift(beta) + comm + comp

    def compose(self, E: np.ndarray, beta) -> np.ndarray:
        """Response times from pre-drawn standard exponentials.

        ``E[..., 0, :]`` feeds the communication term, ``E[..., 1, :]``
        the load-scaled computation term (see ``SimplifiedDelayModel.compose``).
        """
        b = np.asarray(beta)
        comp_scale = b / self.lambda_y
        return (
            self.shift(beta)
            + E[..., 0, :] / self.lambda_x
            + comp_scale * E[..., 1, :]
        )


def _check_beta(beta) -> None:
    b = np.asarray(beta)
    if np.any(b <= 0.0) or np.any(b > 1.0):
        raise ValueError(f"beta must be in (0, 1], got {beta}")


# ---------------------------------------------------------------------------
# Parameter estimation from telemetry
# ---------------------------------------------------------------------------

def fit_simplified_mle(
    samples: np.ndarray, betas: np.ndarray
) -> SimplifiedDelayModel:
    """MLE of the simplified model from (response time, load) telemetry.

    For a shifted exponential with known per-sample scale multiplier
    ``beta_i`` the MLE of the shift is ``min_i (z_i)`` restricted by the
    smallest normalized sample and the rate follows from the mean of the
    normalized excesses:

        z_i = shift + beta_i * E_i / lambda_y,  E_i ~ Exp(1)
        shift_hat = min_i z_i  (consistent, biased by O(1/n))
        lambda_hat = mean_i (beta_i) applied to excess via MLE closed form.
    """
    z = np.asarray(samples, dtype=np.float64)
    b = np.broadcast_to(np.asarray(betas, dtype=np.float64), z.shape)
    if z.size < 2:
        raise ValueError("need at least 2 samples")
    # Normalize to unit load: (z - shift) / beta ~ Exp(lambda_y).
    # Joint MLE: shift_hat minimizes over the normalized support constraint.
    # z_i >= shift for all i; likelihood increases in shift, so
    # shift_hat = min_i z_i (attained where beta smallest matters only via
    # support; the constant shift is load independent under Def. 1).
    shift_hat = float(z.min())
    excess = (z - shift_hat) / b
    mean_excess = float(excess.mean())
    if mean_excess <= 0:
        # Degenerate (all samples equal): fall back to a large rate.
        return SimplifiedDelayModel(lambda_y=1e9, x=shift_hat, y=0.0)
    lambda_hat = 1.0 / mean_excess
    return SimplifiedDelayModel(lambda_y=lambda_hat, x=shift_hat, y=0.0)


def fit_simplified_mle_censored(
    samples: np.ndarray,
    betas: np.ndarray,
    censored: Optional[np.ndarray] = None,
) -> SimplifiedDelayModel:
    """Censoring-aware MLE of the simplified model (type-II censoring).

    On real hardware a fastest-k step observes only the k smallest of n
    response times; the n - k stragglers are *censored* at the step's
    k-th order statistic (we only learn ``Z > z_(k)``). Fitting the
    uncensored MLE to such telemetry is biased fast: the sample mean of
    the k winners underestimates the fleet mean, so ``lambda_y`` comes
    out too large and every ``expected_kth`` price is too optimistic.

    ``censored[i]`` counts the workers censored at observation ``i``'s
    value (the caller attaches ``n - k`` to each step's largest observed
    time; 0 elsewhere). The rate MLE is the classic total-time-on-test
    estimator (Epstein & Sobel): with normalized excesses
    ``e_i = (z_i - shift) / beta_i ~ Exp(lambda_y)``,

        lambda_hat = N_observed / sum_i (1 + censored_i) * e_i,

    which is exactly the exponential MLE when nothing is censored
    (``fit_simplified_mle``). The shift MLE is unchanged: censoring only
    tells us ``Z > z_(k) >= min_i z_i``, so the likelihood still
    increases in the shift up to the smallest *observed* sample.
    """
    if censored is None:
        return fit_simplified_mle(samples, betas)
    z = np.asarray(samples, dtype=np.float64)
    b = np.broadcast_to(np.asarray(betas, dtype=np.float64), z.shape)
    c = np.broadcast_to(np.asarray(censored, dtype=np.float64), z.shape)
    if z.size < 2:
        raise ValueError("need at least 2 samples")
    if np.any(c < 0):
        raise ValueError("censored counts must be >= 0")
    shift_hat = float(z.min())
    excess = (z - shift_hat) / b
    total_time_on_test = float(((1.0 + c) * excess).sum())
    if total_time_on_test <= 0:
        return SimplifiedDelayModel(lambda_y=1e9, x=shift_hat, y=0.0)
    lambda_hat = float(z.size) / total_time_on_test
    return SimplifiedDelayModel(lambda_y=lambda_hat, x=shift_hat, y=0.0)


def fit_generalized_mm(
    samples: np.ndarray,
    betas: np.ndarray,
    *,
    x_shift: float = 0.0,
    y_shift: float = 0.0,
) -> GeneralizedDelayModel:
    """Method-of-moments fit of the generalized model.

    The hypoexponential sum has mean ``1/lx + beta/ly`` and variance
    ``1/lx^2 + (beta/ly)^2`` (after removing known shifts). With telemetry
    at two or more distinct loads the two rates are identified by solving
    the per-load moment equations in the least-squares sense; with a single
    load we split the variance evenly (documented fallback).
    """
    z = np.asarray(samples, dtype=np.float64)
    b = np.broadcast_to(np.asarray(betas, dtype=np.float64), z.shape)
    zc = z - x_shift - y_shift * b
    uniq = np.unique(b)
    if uniq.size >= 2:
        # mean_j = 1/lx + beta_j * (1/ly): linear regression on beta.
        means = np.array([zc[b == u].mean() for u in uniq])
        A = np.stack([np.ones_like(uniq), uniq], axis=1)
        coef, *_ = np.linalg.lstsq(A, means, rcond=None)
        inv_lx, inv_ly = float(coef[0]), float(coef[1])
        inv_lx = max(inv_lx, 1e-12)
        inv_ly = max(inv_ly, 1e-12)
        return GeneralizedDelayModel(
            lambda_x=1.0 / inv_lx, lambda_y=1.0 / inv_ly, x=x_shift, y=y_shift
        )
    # Single load: use mean and variance.
    beta = float(uniq[0])
    m, v = float(zc.mean()), float(zc.var())
    # mean = a + c, var = a^2 + c^2 with a = 1/lx, c = beta/ly.
    # Solve: a + c = m, a^2 + c^2 = v  ->  a,c = (m +- sqrt(2v - m^2)) / 2.
    disc = max(2.0 * v - m * m, 0.0)
    root = math.sqrt(disc)
    a = max((m - root) / 2.0, 1e-12)
    c = max((m + root) / 2.0, 1e-12)
    return GeneralizedDelayModel(
        lambda_x=1.0 / a, lambda_y=beta / c, x=x_shift, y=y_shift
    )
