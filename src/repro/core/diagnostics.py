"""Stationary-phase detection for constant-step SGD stages.

The controller must detect, at run time, when the current stage has hit
its error floor (Murata's stationary phase) so it can advance to the next
(k, beta) stage. Two diagnostics are provided:

* ``PflugDiagnostic`` [41]: the running sum of inner products of
  consecutive stochastic gradients. In the transient phase successive
  gradients are positively correlated (drift dominates), near the floor
  they anti-correlate (bounce around the optimum), so the statistic
  drifts negative at stationarity. Known to be learning-rate sensitive.

* ``DistanceDiagnostic`` (adapted from Pesme et al. [35], as the paper's
  simulations do): track Omega_j = ||w_j - w_anchor||^2 against iteration
  count on a log-log scale at geometrically spaced checkpoints. Ballistic
  transient motion gives slope ~2; diffusive/saturating stationary motion
  gives slope well below 1. Declare stationarity when the measured slope
  drops below ``threshold``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = ["PflugDiagnostic", "DistanceDiagnostic", "make_diagnostic"]


class PflugDiagnostic:
    """Pflug's inner-product statistic with a burn-in."""

    def __init__(self, burn_in: int = 32):
        self.burn_in = burn_in
        self.reset()

    def reset(self) -> None:
        self._prev_grad: Optional[np.ndarray] = None
        self._stat = 0.0
        self._count = 0

    def observe(
        self,
        *,
        grad: np.ndarray,
        w: np.ndarray | None = None,
        loss: float | None = None,
    ) -> None:
        g = np.asarray(grad, dtype=np.float64).ravel()
        if self._prev_grad is not None:
            self._stat += float(np.dot(self._prev_grad, g))
        self._prev_grad = g
        self._count += 1

    def is_stationary(self) -> bool:
        return self._count >= self.burn_in and self._stat < 0.0

    # JSON-serializable state for checkpoint round-trip (exact resume).
    def state_dict(self) -> dict:
        return {
            "prev_grad": (
                None if self._prev_grad is None else self._prev_grad.tolist()
            ),
            "stat": self._stat,
            "count": self._count,
        }

    def load_state_dict(self, d: dict) -> None:
        pg = d["prev_grad"]
        self._prev_grad = None if pg is None else np.asarray(pg, np.float64)
        self._stat = float(d["stat"])
        self._count = int(d["count"])


class DistanceDiagnostic:
    """Log-log slope of ||w - w_anchor||^2 at geometric checkpoints."""

    def __init__(
        self,
        ratio: float = 1.5,
        threshold: float = 1.0,
        min_iters: int = 8,
        consecutive: int = 2,
    ):
        if ratio <= 1.0:
            raise ValueError("ratio must be > 1")
        self.ratio = ratio
        self.threshold = threshold
        self.min_iters = min_iters
        self.consecutive = consecutive
        self.reset()

    def reset(self) -> None:
        self._anchor: Optional[np.ndarray] = None
        self._count = 0
        self._next_check = max(self.min_iters, 2)
        self._prev_check: Optional[tuple[int, float]] = None  # (iter, omega)
        self._hits = 0
        self._stationary = False

    def observe(
        self,
        *,
        w: np.ndarray,
        grad: np.ndarray | None = None,
        loss: float | None = None,
    ) -> None:
        wv = np.asarray(w, dtype=np.float64).ravel()
        if self._anchor is None:
            self._anchor = wv.copy()
            return
        self._count += 1
        if self._count < self._next_check:
            return
        omega = float(np.sum((wv - self._anchor) ** 2))
        if omega <= 0.0:
            omega = 1e-300
        if self._prev_check is not None:
            it0, om0 = self._prev_check
            slope = (math.log(omega) - math.log(om0)) / (
                math.log(self._count) - math.log(it0)
            )
            if slope < self.threshold:
                self._hits += 1
                if self._hits >= self.consecutive:
                    self._stationary = True
            else:
                self._hits = 0
        self._prev_check = (self._count, omega)
        self._next_check = max(self._count + 1, int(self._count * self.ratio))

    def is_stationary(self) -> bool:
        return self._stationary

    def state_dict(self) -> dict:
        return {
            "anchor": None if self._anchor is None else self._anchor.tolist(),
            "count": self._count,
            "next_check": self._next_check,
            "prev_check": (
                None if self._prev_check is None else list(self._prev_check)
            ),
            "hits": self._hits,
            "stationary": self._stationary,
        }

    def load_state_dict(self, d: dict) -> None:
        a = d["anchor"]
        self._anchor = None if a is None else np.asarray(a, np.float64)
        self._count = int(d["count"])
        self._next_check = int(d["next_check"])
        pc = d["prev_check"]
        self._prev_check = None if pc is None else (int(pc[0]), float(pc[1]))
        self._hits = int(d["hits"])
        self._stationary = bool(d["stationary"])


class LossPlateauDiagnostic:
    """EWMA relative-improvement plateau test on the stochastic loss.

    Robust for the small beta-substeps of the paper's scheme, where the
    anchor-distance signal is weak: track fast/slow EWMAs of the observed
    minibatch loss; declare stationarity when the fast EWMA stops
    improving on the slow one by more than ``rel_tol``.
    """

    def __init__(
        self,
        fast: float = 0.2,
        slow: float = 0.05,
        rel_tol: float = 0.02,
        min_iters: int = 10,
        consecutive: int = 3,
    ):
        self.fast_a = fast
        self.slow_a = slow
        self.rel_tol = rel_tol
        self.min_iters = min_iters
        self.consecutive = consecutive
        self.reset()

    def reset(self) -> None:
        self._fast: Optional[float] = None
        self._slow: Optional[float] = None
        self._count = 0
        self._hits = 0
        self._stationary = False

    def observe(
        self,
        *,
        loss: Optional[float] = None,
        w: np.ndarray | None = None,
        grad: np.ndarray | None = None,
    ) -> None:
        if loss is None:
            return
        self._count += 1
        if self._fast is None:
            self._fast = self._slow = float(loss)
            return
        self._fast += self.fast_a * (float(loss) - self._fast)
        self._slow += self.slow_a * (float(loss) - self._slow)
        if self._count < self.min_iters:
            return
        denom = abs(self._slow) + 1e-30
        if (self._slow - self._fast) / denom < self.rel_tol:
            self._hits += 1
            if self._hits >= self.consecutive:
                self._stationary = True
        else:
            self._hits = 0

    def is_stationary(self) -> bool:
        return self._stationary

    def state_dict(self) -> dict:
        return {
            "fast": self._fast,
            "slow": self._slow,
            "count": self._count,
            "hits": self._hits,
            "stationary": self._stationary,
        }

    def load_state_dict(self, d: dict) -> None:
        self._fast = None if d["fast"] is None else float(d["fast"])
        self._slow = None if d["slow"] is None else float(d["slow"])
        self._count = int(d["count"])
        self._hits = int(d["hits"])
        self._stationary = bool(d["stationary"])


@dataclasses.dataclass(frozen=True)
class DiagnosticConfig:
    kind: str = "distance"  # "distance" | "pflug" | "loss"
    ratio: float = 1.5
    threshold: float = 1.0
    min_iters: int = 8
    consecutive: int = 2
    burn_in: int = 32
    rel_tol: float = 0.02
    fast: float = 0.2
    slow: float = 0.05


def make_diagnostic(cfg: DiagnosticConfig):
    if cfg.kind == "pflug":
        return PflugDiagnostic(burn_in=cfg.burn_in)
    if cfg.kind == "distance":
        return DistanceDiagnostic(
            ratio=cfg.ratio,
            threshold=cfg.threshold,
            min_iters=cfg.min_iters,
            consecutive=cfg.consecutive,
        )
    if cfg.kind == "loss":
        return LossPlateauDiagnostic(
            fast=cfg.fast,
            slow=cfg.slow,
            rel_tol=cfg.rel_tol,
            min_iters=cfg.min_iters,
            consecutive=cfg.consecutive,
        )
    raise ValueError(f"unknown diagnostic kind: {cfg.kind}")
