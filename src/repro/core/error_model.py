"""The SGD error-decay model of Eq. (1) and its stage recursion Eq. (10).

For a stage running with ``k`` workers at load ``beta`` (effective batch
``phi * s`` with ``phi = k * beta``), the expected optimality gap after j
iterations obeys

    E(k, beta, j) <= floor + (1 - eta*c)^j * (e0 - floor),
    floor = eta * L * sigma_grad^2 / (2 * c * s * phi).

Time enters through the per-iteration duration mu_{k:n}(beta): j = t / mu.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["SGDHyperParams", "error_floor", "error_after", "time_to_error", "alpha"]


@dataclasses.dataclass(frozen=True)
class SGDHyperParams:
    """Constants of the convergence bound (Bottou et al. [45])."""

    eta: float          # learning rate
    L: float            # Lipschitz constant of the gradient
    sigma_grad2: float  # upper bound on per-sample gradient variance
    c: float            # strong-convexity parameter
    s: int              # samples per worker

    def __post_init__(self) -> None:
        if not (0.0 < self.eta * self.c < 1.0):
            raise ValueError(
                f"need 0 < eta*c < 1 for contraction, got {self.eta * self.c}"
            )
        if self.s <= 0:
            raise ValueError("s must be positive")


def alpha(hp: SGDHyperParams) -> float:
    """Per-iteration contraction exponent: alpha = -log(1 - eta c) > 0."""
    return -math.log1p(-hp.eta * hp.c)


def error_floor(hp: SGDHyperParams, phi: float) -> float:
    """Stationary error floor for effective batch-size factor phi = k*beta."""
    if phi <= 0:
        raise ValueError("phi must be > 0")
    return hp.eta * hp.L * hp.sigma_grad2 / (2.0 * hp.c * hp.s * phi)


def error_after(
    hp: SGDHyperParams, phi: float, e0: float, iters: float
) -> float:
    """Gap after ``iters`` iterations starting from gap ``e0`` (Eq. 10)."""
    fl = error_floor(hp, phi)
    return fl + math.exp(-alpha(hp) * iters) * (e0 - fl)


def time_to_error(
    hp: SGDHyperParams, phi: float, mu: float, e0: float, target: float
) -> float:
    """Time for the stage (per-iteration cost ``mu``) to reach ``target``.

    Returns ``inf`` if the target lies at or below this stage's floor.
    """
    fl = error_floor(hp, phi)
    if target <= fl or e0 <= target:
        return 0.0 if e0 <= target else math.inf
    return mu / alpha(hp) * math.log((e0 - fl) / (target - fl))
