"""Order statistics of worker response times (Prop. 1 and Thm. 5).

``mu_{k:n}(beta)`` is the expected time until the k-th fastest of n workers
responds, given per-worker load ``beta``. This is the per-iteration cost of
the fastest-k strategy and the quantity every scheduling decision in the
paper is priced against.

* Simplified model (Def. 1): closed form (Prop. 1)
    mu^(1)_{k:n}(beta) = (beta/lambda_y) * H(n, k) + x + y,
  with the harmonic tail H(n, k) = sum_{j=n-k+1}^n 1/j.

* Generalized model (Def. 2): the paper's Thm. 5 gives an alternating
  quadruple sum which is numerically catastrophic beyond n ~ 20 (binomial
  coefficients up to 2^n with signed cancellation). We evaluate the same
  expectation by exact survival-function integration,

    E[S_{(k)}] = int_0^inf (1 - F_{(k)}(z)) dz,
    F_{(k)}(z) = sum_{j=k}^n C(n,j) F(z)^j (1-F(z))^{n-j},

  with the closed-form hypoexponential CDF F, using Gauss-Legendre
  quadrature. The quadruple sum is kept (``thm5_quadruple_sum``) and used
  as a cross-check for small n in the tests. See DESIGN.md §8.5.

Public API contract: everything here is pure math over the two delay
models in ``repro.core.delay_models`` — no model/runtime state, no
randomness, safe to call from any scheduler at decision frequency.
Every consumer prices decisions with the same two functions:
``expected_kth`` (training controller, ``serve.router.HedgedRouter``
fan-outs, ``serve.speculative`` hedged gamma pricing) and
``expected_kth_derivative`` (beta* line search). ``thm5_quadruple_sum``
is a validation reference only — do not ship it into schedules (it is
numerically unusable past n ~ 20, by design of the comparison).
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Tuple, Union

import numpy as np

from .delay_models import GeneralizedDelayModel, SimplifiedDelayModel

DelayModel = Union[SimplifiedDelayModel, GeneralizedDelayModel]


def _is_simplified(model: DelayModel) -> bool:
    """Structural dispatch: Def. 2 adds the communication rate
    ``lambda_x``; Def. 1 has none. (Not ``isinstance`` — the module can
    be imported under two package names, e.g. pytest --doctest-modules
    with the src/ namespace layout, and class identity would not
    survive.)"""
    return not hasattr(model, "lambda_x")

__all__ = [
    "harmonic_tail",
    "expected_kth",
    "expected_kth_derivative",
    "thm5_quadruple_sum",
]


@lru_cache(maxsize=4096)
def harmonic_tail(n: int, k: int) -> float:
    """H(n, k) = sum_{j=n-k+1}^{n} 1/j — grows with k, shrinks with n.

    >>> harmonic_tail(4, 1)
    0.25
    >>> round(harmonic_tail(3, 3), 6)       # full wait: H_3
    1.833333
    >>> harmonic_tail(8, 2) < harmonic_tail(4, 2)   # more workers help
    True
    """
    if not (1 <= k <= n):
        raise ValueError(f"need 1 <= k <= n, got k={k}, n={n}")
    return float(sum(1.0 / j for j in range(n - k + 1, n + 1)))


def expected_kth(model: DelayModel, n: int, k: int, beta: float) -> float:
    """E[Z_{(k:n)}] for per-worker load ``beta`` under either delay model.

    Prop. 1 closed form for the simplified model (shift + scaled
    harmonic tail):

    >>> from repro.core.delay_models import SimplifiedDelayModel
    >>> m = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    >>> mu = expected_kth(m, 4, 1, 1.0)
    >>> mu == m.shift + 0.5 * harmonic_tail(4, 1)
    True

    Halving the per-worker load beta halves the stochastic part:

    >>> half = expected_kth(m, 4, 1, 0.5)
    >>> round((half - m.shift) / (mu - m.shift), 6)
    0.5
    """
    if not (1 <= k <= n):
        raise ValueError(f"need 1 <= k <= n, got k={k}, n={n}")
    if _is_simplified(model):
        return (beta / model.lambda_y) * harmonic_tail(n, k) + model.shift
    return model.shift(beta) + _hypoexp_kth_mean(
        model.lambda_x, model.comp_rate(beta), n, k
    )


def expected_kth_derivative(
    model: DelayModel, n: int, k: int, beta: float, *, eps: float = 1e-6
) -> float:
    """d mu_{k:n} / d beta. Closed form for Def. 1, central diff for Def. 2."""
    if _is_simplified(model):
        return harmonic_tail(n, k) / model.lambda_y
    lo = max(beta - eps, 1e-9)
    hi = min(beta + eps, 1.0)
    flo = expected_kth(model, n, k, lo)
    fhi = expected_kth(model, n, k, hi)
    return (fhi - flo) / (hi - lo)


# ---------------------------------------------------------------------------
# Hypoexponential order statistics by survival integration
# ---------------------------------------------------------------------------

_GL_NODES = 384  # Gauss-Legendre nodes; integrand is smooth and monotone.


@lru_cache(maxsize=1)
def _gl_rule(nodes: int = _GL_NODES):
    x, w = np.polynomial.legendre.leggauss(nodes)
    return x, w


def _hypoexp_cdf(z: np.ndarray, a: float, b: float) -> np.ndarray:
    """CDF of Exp(a) + Exp(b) at z >= 0 (a, b rates)."""
    z = np.asarray(z, dtype=np.float64)
    if abs(a - b) < 1e-9 * max(a, b):
        # Erlang(2, a) limit.
        r = 0.5 * (a + b)
        return -np.expm1(-r * z) - r * z * np.exp(-r * z)
    return 1.0 - (b * np.exp(-a * z) - a * np.exp(-b * z)) / (b - a)


@lru_cache(maxsize=1024)
def _log_binom_tail_coeffs(n: int, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """(j, log C(n, j)) for j = k..n — the tail's summation support."""
    j = np.arange(k, n + 1, dtype=np.float64)
    lg_n1 = math.lgamma(n + 1)
    logc = np.array(
        [lg_n1 - math.lgamma(jj + 1) - math.lgamma(n - jj + 1) for jj in range(k, n + 1)]
    )
    return j, logc


def _binom_tail(p: np.ndarray, n: int, k: int) -> np.ndarray:
    """P(Binomial(n, p) >= k) = sum_{j=k}^{n} C(n,j) p^j (1-p)^(n-j).

    Fully vectorized over the evaluation points (the quadrature nodes of
    ``_hypoexp_kth_mean``): the log-binomial coefficient vector for the
    (n, k) tail is precomputed once and the whole term matrix is
    evaluated as one broadcasted logsumexp — no Python loop over j. For
    the n <= a few hundred used by schedules, float64 log-space terms
    are accurate.
    """
    p = np.clip(np.asarray(p, dtype=np.float64), 0.0, 1.0)
    logp = np.log(np.clip(p, 1e-300, 1.0))
    log1mp = np.log1p(-np.clip(p, 0.0, 1.0 - 1e-16))
    j, logc = _log_binom_tail_coeffs(n, k)
    # terms[..., m] = log of the j=k+m summand at each evaluation point.
    terms = (
        logc
        + logp[..., None] * j
        + log1mp[..., None] * (n - j)
    )
    m = terms.max(axis=-1, keepdims=True)
    out = np.exp(m[..., 0]) * np.sum(np.exp(terms - m), axis=-1)
    # p == 1 exactly -> tail is 1.
    out = np.where(p >= 1.0 - 1e-16, 1.0, out)
    return np.clip(out, 0.0, 1.0)


def _hypoexp_kth_mean(a: float, b: float, n: int, k: int) -> float:
    """E of the k-th order statistic of n i.i.d. Exp(a)+Exp(b) sums."""
    # Integration horizon: survival of the max decays like n*exp(-r_min z).
    r_min = min(a, b)
    z_max = (math.log(max(n, 2)) + 45.0) / r_min
    x, w = _gl_rule()
    z = 0.5 * z_max * (x + 1.0)
    weights = 0.5 * z_max * w
    cdf = _hypoexp_cdf(z, a, b)
    surv_k = 1.0 - _binom_tail(cdf, n, k)
    return float(np.sum(weights * surv_k))


# ---------------------------------------------------------------------------
# Paper Thm. 5 closed form (validation reference for small n)
# ---------------------------------------------------------------------------

def thm5_quadruple_sum(
    model: GeneralizedDelayModel, n: int, k: int, beta: float
) -> float:
    """Literal evaluation of the paper's Theorem 5 (small n only).

    Alternating signs make this unusable for n beyond ~20 in float64; it
    exists purely to cross-validate the quadrature path.
    """
    lx = model.lambda_x
    lyb = model.comp_rate(beta)
    if abs(lx - lyb) < 1e-12:
        raise ValueError("Thm. 5 form requires lambda_x != lambda_y/beta")
    total = 0.0
    for j in range(k, n + 1):
        for rho in range(0, j + 1):
            for tau in range(0, rho + n - j + 1):
                for xi in range(0, tau + 1):
                    alpha = lx * (rho + n - j - tau + xi) + lyb * (tau - xi)
                    if alpha == 0.0:
                        continue
                    coeff = (
                        math.comb(n, j)
                        * math.comb(j, rho)
                        * math.comb(rho + n - j, tau)
                        * math.comb(tau, xi)
                    )
                    # Note: the paper's printed exponent of the rate ratio is
                    # rho in one factor and tau in the CDF expansion; the
                    # consistent derivation (Appendix C) carries
                    # (lx/(lx - lyb))^tau and an extra (-1)^tau bookkeeping
                    # folded into the expansion. We follow Appendix C's final
                    # line with ratio exponent tau.
                    ratio = (lx / (lx - lyb)) ** tau
                    total += coeff * ((-1.0) ** (rho + xi + 1)) * ratio / alpha
    # With F_{(k)}(z) = 1 + sum_{alpha>0} c_m e^{-alpha_m z}, the mean is
    # E = int (1 - F) dz = -sum c_m / alpha_m, i.e. exactly the accumulated
    # (-1)^{rho+xi+1} terms above (the alpha = 0 term is the constant 1).
    return model.shift(beta) + total
