"""Theoretical schedule evaluation (paper Figs. 1-3 machinery).

Given hyper-parameters of the convergence bound (Eq. 1), a delay model,
and a strategy, roll the staged schedule forward analytically:

  stage tau: (k, beta)  ->  mu_tau (order stats), floor_tau,
  switch at t_tau per Thm. 2,  gap update per Eq. 10,

until the target gap is reached; accumulate the paper's cost units
(communication n + k per iteration, computation beta * s per iteration).
This module is pure host-side float math — it is what Figs. 1-3 integrate
over a (lambda_y, x) grid.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from .controller import Stage, StrategyConfig, next_stage
from .error_model import SGDHyperParams, error_floor, time_to_error
from .order_stats import DelayModel, expected_kth
from .switching import gap_at_switch, switching_interval

__all__ = ["StageRecord", "ScheduleResult", "evaluate_schedule"]


@dataclasses.dataclass(frozen=True)
class StageRecord:
    k: int
    beta: float
    t_start: float
    t_end: float
    iters: float
    gap_start: float
    gap_end: float
    mu: float


@dataclasses.dataclass(frozen=True)
class ScheduleResult:
    reached: bool
    runtime: float
    comp_cost: float        # sum over iterations of beta * s   (paper's unit)
    comm_cost: float        # sum over iterations of (n + k)    (paper's unit)
    stages: List[StageRecord]

    @property
    def n_stages(self) -> int:
        return len(self.stages)


def evaluate_schedule(
    cfg: StrategyConfig,
    model: DelayModel,
    hp: SGDHyperParams,
    *,
    e0: float,
    target: float,
    max_stages: int = 10_000,
) -> ScheduleResult:
    """Analytic roll-out of ``cfg.strategy`` until the gap reaches ``target``."""
    if target >= e0:
        return ScheduleResult(True, 0.0, 0.0, 0.0, [])

    stage: Optional[Stage] = cfg.initial_stage()
    t = 0.0
    gap = e0
    comp = 0.0
    comm = 0.0
    records: List[StageRecord] = []

    for _ in range(max_stages):
        assert stage is not None
        mu = expected_kth(model, cfg.n, stage.k, stage.beta)
        nxt = next_stage(cfg, stage, model)

        # Time for the *current* stage to reach the target, if it can.
        t_hit = time_to_error(hp, stage.phi, mu, gap, target)

        if nxt is None:
            # Terminal stage: run to target or report failure at the floor.
            if math.isinf(t_hit):
                return ScheduleResult(False, math.inf, comp, comm, records)
            iters = t_hit / mu
            records.append(
                StageRecord(stage.k, stage.beta, t, t + t_hit, iters, gap, target, mu)
            )
            return ScheduleResult(
                True,
                t + t_hit,
                comp + iters * stage.beta * cfg.s,
                comm + iters * (cfg.n + stage.k),
                records,
            )

        mu_next = expected_kth(model, cfg.n, nxt.k, nxt.beta)
        dt = switching_interval(
            hp,
            phi_cur=stage.phi,
            mu_cur=mu,
            phi_next=nxt.phi,
            mu_next=mu_next,
            gap_start=gap,
        )

        if t_hit <= dt:
            # Target reached inside this stage before the optimal switch.
            iters = t_hit / mu
            records.append(
                StageRecord(stage.k, stage.beta, t, t + t_hit, iters, gap, target, mu)
            )
            return ScheduleResult(
                True,
                t + t_hit,
                comp + iters * stage.beta * cfg.s,
                comm + iters * (cfg.n + stage.k),
                records,
            )

        gap_end = gap_at_switch(
            hp, phi_cur=stage.phi, mu_cur=mu, gap_start=gap, dt=dt
        )
        iters = dt / mu
        records.append(
            StageRecord(stage.k, stage.beta, t, t + dt, iters, gap, gap_end, mu)
        )
        comp += iters * stage.beta * cfg.s
        comm += iters * (cfg.n + stage.k)
        t += dt
        gap = gap_end
        stage = nxt

    raise RuntimeError(f"schedule did not terminate in {max_stages} stages")
