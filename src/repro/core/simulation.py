"""Event-driven straggler simulation of distributed SGD (paper Fig. 4, 5-9).

Reproduces the paper's linear-regression experiment: n workers hold
disjoint partitions of v samples; at each iteration every worker draws a
random batch of ``beta * s`` of its samples; the main node waits for the k
fastest responses (response times drawn from a delay model), averages
their partial gradients, and steps. The controller advances (k, beta)
stages when the stationarity diagnostic fires.

Paper cost units are accounted verbatim:
  communication += n + k      per iteration
  computation   += beta * s   per iteration  (per-worker task size)

This simulator is the *behavioural* twin of the production runtime in
``repro.runtime.train_loop`` — same controller, same delay models — so
paper-claim regressions run in milliseconds on CPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Tuple

import numpy as np

from .controller import Controller, Stage, StrategyConfig
from .order_stats import DelayModel

__all__ = [
    "LinregProblem",
    "SimResult",
    "simulate",
    "spawn_lane_rngs",
    "chunk_len",
    "draw_response_chunk",
    "draw_key_chunk",
]


# ---------------------------------------------------------------------------
# Shared RNG-lane layout (DESIGN.md §9.2)
#
# Scalar and batched engines must consume *identical* per-seed streams so
# ``simulate_batch`` reproduces ``simulate`` lane-for-lane. Each seed owns
# two independent sub-streams (spawned off one SeedSequence):
#
#   z-stream : standard exponentials, chunks of (chunk, n_exp_streams, n),
#              composed into response times via ``model.compose`` — the
#              load ``beta`` only scales the draws, so the stream layout
#              is independent of the stage schedule.
#   u-stream : uniform sort-keys, chunks of (chunk, n, s). Worker ``i``'s
#              batch at load ``beta`` is the ``bs = round(beta * s)``
#              samples with the smallest keys in row ``i`` — an exact
#              without-replacement sample that the scalar engine extracts
#              by argpartition and the batched engine by thresholding.
#
# Both streams advance one slice per iteration unconditionally, so the
# chunk position depends only on the iteration count, never on (k, beta).
# ---------------------------------------------------------------------------

_CHUNK_TARGET_ELEMS = 2_000_000


def chunk_len(n: int, s: int) -> int:
    """Iterations per RNG chunk — part of the stream layout, so it must
    depend only on (n, s), never on lane count or stage state."""
    return max(8, min(256, _CHUNK_TARGET_ELEMS // max(n * s, 1)))


def spawn_lane_rngs(seed: int) -> Tuple[np.random.Generator, np.random.Generator]:
    """(z_rng, u_rng) — the two independent sub-streams of one seed lane."""
    z_child, u_child = np.random.SeedSequence(seed).spawn(2)
    return np.random.default_rng(z_child), np.random.default_rng(u_child)


def draw_response_chunk(
    z_rng: np.random.Generator, model: DelayModel, n: int, chunk: int
) -> np.ndarray:
    """(chunk, model.n_exp_streams, n) standard exponentials."""
    return z_rng.standard_exponential((chunk, model.n_exp_streams, n))


def draw_key_chunk(
    u_rng: np.random.Generator, n: int, s: int, chunk: int
) -> np.ndarray:
    """(chunk, n, s) uniform batch-selection keys."""
    return u_rng.random((chunk, n, s))


@dataclasses.dataclass
class LinregProblem:
    """The paper's simulation task: least squares on random integer data.

    X entries are uniform on {1..100}, labels uniform on {1..10} (paper's
    "[100]"/"[10]" notation). d (feature dim) and eta are unspecified in
    the paper; we fix d=10 and a stable eta and record the choice
    (EXPERIMENTS.md §Paper).
    """

    X: np.ndarray
    y: np.ndarray
    n_workers: int
    eta: float
    w_star: np.ndarray
    f_star: float

    @classmethod
    def generate(
        cls,
        *,
        v: int = 400,
        d: int = 10,
        n_workers: int = 20,
        eta: Optional[float] = None,
        seed: int = 0,
    ) -> "LinregProblem":
        rng = np.random.default_rng(seed)
        X = rng.integers(1, 101, size=(v, d)).astype(np.float64)
        y = rng.integers(1, 11, size=(v,)).astype(np.float64)
        w_star, *_ = np.linalg.lstsq(X, y, rcond=None)
        f_star = float(np.mean((X @ w_star - y) ** 2))
        if eta is None:
            # The paper does not state (d, eta). Calibrated so the paper's
            # quoted readout gap (2e-2) sits ~1.4x ABOVE the k=1, beta=1
            # noise floor: the analytic schedule (Thm. 2 + Cor. 4) then
            # predicts runtime ratio 0.55, comp -59.7%, comm +12.7% vs
            # adaptive-k — matching the paper's 'roughly halves' / -59.9% /
            # +15.7% (EXPERIMENTS.md §Paper records the calibration sweep).
            # eta = 1.9% of the GD stability limit 2/lambda_max(Hessian).
            lam_max = float(np.linalg.eigvalsh(2.0 * X.T @ X / v).max())
            eta = 0.038 / lam_max
        return cls(X=X, y=y, n_workers=n_workers, eta=eta, w_star=w_star,
                   f_star=f_star)

    @property
    def v(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]

    @property
    def s(self) -> int:
        return self.v // self.n_workers

    def full_loss(self, w: np.ndarray) -> float:
        return float(np.mean((self.X @ w - self.y) ** 2))

    def gap(self, w: np.ndarray) -> float:
        return self.full_loss(w) - self.f_star

    def partition(self, i: int) -> slice:
        return slice(i * self.s, (i + 1) * self.s)


@dataclasses.dataclass
class SimResult:
    times: np.ndarray        # wall-clock at eval points
    gaps: np.ndarray         # F(w_t) - F_star at eval points
    comp_at_eval: np.ndarray # cumulative computation cost at eval points
    comm_at_eval: np.ndarray # cumulative communication cost at eval points
    runtime: float
    comp_cost: float
    comm_cost: float
    iterations: int
    stage_log: List[Tuple[int, Stage]]
    reached: bool

    def time_to_gap(self, target: float) -> float:
        """First wall-clock time at which the recorded gap <= target."""
        idx = np.nonzero(self.gaps <= target)[0]
        return float(self.times[idx[0]]) if idx.size else math.inf

    def cost_at_gap(self, target: float) -> Tuple[float, float]:
        """(comp, comm) cumulative cost when the gap first hits target."""
        idx = np.nonzero(self.gaps <= target)[0]
        if not idx.size:
            return math.inf, math.inf
        i = idx[0]
        return float(self.comp_at_eval[i]), float(self.comm_at_eval[i])


def simulate(
    problem: LinregProblem,
    cfg: StrategyConfig,
    model: DelayModel,
    *,
    seed: int = 0,
    max_iters: int = 200_000,
    target_gap: Optional[float] = None,
    eval_every: int = 1,
    w0: Optional[np.ndarray] = None,
    estimate_model: bool = False,
    oracle_switch_times: Optional[list] = None,
) -> SimResult:
    """Run one simulated distributed-SGD training under ``cfg.strategy``.

    oracle_switch_times: optional wall-clock switch times from the
    analytic schedule (Thm. 2); when given, stages advance at those times
    instead of on the stationarity diagnostic — this isolates the
    strategy's value from diagnostic quality (EXPERIMENTS.md §Paper).

    RNG discipline: this engine is the reference oracle for the batched
    ``repro.core.vector_sim.simulate_batch``; both consume the chunked
    two-stream layout documented at the top of this module, so a batched
    lane run at ``seed`` reproduces this function's trajectory.
    """
    z_rng, u_rng = spawn_lane_rngs(seed)
    n, s = cfg.n, cfg.s
    if n != problem.n_workers or s != problem.s:
        raise ValueError("cfg (n, s) must match the problem partitioning")
    chunk = chunk_len(n, s)

    ctrl = Controller(
        cfg,
        model=None if estimate_model else model,
        estimate_model=estimate_model,
    )
    if estimate_model:
        ctrl.oracle_model = None

    w = np.zeros(problem.d) if w0 is None else w0.copy()
    t = 0.0
    comp = 0.0
    comm = 0.0
    times = [0.0]
    gaps = [problem.gap(w)]
    comps = [0.0]
    comms = [0.0]
    reached = False
    it = 0

    X, y, eta = problem.X, problem.y, problem.eta
    E_chunk = U_chunk = None
    pos = chunk  # forces a draw on the first iteration

    for it in range(1, max_iters + 1):
        stage = ctrl.stage
        k, beta = stage.k, stage.beta
        bs = max(int(round(beta * s)), 1)

        if pos == chunk:
            E_chunk = draw_response_chunk(z_rng, model, n, chunk)
            U_chunk = draw_key_chunk(u_rng, n, s, chunk)
            pos = 0
        # Response times for all n workers at this load.
        z = model.compose(E_chunk[pos], beta)
        U_it = U_chunk[pos]
        pos += 1
        order = np.argpartition(z, k - 1)
        fastest = order[:k]
        t += float(z[fastest].max())

        # Partial gradients of the k fastest workers on random local
        # batches — the bs smallest sort-keys of each worker's row.
        grad = np.zeros_like(w)
        loss_sum = 0.0
        for i in fastest:
            part = problem.partition(int(i))
            if bs < s:
                idx = part.start + np.argpartition(U_it[i], bs - 1)[:bs]
                Xi, yi = X[idx], y[idx]
            else:
                Xi, yi = X[part], y[part]
            resid = Xi @ w - yi
            grad += Xi.T @ resid
            loss_sum += float(resid @ resid)
        grad *= 2.0 / (k * bs)
        w = w - eta * grad

        comp += beta * s
        comm += n + k
        ctrl.observe(w=w, grad=grad, loss=loss_sum / (k * bs), response_times=z)
        if oracle_switch_times is not None:
            while (
                ctrl.stage_idx < len(oracle_switch_times)
                and t >= oracle_switch_times[ctrl.stage_idx]
            ):
                if ctrl.advance() is None:
                    break
        else:
            ctrl.maybe_advance()

        if it % eval_every == 0:
            g = problem.gap(w)
            times.append(t)
            gaps.append(g)
            comps.append(comp)
            comms.append(comm)
            if target_gap is not None and g <= target_gap:
                reached = True
                break

    return SimResult(
        times=np.array(times),
        gaps=np.array(gaps),
        comp_at_eval=np.array(comps),
        comm_at_eval=np.array(comms),
        runtime=t,
        comp_cost=comp,
        comm_cost=comm,
        iterations=it,
        stage_log=list(ctrl.stage_history),
        reached=reached,
    )
