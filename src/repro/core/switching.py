"""Optimal stage-switching times (Theorem 2).

Given the current stage ``tau`` (k, beta, started at t_{tau-1} with gap
e(t_{tau-1})) and the parameters of the next stage, the optimal time to
switch is when the *time* derivative of the error bound of the next stage
overtakes that of the current stage (Eq. 9):

    t_tau = t_{tau-1} + (mu_tau / alpha) * log(
        (mu_{tau+1} - mu_tau) * phi_{tau+1} * (2 c phi_tau s e(t_{tau-1}) - eta L sigma^2)
        / (mu_tau * eta L sigma^2 * (phi_{tau+1} - phi_tau)) )

Degenerate cases (switch immediately, i.e. dt = 0):
  * the current gap is already at/below the current stage's floor,
  * the log argument is <= 1 (the next stage dominates from the start).
"""

from __future__ import annotations

import math

from .error_model import SGDHyperParams, alpha, error_floor

__all__ = ["switching_interval"]


def switching_interval(
    hp: SGDHyperParams,
    *,
    phi_cur: float,
    mu_cur: float,
    phi_next: float,
    mu_next: float,
    gap_start: float,
) -> float:
    """Duration dt = t_tau - t_{tau-1} of stage tau per Theorem 2.

    Args:
      phi_cur / phi_next: effective batch factors k*beta of the two stages.
      mu_cur / mu_next: expected per-iteration durations mu_{k:n}(beta).
      gap_start: e(t_{tau-1}), the optimality gap when the stage began.

    Returns:
      Non-negative switching interval (0 means switch immediately).
    """
    if phi_next <= phi_cur:
        raise ValueError(
            f"stages must strictly grow phi: {phi_cur} -> {phi_next}"
        )
    if mu_next <= mu_cur:
        # Next stage is both statistically larger AND faster per iteration:
        # it strictly dominates, switch immediately. (Possible under Def. 2
        # when raising k while slashing beta.)
        return 0.0
    num = 2.0 * hp.c * phi_cur * hp.s * gap_start - hp.eta * hp.L * hp.sigma_grad2
    if num <= 0.0:
        # Gap already at/below the current floor -> no progress left here.
        return 0.0
    arg = (
        (mu_next - mu_cur)
        * phi_next
        * num
        / (mu_cur * hp.eta * hp.L * hp.sigma_grad2 * (phi_next - phi_cur))
    )
    if arg <= 1.0:
        return 0.0
    return mu_cur / alpha(hp) * math.log(arg)


def gap_at_switch(
    hp: SGDHyperParams,
    *,
    phi_cur: float,
    mu_cur: float,
    gap_start: float,
    dt: float,
) -> float:
    """e(t_tau) from e(t_{tau-1}) after running stage tau for dt (Eq. 10)."""
    fl = error_floor(hp, phi_cur)
    return fl + math.exp(-alpha(hp) * dt / mu_cur) * (gap_start - fl)
