"""Seeds-batched, fully vectorized twin of ``repro.core.simulation.simulate``.

Every paper claim is an expectation over simulation seeds, so the
benchmark layer's hot path is "run the event-driven simulator S times".
``simulate_batch`` steps all S seed lanes as one ``(S, d)`` weight array:
response times for all lanes x workers are composed from chunked
pre-drawn exponentials, the per-worker Python gradient loop becomes a
masked-residual computation (two small GEMMs per iteration for *all*
lanes), and the per-seed ``Controller`` objects collapse to a
precomputed (k, beta) stage table (``repro.core.controller.stage_table``)
indexed by a per-lane stage pointer plus vectorized diagnostic state.

Equivalence contract (tests/test_vector_sim.py): lane ``i`` of
``simulate_batch(..., seeds=S)`` reproduces ``simulate(..., seed=i)``
because both consume the identical per-seed two-stream RNG layout
documented in ``repro.core.simulation`` (DESIGN.md §9). Trajectories
match to floating-point roundoff (summation order differs), stage logs
match exactly.

The scalar engine stays the readable reference oracle; this module is
the performance path (`benchmarks/perf_sim.py` tracks the speedup).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .controller import Stage, StrategyConfig, stage_table
from .delay_models import GeneralizedDelayModel, SimplifiedDelayModel
from .diagnostics import DiagnosticConfig
from .order_stats import DelayModel
from .simulation import (
    LinregProblem,
    SimResult,
    chunk_len,
    draw_key_chunk,
    draw_response_chunk,
    spawn_lane_rngs,
)

__all__ = ["BatchSimResult", "simulate_batch"]


# ---------------------------------------------------------------------------
# Vectorized per-lane stationarity diagnostics
#
# Lane-parallel ports of repro.core.diagnostics; each mirrors the scalar
# class's update rule exactly (same checkpoints, same truncation, same
# latches) so per-lane switch decisions agree with a scalar run.
# ---------------------------------------------------------------------------


class _BatchDistanceDiagnostic:
    """Lane-parallel ``DistanceDiagnostic``."""

    def __init__(self, cfg: DiagnosticConfig, lanes: int, d: int):
        self.ratio = cfg.ratio
        self.threshold = cfg.threshold
        self.min_iters = cfg.min_iters
        self.consecutive = cfg.consecutive
        self._anchor = np.zeros((lanes, d))
        self._has_anchor = np.zeros(lanes, dtype=bool)
        self._count = np.zeros(lanes, dtype=np.int64)
        self._next_check = np.zeros(lanes, dtype=np.int64)
        self._prev_iter = np.ones(lanes, dtype=np.int64)
        self._prev_omega = np.ones(lanes)
        self._has_prev = np.zeros(lanes, dtype=bool)
        self._hits = np.zeros(lanes, dtype=np.int64)
        self.stationary = np.zeros(lanes, dtype=bool)
        self.reset_lanes(np.ones(lanes, dtype=bool))

    def reset_lanes(self, m: np.ndarray) -> None:
        self._has_anchor[m] = False
        self._count[m] = 0
        self._next_check[m] = max(self.min_iters, 2)
        self._has_prev[m] = False
        self._hits[m] = 0
        self.stationary[m] = False
        self._pending_anchor = True

    def observe(self, *, w, grad=None, loss=None, active) -> None:
        if self._pending_anchor:
            new_anchor = active & ~self._has_anchor
            if new_anchor.any():
                self._anchor[new_anchor] = w[new_anchor]
                self._has_anchor |= new_anchor
            self._pending_anchor = bool((~self._has_anchor).any())
            obs = active & ~new_anchor
        else:
            obs = active
        self._count += obs
        chk = obs & (self._count >= self._next_check)
        if not chk.any():
            return
        dw = w - self._anchor
        omega = np.einsum("ld,ld->l", dw, dw)
        omega = np.where(omega <= 0.0, 1e-300, omega)
        judged = chk & self._has_prev
        if judged.any():
            with np.errstate(divide="ignore", invalid="ignore"):
                slope = (np.log(omega) - np.log(self._prev_omega)) / (
                    np.log(self._count) - np.log(self._prev_iter)
                )
            hit = judged & (slope < self.threshold)
            self._hits[hit] += 1
            self._hits[judged & ~hit] = 0
            self.stationary[hit & (self._hits >= self.consecutive)] = True
        self._prev_iter[chk] = self._count[chk]
        self._prev_omega[chk] = omega[chk]
        self._has_prev |= chk
        self._next_check[chk] = np.maximum(
            self._count + 1, (self._count * self.ratio).astype(np.int64)
        )[chk]


class _BatchPflugDiagnostic:
    """Lane-parallel ``PflugDiagnostic``."""

    def __init__(self, cfg: DiagnosticConfig, lanes: int, d: int):
        self.burn_in = cfg.burn_in
        self._prev_grad = np.zeros((lanes, d))
        self._has_prev = np.zeros(lanes, dtype=bool)
        self._stat = np.zeros(lanes)
        self._count = np.zeros(lanes, dtype=np.int64)
        self.stationary = np.zeros(lanes, dtype=bool)

    def reset_lanes(self, m: np.ndarray) -> None:
        self._has_prev[m] = False
        self._stat[m] = 0.0
        self._count[m] = 0
        self.stationary[m] = False

    def observe(self, *, w=None, grad, loss=None, active) -> None:
        dot = np.einsum("ld,ld->l", self._prev_grad, grad)
        upd = active & self._has_prev
        self._stat[upd] += dot[upd]
        self._prev_grad[active] = grad[active]
        self._count[active] += 1
        self._has_prev |= active
        self.stationary = (self._count >= self.burn_in) & (self._stat < 0.0)


class _BatchLossPlateauDiagnostic:
    """Lane-parallel ``LossPlateauDiagnostic``."""

    def __init__(self, cfg: DiagnosticConfig, lanes: int, d: int):
        self.fast_a = cfg.fast
        self.slow_a = cfg.slow
        self.rel_tol = cfg.rel_tol
        self.min_iters = cfg.min_iters
        self.consecutive = cfg.consecutive
        self._fast = np.zeros(lanes)
        self._slow = np.zeros(lanes)
        self._has_init = np.zeros(lanes, dtype=bool)
        self._count = np.zeros(lanes, dtype=np.int64)
        self._hits = np.zeros(lanes, dtype=np.int64)
        self.stationary = np.zeros(lanes, dtype=bool)

    def reset_lanes(self, m: np.ndarray) -> None:
        self._has_init[m] = False
        self._count[m] = 0
        self._hits[m] = 0
        self.stationary[m] = False

    def observe(self, *, w=None, grad=None, loss, active) -> None:
        self._count[active] += 1
        init = active & ~self._has_init
        if init.any():
            self._fast[init] = loss[init]
            self._slow[init] = loss[init]
            self._has_init |= init
        rest = active & ~init
        self._fast[rest] += self.fast_a * (loss - self._fast)[rest]
        self._slow[rest] += self.slow_a * (loss - self._slow)[rest]
        eligible = rest & (self._count >= self.min_iters)
        if not eligible.any():
            return
        ratio = (self._slow - self._fast) / (np.abs(self._slow) + 1e-30)
        hit = eligible & (ratio < self.rel_tol)
        self._hits[hit] += 1
        self._hits[eligible & ~hit] = 0
        self.stationary[hit & (self._hits >= self.consecutive)] = True


_BATCH_DIAGNOSTICS = {
    "distance": _BatchDistanceDiagnostic,
    "pflug": _BatchPflugDiagnostic,
    "loss": _BatchLossPlateauDiagnostic,
}


def _make_batch_diagnostic(cfg: DiagnosticConfig, lanes: int, d: int):
    try:
        cls = _BATCH_DIAGNOSTICS[cfg.kind]
    except KeyError:
        raise ValueError(f"unknown diagnostic kind: {cfg.kind}") from None
    return cls(cfg, lanes, d)


# ---------------------------------------------------------------------------
# Batched result
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatchSimResult:
    """Per-lane trajectories of one ``simulate_batch`` run.

    Eval-point arrays are ``(lanes, T)`` where ``T`` is the longest lane's
    record; lane ``i``'s first ``n_evals[i]`` entries are valid (lanes that
    hit ``target_gap`` early freeze and stop recording). ``lane(i)``
    reconstructs the scalar-engine ``SimResult`` view.
    """

    seeds: Tuple[int, ...]
    times: np.ndarray         # (lanes, T)
    gaps: np.ndarray          # (lanes, T)
    comp_at_eval: np.ndarray  # (lanes, T)
    comm_at_eval: np.ndarray  # (lanes, T)
    n_evals: np.ndarray       # (lanes,) valid prefix length per lane
    runtime: np.ndarray       # (lanes,)
    comp_cost: np.ndarray     # (lanes,)
    comm_cost: np.ndarray     # (lanes,)
    iterations: np.ndarray    # (lanes,)
    reached: np.ndarray       # (lanes,) bool
    stage_logs: List[List[Tuple[int, Stage]]]

    def __len__(self) -> int:
        return len(self.seeds)

    def lane(self, i: int) -> SimResult:
        ne = int(self.n_evals[i])
        return SimResult(
            times=self.times[i, :ne].copy(),
            gaps=self.gaps[i, :ne].copy(),
            comp_at_eval=self.comp_at_eval[i, :ne].copy(),
            comm_at_eval=self.comm_at_eval[i, :ne].copy(),
            runtime=float(self.runtime[i]),
            comp_cost=float(self.comp_cost[i]),
            comm_cost=float(self.comm_cost[i]),
            iterations=int(self.iterations[i]),
            stage_log=list(self.stage_logs[i]),
            reached=bool(self.reached[i]),
        )

    def __iter__(self):
        return (self.lane(i) for i in range(len(self)))

    def mean_time_to_gap(self, target: float) -> float:
        vals = [r.time_to_gap(target) for r in self]
        return float(np.mean(vals))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


def simulate_batch(
    problem: LinregProblem,
    cfg: StrategyConfig,
    model: DelayModel,
    *,
    seeds: Union[int, Sequence[int]] = 24,
    max_iters: int = 200_000,
    target_gap: Optional[float] = None,
    eval_every: int = 1,
    w0: Optional[np.ndarray] = None,
    estimate_model: bool = False,
    oracle_switch_times: Optional[list] = None,
) -> BatchSimResult:
    """Run ``simulate`` for many seeds at once, vectorized across lanes.

    ``seeds`` is either a lane count (lanes run seeds ``0..seeds-1``, the
    convention of ``benchmarks.common.mean_curves``) or an explicit seed
    sequence. All other parameters mirror ``simulate``; lane ``i``
    reproduces ``simulate(..., seed=seeds[i])`` (same RNG streams, same
    stage decisions, trajectories equal to FP roundoff).
    """
    if estimate_model:
        raise ValueError(
            "online model estimation is sequential per lane; use the scalar "
            "simulate(estimate_model=True) reference engine for it"
        )
    seed_list: Tuple[int, ...] = (
        tuple(range(seeds)) if isinstance(seeds, (int, np.integer)) else tuple(seeds)
    )
    L = len(seed_list)
    if L == 0:
        raise ValueError("need at least one seed lane")
    n, s = cfg.n, cfg.s
    if n != problem.n_workers or s != problem.s:
        raise ValueError("cfg (n, s) must match the problem partitioning")

    X, y, eta = problem.X, problem.y, problem.eta
    v, d = problem.v, problem.d
    XT = np.ascontiguousarray(X.T)
    f_star = problem.f_star

    # -- stage table + per-lane stage state ---------------------------------
    table = stage_table(cfg, model)
    T = len(table)
    k_tab = np.array([st.k for st in table], dtype=np.int64)
    beta_tab = np.array([st.beta for st in table])
    bs_tab = np.maximum(np.rint(beta_tab * s).astype(np.int64), 1)
    stage_idx = np.zeros(L, dtype=np.int64)
    terminal = np.zeros(L, dtype=bool)

    k_lane = np.empty(L, dtype=np.int64)
    beta_lane = np.empty(L)
    bs_lane = np.empty(L, dtype=np.int64)
    gcoef = np.empty(L)      # 2 / (k * bs)
    comp_inc = np.empty(L)   # beta * s
    comm_inc = np.empty(L)   # n + k

    # Inline response-time composition (``model.compose`` unrolled with the
    # per-lane load factors precomputed at each stage change; same float
    # ops as the scalar path, so values match bitwise).
    is_simple = isinstance(model, SimplifiedDelayModel)
    is_general = isinstance(model, GeneralizedDelayModel)
    comp_scale = np.empty((L, 1))  # beta / lambda_y
    shift_lane = np.empty((L, 1))  # generalized: x + y * beta
    # Per-iteration batch-subsampling state (bs < s for any lane):
    any_subsample = False
    bs_m1_col = np.empty((L, 1), dtype=np.int64)
    lane_col = np.arange(L)[:, None]
    worker_row = np.arange(n)[None, :]

    def regather_stages() -> None:
        nonlocal any_subsample
        k_lane[:] = k_tab[stage_idx]
        beta_lane[:] = beta_tab[stage_idx]
        bs_lane[:] = bs_tab[stage_idx]
        gcoef[:] = 2.0 / (k_lane * bs_lane)
        comp_inc[:] = beta_lane * s
        comm_inc[:] = float(n) + k_lane
        comp_scale[:, 0] = beta_lane / model.lambda_y
        if is_general:
            shift_lane[:, 0] = model.x + model.y * beta_lane
        any_subsample = bool((bs_lane < s).any())
        bs_m1_col[:, 0] = bs_lane - 1

    regather_stages()

    # -- diagnostics / oracle switching -------------------------------------
    adaptive = cfg.strategy not in ("naive", "fastest_k")
    use_oracle = oracle_switch_times is not None
    diag = None
    if adaptive and not use_oracle:
        diag = _make_batch_diagnostic(cfg.diagnostic, L, d)
    needs_loss = diag is not None and isinstance(diag, _BatchLossPlateauDiagnostic)
    if use_oracle:
        ost = np.asarray(list(oracle_switch_times), dtype=np.float64)
        n_ost = ost.size
    stage_logs: List[List[Tuple[int, Stage]]] = [[(0, table[0])] for _ in range(L)]

    def advance_lanes(mask: np.ndarray, it: int) -> bool:
        """Mirror ``Controller.advance`` for the masked lanes."""
        at_end = mask & (stage_idx >= T - 1)
        terminal[at_end] = True
        adv = mask & ~at_end
        if not adv.any():
            return False
        stage_idx[adv] += 1
        for lane in np.nonzero(adv)[0]:
            stage_logs[lane].append((it, table[stage_idx[lane]]))
        if diag is not None:
            diag.reset_lanes(adv)
        return True

    # -- per-lane weights and accumulators ----------------------------------
    if w0 is None:
        w = np.zeros((L, d))
    else:
        w0 = np.asarray(w0, dtype=np.float64)
        w = np.broadcast_to(w0, (L, d)).copy() if w0.ndim == 1 else w0.copy()
        if w.shape != (L, d):
            raise ValueError(f"w0 must broadcast to {(L, d)}, got {w0.shape}")
    t = np.zeros(L)
    comp = np.zeros(L)
    comm = np.zeros(L)
    active = np.ones(L, dtype=bool)
    reached = np.zeros(L, dtype=bool)
    iterations = np.zeros(L, dtype=np.int64)
    n_evals = np.ones(L, dtype=np.int64)

    r_buf = np.empty((L, v))
    lane_ar = np.arange(L)

    def gap_all() -> np.ndarray:
        np.matmul(w, XT, out=r_buf)
        np.subtract(r_buf, y, out=r_buf)
        return np.einsum("lv,lv->l", r_buf, r_buf) / v - f_star

    times_rec = [np.zeros(L)]
    gaps_rec = [gap_all()]
    comps_rec = [np.zeros(L)]
    comms_rec = [np.zeros(L)]

    # -- chunked per-lane RNG streams (shared layout with the scalar engine)
    chunk = chunk_len(n, s)
    rngs = [spawn_lane_rngs(sd) for sd in seed_list]
    E_buf = np.empty((chunk, L, model.n_exp_streams, n))
    U_buf = np.empty((chunk, L, n, s))
    pos = chunk

    for it in range(1, max_iters + 1):
        if pos == chunk:
            for lane in np.nonzero(active)[0]:
                z_rng, u_rng = rngs[lane]
                E_buf[:, lane] = draw_response_chunk(z_rng, model, n, chunk)
                U_buf[:, lane] = draw_key_chunk(u_rng, n, s, chunk)
            pos = 0
        E_it = E_buf[pos]
        U_it = U_buf[pos]
        pos += 1

        np.copyto(iterations, it, where=active)

        # Response times, k-th order statistic, fastest-k mask.
        if is_simple:
            z = model.shift + comp_scale * E_it[:, 0, :]
        elif is_general:
            z = shift_lane + E_it[:, 0, :] / model.lambda_x + comp_scale * E_it[:, 1, :]
        else:
            z = model.compose(E_it, beta_lane[:, None])
        zs = np.sort(z, axis=1)
        kth = zs[lane_ar, k_lane - 1]
        np.add(t, kth, out=t, where=active)
        fast = z <= kth[:, None]

        # Batch-selection mask: worker i contributes its bs smallest-key
        # samples. One row-sort covers every lane's bs (cheaper than any
        # per-bs partition at these row lengths); rows with bs == s
        # threshold at the row max, selecting everything.
        if any_subsample:
            Us = np.sort(U_it, axis=-1)
            thr = Us[lane_col, worker_row, bs_m1_col]
            Mb = ((U_it <= thr[:, :, None]) & fast[:, :, None]).reshape(L, v)
        else:
            Mb = np.repeat(fast, s, axis=1)

        # Gradient of all lanes: residuals on the full data, masked to the
        # selected samples, contracted back through X (two small GEMMs).
        np.matmul(w, XT, out=r_buf)
        np.subtract(r_buf, y, out=r_buf)
        Mr = np.where(Mb, r_buf, 0.0)
        grad = Mr @ X
        grad *= gcoef[:, None]
        np.subtract(w, eta * grad, out=w, where=active[:, None])

        np.add(comp, comp_inc, out=comp, where=active)
        np.add(comm, comm_inc, out=comm, where=active)

        # Stage control: diagnostics or oracle switch times.
        dirty = False
        if diag is not None:
            loss = (
                np.einsum("lv,lv->l", Mr, r_buf) * (gcoef / 2.0)
                if needs_loss
                else None
            )
            diag.observe(w=w, grad=grad, loss=loss, active=active)
            fired = diag.stationary & active & ~terminal
            if fired.any():
                dirty = advance_lanes(fired, it)
        elif use_oracle and n_ost > 0:
            while True:
                idx_c = np.minimum(stage_idx, max(n_ost - 1, 0))
                due = (
                    active
                    & ~terminal
                    & (stage_idx < n_ost)
                    & (t >= ost[idx_c])
                )
                if not due.any():
                    break
                if not advance_lanes(due, it):
                    break
                dirty = True
        if dirty:
            regather_stages()

        if it % eval_every == 0:
            g = gap_all()
            times_rec.append(t.copy())
            gaps_rec.append(np.where(active, g, gaps_rec[-1]))
            comps_rec.append(comp.copy())
            comms_rec.append(comm.copy())
            n_evals[active] += 1
            if target_gap is not None:
                done = active & (g <= target_gap)
                if done.any():
                    reached |= done
                    active &= ~done
                    if not active.any():
                        break

    return BatchSimResult(
        seeds=seed_list,
        times=np.stack(times_rec, axis=1),
        gaps=np.stack(gaps_rec, axis=1),
        comp_at_eval=np.stack(comps_rec, axis=1),
        comm_at_eval=np.stack(comms_rec, axis=1),
        n_evals=n_evals,
        runtime=t,
        comp_cost=comp,
        comm_cost=comm,
        iterations=iterations,
        reached=reached,
        stage_logs=stage_logs,
    )
