from .pipeline import StagedBatcher, TokenStream, make_frame_stream

__all__ = ["StagedBatcher", "TokenStream", "make_frame_stream"]
