"""Synthetic data pipeline with per-stage beta-scaled batching.

``TokenStream`` produces deterministic synthetic LM batches (structured
enough that a ~100M model visibly learns: a periodic Markov-ish stream
with a learnable transition rule, not uniform noise).

``StagedBatcher`` is the bridge to the paper: given the controller's
current stage (k, beta), it emits batches whose per-worker share is
``beta * b_w`` sequences (b_w = global_batch / n_workers), laid out
worker-major so the masked fastest-k aggregation can weight examples by
worker (repro.dist.collectives.example_weights). Changing beta changes
the batch SHAPE — the step cache compiles one program per stage shape.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["TokenStream", "StagedBatcher", "make_frame_stream"]


class TokenStream:
    """Deterministic synthetic token stream: next = (a*cur + b) % V with
    noise — learnable structure with controllable difficulty."""

    def __init__(self, vocab_size: int, seed: int = 0, noise: float = 0.1):
        self.vocab = vocab_size
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.a = 31
        self.b = 17

    def sequences(self, n: int, seq_len: int) -> np.ndarray:
        start = self.rng.integers(0, self.vocab, size=(n, 1))
        seqs = [start]
        cur = start
        for _ in range(seq_len):
            nxt = (self.a * cur + self.b) % self.vocab
            flip = self.rng.random(cur.shape) < self.noise
            rnd = self.rng.integers(0, self.vocab, size=cur.shape)
            cur = np.where(flip, rnd, nxt)
            seqs.append(cur)
        arr = np.concatenate(seqs, axis=1)  # (n, seq_len + 1)
        return arr.astype(np.int32)


def make_frame_stream(d_model: int, seed: int = 0):
    """Audio-stub stream: smooth random frame embeddings + kmeans-ish labels."""
    rng = np.random.default_rng(seed)

    def sample(n: int, seq_len: int, vocab: int):
        x = rng.standard_normal((n, seq_len, d_model)).astype(np.float32)
        # Smooth along time so there is learnable temporal structure.
        x = 0.5 * x + 0.5 * np.roll(x, 1, axis=1)
        labels = (np.abs(x[..., :8]).sum(-1) * 37).astype(np.int64) % vocab
        return x, labels.astype(np.int32)

    return sample


@dataclasses.dataclass
class StagedBatcher:
    stream: TokenStream
    n_workers: int           # fleet size at construction (beta=1 reference)
    global_batch: int        # at beta = 1
    seq_len: int

    def _per_worker(self, beta: float) -> int:
        b_w = self.global_batch // self.n_workers
        return max(int(round(beta * b_w)), 1)

    def batch_for_stage(
        self, beta: float, n_workers: Optional[int] = None
    ) -> Dict[str, np.ndarray]:
        """Worker-major batch for the stage's (beta, fleet size).

        ``n_workers`` overrides the construction-time fleet size so an
        elastic loop can keep the batch layout aligned with the
        controller's CURRENT n after failures/rejoins: the per-worker
        share stays the beta-scaled b_w (per-worker compute is the
        paper's knob) and the batch shrinks/grows with the fleet,
        keeping ``B % n == 0`` — the worker-major mask contract.
        """
        n = self.n_workers if n_workers is None else n_workers
        if n < 1:
            raise ValueError(f"need at least one worker, got {n}")
        B = self._per_worker(beta) * n
        arr = self.stream.sequences(B, self.seq_len)
        return {
            "inputs": arr[:, :-1],
            "labels": arr[:, 1:],
        }

    def batch_shape(self, beta: float, n_workers: Optional[int] = None):
        n = self.n_workers if n_workers is None else n_workers
        return (self._per_worker(beta) * n, self.seq_len)
