"""Distributed-execution substrate for the fastest-k / beta-scaled runtime.

The paper's scheme (adaptive number of waited-for workers k, adaptive
per-worker computation load beta) only pays off once it is wired into a
real sharded runtime. This package provides that wiring:

  sharding.py          — logical-axis -> mesh-axis rules, PartitionSpec
                         derivation, and the ambient activation-sharding
                         context used by the model code,
  collectives.py       — masked fastest-k aggregation: the worker mask
                         enters the loss as DATA, so dropping stragglers
                         never triggers a recompile (DESIGN.md §2.3),
  compression.py       — int8 gradient codec + error feedback (the
                         paper's "slight increase in communication load"
                         is bought back by compressing the result),
  pipeline_parallel.py — GPipe-style pipeline stage for depth sharding.

Everything here is pure JAX (no pallas): the collectives are expressed
as weighted reductions and sharding constraints so GSPMD chooses the
actual all-reduce/all-gather schedule.
"""

import jax

if not hasattr(jax, "set_mesh"):
    # Compatibility shim for older jax (< 0.5): launch scripts and tests
    # use ``with jax.set_mesh(mesh):`` from the newer API. A ``Mesh`` is
    # itself a context manager that installs the ambient mesh, so the
    # shim simply returns it. Caveat: only the context-manager usage is
    # emulated — a bare ``jax.set_mesh(mesh)`` statement does NOT install
    # a global mesh the way the real API does. Self-disables once jax
    # provides the real function.
    def _set_mesh(mesh):
        return mesh

    jax.set_mesh = _set_mesh

from .collectives import contributors, example_weights, masked_weighted_ce
from .compression import Int8Codec, ef_compress_tree
from .sharding import (
    DEFAULT_RULES,
    FSDP_POD_RULES,
    PURE_DP_RULES,
    SP_DECODE_RULES,
    ShardingRules,
    activation_sharding,
    batch_pspec,
    constrain_batch,
    constrain_logical,
    logical_to_pspec,
    make_sharding_fn,
)

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "FSDP_POD_RULES",
    "PURE_DP_RULES",
    "SP_DECODE_RULES",
    "logical_to_pspec",
    "batch_pspec",
    "make_sharding_fn",
    "activation_sharding",
    "constrain_batch",
    "constrain_logical",
    "contributors",
    "example_weights",
    "masked_weighted_ce",
    "Int8Codec",
    "ef_compress_tree",
]
