"""Masked fastest-k aggregation.

The central node only waits for the fastest k of n workers; the batch is
laid out WORKER-MAJOR (worker w owns the contiguous example slice
``[w * b_w, (w + 1) * b_w)``, with ``b_w = beta * B / n`` set by the
data pipeline's beta scaling). The responding-worker mask enters the
loss as DATA, never as shape: per-example weights zero out the
stragglers' examples and the normalizer counts only contributed tokens.

This makes the masked step EXACTLY the dense step run on the k
contributing workers' examples (the paper's aggregation, eq. (2)): the
weights of dropped examples are zero, so their activations cannot
influence the loss or any parameter gradient, and the normalization is
over contributed tokens only. Under uniformly random k-subsets the
masked gradient is an unbiased estimator of the full-batch gradient,
with variance scaled by n/k (DESIGN.md §2.3).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "contributors",
    "check_worker_major",
    "example_weights",
    "masked_weighted_ce",
]


def contributors(worker_mask: jax.Array) -> jax.Array:
    """Number of workers whose gradients entered the step (k_effective)."""
    return jnp.sum(worker_mask.astype(jnp.float32))


def check_worker_major(batch: int, n_workers: int) -> int:
    """The mask-vs-batch layout contract. Returns rows per worker.

    A fastest-k mask is a LENGTH-``n_workers`` vector over the workers
    that produced THIS batch: the batch is worker-major (worker ``w``
    owns rows ``[w * b_w, (w + 1) * b_w)``) and ``batch`` must divide
    evenly into ``n_workers`` shares. Slicing a stale larger-fleet mask
    down to the batch size — or comparing worker count against batch
    rows — silently misassigns rows to the wrong workers after the
    fleet shrinks; size the mask for the current fleet instead.
    """
    if n_workers < 1:
        raise ValueError(f"need at least one worker, got {n_workers}")
    if batch % n_workers != 0:
        raise ValueError(
            f"batch {batch} not divisible by n_workers {n_workers}; the "
            "worker-major layout requires equal per-worker shares (is the "
            "mask sized for the current fleet that produced this batch?)"
        )
    return batch // n_workers


def example_weights(worker_mask: jax.Array, batch: int) -> jax.Array:
    """Expand a (n_workers,) 0/1 mask to per-example weights (batch,).

    The batch must be worker-major with equal per-worker shares: example
    ``i`` belongs to worker ``i // (batch / n)`` (``check_worker_major``).
    """
    if worker_mask.ndim != 1:
        raise ValueError(
            f"worker_mask must be 1-D over workers, got shape {worker_mask.shape}"
        )
    per_worker = check_worker_major(batch, worker_mask.shape[0])
    return jnp.repeat(
        worker_mask.astype(jnp.float32), per_worker,
        total_repeat_length=batch,
    )


def masked_weighted_ce(
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    worker_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy with optional per-token mask and fastest-k worker mask.

    logits: (B, S, V); labels: (B, S) int; mask: (B, S) or None;
    worker_mask: (n_workers,) 0/1 or None (B must be a multiple of n).

    Returns ``(loss, denom)`` where loss is the mean NLL over contributed
    (unmasked, responding-worker) tokens and denom is that token count —
    the weight used to recombine gradient-accumulation microbatches.
    """
    w = (
        jnp.ones(labels.shape, jnp.float32)
        if mask is None
        else mask.astype(jnp.float32)
    )
    if worker_mask is not None:
        w = w * example_weights(worker_mask, labels.shape[0])[:, None]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * w
    denom = w.sum()
    loss = nll.sum() / jnp.maximum(denom, 1.0)
    return loss, denom
