"""Gradient compression with error feedback.

The paper's scheme trades a slight INCREASE in communication load
(smaller beta -> more iterations -> more result uploads) for reduced
computation. This module buys that communication back: workers upload
int8-quantized results and carry the quantization error forward into the
next round (error feedback, a la EF-SGD), which restores convergence to
the uncompressed fixed point.

``Int8Codec`` is a per-tensor absmax codec: 4x smaller uploads than
float32 with max elementwise error of scale/2. ``ef_compress_tree``
applies it leaf-wise over a gradient pytree while threading the residual
state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["Int8Codec", "ef_compress_tree"]


class Int8Codec:
    """Per-tensor symmetric absmax int8 quantization."""

    @staticmethod
    def encode(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """x (float) -> (q int8, scale float32 scalar); x ~= q * scale."""
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf)) / 127.0
        safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
        return q, scale

    @staticmethod
    def decode(q: jax.Array, scale: jax.Array) -> jax.Array:
        return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual):
    """Quantize a gradient pytree with error feedback.

    Each leaf is compensated (``g + residual``), int8 round-tripped, and
    the new residual is the quantization error. Returns
    ``(decoded_grads, new_residual)`` with the input tree structure —
    the decoded values are what the aggregator would reconstruct from
    the workers' int8 uploads.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves, r_treedef = jax.tree.flatten(residual)
    if treedef != r_treedef:
        raise ValueError(
            f"grads and residual tree structures do not match: "
            f"{treedef} vs {r_treedef}"
        )
    decoded, new_resid = [], []
    for g, r in zip(g_leaves, r_leaves):
        v = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, scale = Int8Codec.encode(v)
        d = Int8Codec.decode(q, scale)
        decoded.append(d.astype(g.dtype))
        new_resid.append(v - d)
    return treedef.unflatten(decoded), treedef.unflatten(new_resid)
