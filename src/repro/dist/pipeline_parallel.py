"""GPipe-style pipeline parallelism over a mesh axis.

``stage_params`` splits a layer-stacked parameter tree into per-stage
chunks; ``pipeline_forward`` runs the classic GPipe schedule: microbatch
``m`` enters stage 0 at tick ``m``, activations rotate stage-to-stage
with ``ppermute`` each tick, and the last stage emits microbatch ``m``
at tick ``m + n_stages - 1``. Total ticks: ``n_micro + n_stages - 1``
(the usual bubble); each device only ever holds its own stage's weights.

Expressed with ``shard_map`` so the per-stage compute is explicitly
local and the only communication is the neighbor exchange.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["stage_params", "pipeline_forward"]


def stage_params(params, n_stages: int):
    """Split layer-stacked params (L, ...) into (n_stages, L/n_stages, ...).

    Works leaf-wise on pytrees; every leaf's leading dim must be the
    layer dim and divisible by ``n_stages``.
    """

    def split(w):
        L = w.shape[0]
        if L % n_stages != 0:
            raise ValueError(f"{L} layers not divisible by {n_stages} stages")
        return w.reshape(n_stages, L // n_stages, *w.shape[1:])

    return jax.tree.map(split, params)


def pipeline_forward(
    layer_fn: Callable,
    staged_params,
    x: jax.Array,
    mesh,
    axis: str = "pipe",
) -> jax.Array:
    """Run ``layer_fn`` over all layers of ``staged_params`` in a GPipe
    schedule on the ``axis`` dim of ``mesh``.

    layer_fn: ``(layer_params, h) -> h`` for a single layer.
    staged_params: output of :func:`stage_params`; leading dim must equal
        the mesh axis size.
    x: (n_micro, microbatch, ...) microbatched inputs.

    Returns (n_micro, microbatch, ...) outputs, numerically identical to
    applying all layers sequentially to each microbatch.
    """
    if axis not in mesh.shape:
        axis = tuple(mesh.shape)[0]
    n_stages = mesh.shape[axis]
    leading = {w.shape[0] for w in jax.tree.leaves(staged_params)}
    if leading != {n_stages}:
        raise ValueError(
            f"staged_params leading dim(s) {sorted(leading)} != pipeline axis "
            f"{axis!r} size {n_stages}; re-split with stage_params(params, "
            f"{n_stages}) or pass the intended mesh axis"
        )
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_apply(params, h):
        def body(carry, layer):
            return layer_fn(layer, carry), None

        out, _ = jax.lax.scan(body, h, params)
        return out

    def per_stage(params, xs):
        # params: (1, layers_per_stage, ...) local shard; xs replicated.
        params = jax.tree.map(lambda w: w[0], params)
        stage = jax.lax.axis_index(axis)

        def tick(t, carry):
            state, outs = carry
            # Stage 0 ingests microbatch t (clipped: the tail ticks feed
            # garbage that can never reach a valid output slot); other
            # stages consume the neighbor's activation from tick t-1.
            inp = jnp.where(
                stage == 0, xs[jnp.clip(t, 0, n_micro - 1)], state
            )
            h = stage_apply(params, inp)
            # The last stage finished microbatch t - (n_stages - 1).
            m = t - (n_stages - 1)
            outs = jnp.where(
                (stage == n_stages - 1) & (m >= 0),
                outs.at[jnp.clip(m, 0, n_micro - 1)].set(h),
                outs,
            )
            state = jax.lax.ppermute(h, axis, perm)
            return state, outs

        state0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (state0, outs0))
        # Outputs live on the last stage (zeros elsewhere): psum
        # replicates them so the caller sees one full array.
        return jax.lax.psum(outs, axis)

    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(staged_params, x)
