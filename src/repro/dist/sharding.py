"""Logical-axis sharding rules and the ambient activation-sharding context.

Model code never names mesh axes. Parameters carry LOGICAL axis names in
their ``ParamSpec.axes`` (``"embed"``, ``"ffn"``, ``"vocab"``, ...);
activations are constrained through :func:`constrain_batch` /
:func:`constrain_logical`. This module owns the single mapping from
logical names to mesh axes (:class:`ShardingRules`) and derives concrete
``PartitionSpec``s from it, with three safety rules applied in order:

  1. axes absent from the mesh are dropped (a single-pod mesh has no
     ``"pod"`` axis — ``act_batch = ("pod", "data")`` degrades to
     ``("data",)``),
  2. a mesh axis is never used twice in one spec (first dim wins),
  3. a dim that is not divisible by the prospective axis-size product is
     progressively relaxed by dropping trailing axes, down to replicated.

The ambient context (:func:`activation_sharding`) carries
``(mesh, dp_axes, seq_axis)`` so that pure model functions can constrain
intermediate activations without threading the mesh through every call.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "FSDP_POD_RULES",
    "PURE_DP_RULES",
    "SP_DECODE_RULES",
    "logical_to_pspec",
    "batch_pspec",
    "make_sharding_fn",
    "activation_sharding",
    "constrain_batch",
    "constrain_logical",
]

# A logical axis maps to: None (replicated), one mesh axis, or an ordered
# tuple of mesh axes (sharded over their product).
AxisRule = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping. One field per logical axis."""

    # parameter axes
    embed: AxisRule = None         # d_model rows (FSDP axis by default)
    embed_out: AxisRule = None     # d_model columns of square projections
    vocab: AxisRule = None
    ffn: AxisRule = None
    ffn_out: AxisRule = None
    heads: AxisRule = None
    head_dim: AxisRule = None
    kv_heads: AxisRule = None
    kv_lora: AxisRule = None       # MLA latent dims
    q_lora: AxisRule = None
    expert: AxisRule = None        # MoE expert dim (EP axis)
    expert_ffn: AxisRule = None
    ssm_heads: AxisRule = None
    ssm_inner: AxisRule = None
    layers: AxisRule = None        # stacked-segment leading dim
    # activation / cache axes
    act_batch: AxisRule = None
    act_kv_seq: AxisRule = None

    def get(self, name: str) -> AxisRule:
        return getattr(self, name, None)

    def replace(self, **kwargs) -> "ShardingRules":
        return dataclasses.replace(self, **kwargs)


# FSDP over the data axis + tensor parallelism over the model axis. The
# batch shards over (pod, data) — the fastest-k worker grain.
DEFAULT_RULES = ShardingRules(
    embed="data",
    embed_out="model",
    vocab="model",
    ffn="model",
    ffn_out="model",
    heads="model",
    kv_heads="model",
    expert="model",
    ssm_heads="model",
    ssm_inner="model",
    act_batch=("pod", "data"),
)

# Pod-wide ZeRO: FSDP axis spans (pod, data) — for the largest configs.
FSDP_POD_RULES = DEFAULT_RULES.replace(embed=("pod", "data"))

# Sequence-parallel KV caches for distributed flash-decode.
SP_DECODE_RULES = DEFAULT_RULES.replace(act_kv_seq="model")

# Pure data parallelism: params replicated, batch over every mesh axis.
PURE_DP_RULES = ShardingRules(act_batch=("pod", "data", "model"))


def _axis_sizes(mesh) -> dict:
    # Works for both jax.sharding.Mesh and lightweight test stubs: only
    # ``mesh.shape`` (an axis-name -> size mapping) is required.
    return dict(mesh.shape)


def _fit_axes(
    candidate: Sequence[str], dim: int, sizes: dict, used: set
) -> Tuple[str, ...]:
    """Filter a candidate mesh-axis tuple against the mesh (rules 1-3)."""
    cand = tuple(a for a in candidate if a in sizes and a not in used)
    def prod(axes):
        p = 1
        for a in axes:
            p *= sizes[a]
        return p
    while cand and dim % prod(cand) != 0:
        cand = cand[:-1]
    return cand


def logical_to_pspec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh,
    rules: ShardingRules,
) -> P:
    """Derive a PartitionSpec for one array from its logical axes."""
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        entry = None
        rule = rules.get(name) if name is not None else None
        if rule is not None:
            cand = _fit_axes((rule,) if isinstance(rule, str) else rule,
                             dim, sizes, used)
            if cand:
                used.update(cand)
                entry = cand[0] if len(cand) == 1 else cand
        entries.append(entry)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def batch_pspec(
    mesh, batch: int, n_trailing: int = 0, *, dp_axes: Optional[Sequence[str]] = None
) -> P:
    """PartitionSpec sharding dim 0 (the batch) over the data-parallel
    axes, with ``n_trailing`` replicated trailing dims."""
    sizes = _axis_sizes(mesh)
    cand = _fit_axes(tuple(dp_axes) if dp_axes is not None else ("pod", "data"),
                     batch, sizes, set())
    entry = None if not cand else (cand[0] if len(cand) == 1 else cand)
    if entry is None:
        return P()
    return P(entry, *(None,) * n_trailing)


def make_sharding_fn(
    mesh, rules: Optional[ShardingRules] = None
) -> Callable[[object], NamedSharding]:
    """Returns ``spec -> NamedSharding`` for ParamSpec-like objects
    (anything with ``.axes`` and ``.shape``)."""
    rules = DEFAULT_RULES if rules is None else rules

    def sharding_for(spec) -> NamedSharding:
        return NamedSharding(
            mesh, logical_to_pspec(spec.axes, spec.shape, mesh, rules)
        )

    return sharding_for


# ---------------------------------------------------------------------------
# Ambient activation-sharding context
# ---------------------------------------------------------------------------

# ActContext or None. Model code reads this through constrain_batch /
# constrain_logical; repro.models.moe reads it directly to size its
# data-parallel dispatch groups.
class ActContext(NamedTuple):
    mesh: object
    dp: Tuple[str, ...]
    seq_axis: Optional[str]
    rules: ShardingRules


_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_act_ctx", default=None
)


@contextlib.contextmanager
def activation_sharding(
    mesh,
    *,
    seq_axis: Optional[str] = None,
    dp_axes: Optional[Sequence[str]] = None,
    rules: Optional[ShardingRules] = None,
):
    """Install the ambient mesh context for activation constraints.

    ``dp_axes``: mesh axes the batch dim shards over (default: whichever
    of ``("pod", "data")`` the mesh has). ``seq_axis``: optional mesh
    axis for Megatron-style sequence-parallel activations. ``rules``:
    the ShardingRules used to resolve parameter-style logical names in
    :func:`constrain_logical` (default DEFAULT_RULES) — pass the run's
    active rules so activation constraints follow rule overrides.
    """
    sizes = _axis_sizes(mesh)
    if dp_axes is None:
        dp = tuple(a for a in ("pod", "data") if a in sizes)
    else:
        dp = tuple(a for a in dp_axes if a in sizes)
    token = _ACT_CTX.set(
        ActContext(mesh, dp, seq_axis, DEFAULT_RULES if rules is None else rules)
    )
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def _constrain(x, entries, mesh):
    while entries and entries[-1] is None:
        entries.pop()
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def constrain_batch(x):
    """Constrain an activation's dim 0 to the ambient data-parallel axes
    (and dim 1 to the ambient sequence axis, when set). No-op outside an
    :func:`activation_sharding` context — model code stays runnable on a
    single device."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, dp, seq_axis = ctx.mesh, ctx.dp, ctx.seq_axis
    sizes = _axis_sizes(mesh)
    used: set = set()
    cand = _fit_axes(dp, x.shape[0], sizes, used)
    entries: list = [None if not cand else (cand[0] if len(cand) == 1 else cand)]
    used.update(cand)
    if x.ndim >= 2 and seq_axis is not None:
        seq = _fit_axes((seq_axis,), x.shape[1], sizes, used)
        entries.append(seq[0] if seq else None)
    return _constrain(x, entries, mesh)


def constrain_logical(x, axes: Sequence[Optional[str]]):
    """Constrain an activation by logical axis names under the ambient
    context. ``act_batch`` resolves to the ambient dp axes and
    ``act_kv_seq`` to the ambient sequence axis; parameter-style names
    (``expert``, ``heads``, ...) resolve through the ambient context's
    ShardingRules. No-op outside an :func:`activation_sharding` context."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, dp, seq_axis = ctx.mesh, ctx.dp, ctx.seq_axis
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = []
    for name, dim in zip(axes, x.shape):
        if name == "act_batch":
            rule: AxisRule = dp
        elif name == "act_kv_seq":
            rule = seq_axis
        elif name is not None:
            rule = ctx.rules.get(name)
        else:
            rule = None
        entry = None
        if rule:
            cand = _fit_axes((rule,) if isinstance(rule, str) else rule,
                             dim, sizes, used)
            if cand:
                used.update(cand)
                entry = cand[0] if len(cand) == 1 else cand
        entries.append(entry)
    return _constrain(x, entries, mesh)
