"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling).

Each kernel is a subpackage: kernel.py (the pallas_call), ops.py (jit'd
public wrapper), ref.py (pure-jnp oracle). All validated in interpret
mode against the oracles across shape/dtype sweeps (tests/test_kernels).

  flash_attention   — GQA/causal flash attention (train/prefill hot path)
  decode_attention  — flash-decode: single query over long KV caches
  ssd_scan          — Mamba2 SSD chunked scan with carried state
  rmsnorm           — fused row-block RMSNorm
"""
