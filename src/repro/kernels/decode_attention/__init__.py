from .ops import decode_ref, flash_decode, paged_decode_ref, paged_flash_decode

__all__ = ["flash_decode", "decode_ref", "paged_flash_decode", "paged_decode_ref"]
