from .ops import decode_ref, flash_decode

__all__ = ["flash_decode", "decode_ref"]
