"""Pallas TPU flash-decode: single-query attention over a long KV cache.

Decode at 32k-500k context is HBM-bound (the roofline table's verdict on
every decode cell): the step reads the whole KV cache once. This kernel
streams the cache HBM->VMEM in blocks on the LAST (sequential) grid dim,
carrying partial softmax statistics (m, l, acc) in VMEM scratch, and
masks beyond the valid length — one pass, no (S,) score materialization
in HBM, MXU-shaped (G x block_kv) @ (block_kv x D) products.

Grid = (B, Hkv, num_kv_blocks); each program owns one (batch, kv-head)
pair and reduces over its query GROUP (GQA: G = H / Hkv queries share a
kv head) so the cache block is read once for all G queries.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, block_kv):
    ikv = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    kv_start = ikv * block_kv

    @pl.when(kv_start < length)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale    # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (G, bkv)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_ids < length, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,        # (B, H, D) — single query position per sequence
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,        # (B, S, Hkv, Dv)
    lengths: jax.Array,  # (B,) valid prefix length per sequence
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)

    block_kv = min(block_kv, S)
    pad = (-S) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_kv = (S + pad) // block_kv

    # Group queries by kv head: (B, Hkv, G, D).
    qg = q.reshape(B, Hkv, G, D)
    lengths = lengths.astype(jnp.int32).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_kv=block_kv),
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ikv: (b, ikv, h, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, ikv: (b, ikv, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ikv: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, lengths)
    return out.reshape(B, H, Dv)
