"""Pallas TPU flash-decode: single-query attention over a long KV cache.

Decode at 32k-500k context is HBM-bound (the roofline table's verdict on
every decode cell): the step reads the whole KV cache once. This kernel
streams the cache HBM->VMEM in blocks on the LAST (sequential) grid dim,
carrying partial softmax statistics (m, l, acc) in VMEM scratch, and
masks beyond the valid length — one pass, no (S,) score materialization
in HBM, MXU-shaped (G x block_kv) @ (block_kv x D) products.

Grid = (B, Hkv, num_kv_blocks); each program owns one (batch, kv-head)
pair and reduces over its query GROUP (GQA: G = H / Hkv queries share a
kv head) so the cache block is read once for all G queries.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_fwd", "paged_decode_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, block_kv):
    ikv = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    kv_start = ikv * block_kv

    @pl.when(kv_start < length)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale    # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bkv, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (G, bkv)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_ids < length, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jax.Array,        # (B, H, D) — single query position per sequence
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,        # (B, S, Hkv, Dv)
    lengths: jax.Array,  # (B,) valid prefix length per sequence
    *,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)

    block_kv = min(block_kv, S)
    if S % block_kv:
        # Padding here would jnp.pad (= copy) the whole K/V cache in HBM
        # on EVERY decode tick. Caches are allocated block-aligned once
        # (``Model.cache_specs`` rounds max_len up to KV_SEQ_ALIGN), so a
        # dividing block always exists — clamp to the largest one instead
        # of copying. A cache with no usable divisor was allocated
        # without the alignment contract: that IS a caller bug.
        block_kv = next(b for b in range(block_kv, 0, -1) if S % b == 0)
        if block_kv < 8:
            raise ValueError(
                f"cache length S={S} has no usable kv block size; allocate "
                "the cache block-aligned (cache_specs rounds max_len up)"
            )
    n_kv = S // block_kv

    # Group queries by kv head: (B, Hkv, G, D).
    qg = q.reshape(B, Hkv, G, D)
    lengths = lengths.astype(jnp.int32).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_kv=block_kv),
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ikv: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ikv: (b, ikv, h, 0)),
            pl.BlockSpec((1, block_kv, 1, Dv), lambda b, h, ikv: (b, ikv, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ikv: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ikv: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, lengths)
    return out.reshape(B, H, Dv)


# ---------------------------------------------------------------------------
# Paged flash-decode: the KV cache is a global block arena + per-sequence
# block tables (vLLM-style). The grid's sequential dim walks TABLE SLOTS,
# not cache rows: the block table is scalar-prefetched (SMEM before the
# body runs) so each K/V BlockSpec index_map gathers the right arena row,
# and slots past ceil(length/block) clamp to the last live block — Pallas
# skips the HBM->VMEM copy when the mapped block index repeats, and
# @pl.when skips the compute. Decode traffic and FLOPs are therefore
# proportional to LIVE tokens, not to n_slots * max_len.
# ---------------------------------------------------------------------------

def _paged_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, block_size):
    b = pl.program_id(0)
    t = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    kv_start = t * block_size

    @pl.when(kv_start < length)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale    # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # (bs, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # (G, bs)
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kv_ids < length, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    @pl.when(t == n_t - 1)
    def _finish():
        # length == 0 leaves l at 0 -> output exactly zeros (the paged
        # oracle mirrors this convention for empty sequences).
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_fwd(
    q: jax.Array,             # (B, H, D) — single query position per sequence
    k_arena: jax.Array,       # (num_blocks + 1, block_size, Hkv, D)
    v_arena: jax.Array,       # (num_blocks + 1, block_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, T) arena indices; 0 = NULL sink block
    lengths: jax.Array,       # (B,) valid prefix length per sequence
    *,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    block_size, Hkv, Dv = k_arena.shape[1], k_arena.shape[2], v_arena.shape[3]
    T = block_tables.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, G, D)
    block_tables = block_tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def kv_map(b, h, t, tab_ref, len_ref):
        # Clamp dead table slots to the last live block: a repeated block
        # index costs no new copy, and the body skips the compute.
        n_live = jax.lax.div(len_ref[b] + block_size - 1, block_size)
        t_eff = jnp.minimum(t, jnp.maximum(n_live - 1, 0))
        return (tab_ref[b, t_eff], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=(B, Hkv, T),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, t, tab, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_size, 1, D), kv_map),
            pl.BlockSpec((1, block_size, 1, Dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, t, tab, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, block_size=block_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_arena, v_arena)
    return out.reshape(B, H, Dv)
