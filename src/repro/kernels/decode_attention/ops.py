from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_fwd, paged_decode_attention_fwd
from .ref import decode_ref, paged_decode_ref

__all__ = ["flash_decode", "paged_flash_decode", "decode_ref", "paged_decode_ref"]


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(q, k, v, lengths, *, block_kv: int = 512,
                 interpret: bool = False):
    return decode_attention_fwd(q, k, v, lengths, block_kv=block_kv,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode(q, k_arena, v_arena, block_tables, lengths, *,
                       interpret: bool = False):
    """Flash-decode over a paged KV arena: walks only each sequence's
    live blocks via the scalar-prefetched block table."""
    return paged_decode_attention_fwd(
        q, k_arena, v_arena, block_tables, lengths, interpret=interpret
    )
