from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_fwd
from .ref import decode_ref

__all__ = ["flash_decode", "decode_ref"]


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(q, k, v, lengths, *, block_kv: int = 512,
                 interpret: bool = False):
    return decode_attention_fwd(q, k, v, lengths, block_kv=block_kv,
                                interpret=interpret)
