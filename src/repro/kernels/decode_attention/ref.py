"""Oracle: the model stack's own masked single-query attention."""

import jax.numpy as jnp

from repro.models.attention import decode_attention as _model_decode


def decode_ref(q, k, v, lengths):
    # model path takes (B, 1, H, D); kernel takes (B, H, D).
    out = _model_decode(q[:, None], k, v, length=lengths)
    return out[:, 0]
