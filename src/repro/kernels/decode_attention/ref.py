"""Oracles: the model stack's own masked single-query attention.

The paged oracle is the exact jnp path the serving engine decodes with
(gather the block-table view, run ``decode_attention``) — so kernel
parity here transitively proves parity with the engine's hot loop.
"""

import jax.numpy as jnp

from repro.models.attention import decode_attention as _model_decode
from repro.models.attention import paged_kv_view


def decode_ref(q, k, v, lengths):
    # model path takes (B, 1, H, D); kernel takes (B, H, D).
    out = _model_decode(q[:, None], k, v, length=lengths)
    return out[:, 0]


def paged_decode_ref(q, k_arena, v_arena, block_tables, lengths):
    """jnp paged decode: contiguous per-sequence views gathered through
    the block table, then the standard masked decode attention. Empty
    sequences (length 0) return zeros, matching the kernel convention
    (the model softmax would spread mass uniformly over garbage there,
    but length 0 never reaches decode — it exists only for tests)."""
    k = paged_kv_view(k_arena, block_tables)
    v = paged_kv_view(v_arena, block_tables)
    out = _model_decode(q[:, None], k, v, length=lengths)[:, 0]
    return jnp.where((lengths > 0)[:, None, None], out, jnp.zeros_like(out))
