"""Pallas TPU flash attention (GQA, causal) — pl.pallas_call + BlockSpec.

TPU-native design (not a CUDA port):
  * grid = (B, H, num_q_blocks, num_kv_blocks); the LAST grid dim is
    sequential on TPU, so the online-softmax state (m, l, acc) lives in
    VMEM scratch carried across kv steps of one (b, h, iq) tile;
  * BlockSpecs stream (block_q x D) query tiles and (block_kv x D) KV
    tiles HBM->VMEM; the MXU sees (block_q x D) @ (D x block_kv) and
    (block_q x block_kv) @ (block_kv x Dv) matmuls — block sizes default
    to 128 to match the 128x128 systolic array;
  * GQA is resolved in the index_map (kv head = q head // group), so no
    KV duplication ever materializes;
  * causal tiles below the diagonal are skipped with pl.when (work
    skipped, not masked), the diagonal tile uses an iota mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
            causal, block_q, block_kv, seq_kv):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    kv_start = ikv * block_kv

    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (bq, bkv)
        # Bounds + causal mask on the diagonal tile.
        kv_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < seq_kv
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (q_ids >= kv_ids)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    if causal:
        # Skip tiles strictly above the causal frontier (work elided,
        # not just masked — the big win for long-context prefill).
        pl.when(kv_start <= q_start + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ikv == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Skv, Hkv, D)
    v: jax.Array,   # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Skv, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_kv
    n_q, n_kv = Sq_p // block_q, Skv_p // block_kv

    grid = (B, H, n_q, n_kv)
    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        seq_kv=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, block_q, 1, D), lambda b, h, iq, ikv: (b, iq, h, 0)
            ),
            pl.BlockSpec(
                (1, block_kv, 1, D), lambda b, h, iq, ikv, G=G: (b, ikv, h // G, 0)
            ),
            pl.BlockSpec(
                (1, block_kv, 1, Dv), lambda b, h, iq, ikv, G=G: (b, ikv, h // G, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, Dv), lambda b, h, iq, ikv: (b, iq, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq_p, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # m (2-D for lanes)
            pltpu.VMEM((block_q, 1), jnp.float32),    # l
            pltpu.VMEM((block_q, Dv), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :Sq]
    return out
