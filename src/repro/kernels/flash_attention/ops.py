"""Jit'd public wrapper for the flash attention kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_fwd
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, block_q: int = 128, block_kv: int = 128,
    interpret: bool = False,
):
    return flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=interpret,
    )
