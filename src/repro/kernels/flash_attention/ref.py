"""Pure-jnp oracle for the flash attention kernel (GQA, optional causal)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Skv, Hkv, D)
    v: jax.Array,   # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, -1).astype(q.dtype)
