"""Pallas TPU fused RMSNorm: one HBM read, one write per row block.

Grid over row blocks; each program normalizes a (rows x D) tile in VMEM.
Bandwidth-bound by design — the point of fusing is to avoid the separate
mean/var/normalize passes XLA sometimes emits around residual adds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_fwd"]


def _kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(
    x: jax.Array,       # (..., D)
    scale: jax.Array,   # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
