from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_fwd
from .ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_ref"]


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    return rmsnorm_fwd(x, scale, eps=eps, block_rows=block_rows,
                       interpret=interpret)
