"""Oracle: the model stack's own rms_norm."""

from repro.models.layers import rms_norm as rmsnorm_ref

__all__ = ["rmsnorm_ref"]
