from .ops import ssd_ref, ssd_scan

__all__ = ["ssd_scan", "ssd_ref"]
