"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid = (batch, heads, n_chunks); the chunk dim is LAST (sequential on
TPU), so the inter-chunk SSM state (N x P) is carried in VMEM scratch —
the recurrence never touches HBM. Per chunk the kernel does three
MXU matmuls ((Q,N)@(N,P), (Q,N)@(N,Q), (Q,Q)@(Q,P)) plus a cumulative-
decay mask, which is exactly the SSD "dual" form mapped onto the
128x128 systolic array (Q = chunk = 128 by default).

Inputs are pre-activated: dt already softplus'd (+bias), A = -exp(a_log).
The D-skip and gating stay in the surrounding jnp block (cheap,
bandwidth-bound there anyway).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_fwd"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    A = a_ref[0, 0]                                  # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)

    a = dt * A                                       # (Q,)
    a_cum = jnp.cumsum(a)
    a_total = a_cum[-1]

    state = state_ref[...]                           # (N, P)

    # Inter-chunk: y_i = exp(a_cum_i) * C_i @ state_in.
    y_inter = jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (Q, P)

    # Intra-chunk: scores = (C B^T) o L, y += scores @ (dt * x).
    seg = a_cum[:, None] - a_cum[None, :]            # (Q, Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(iq >= jq, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L                                            # (Q, Q)
    xdt = x * dt[:, None]
    y = y_inter + jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # State update: S <- exp(a_total) S + B^T @ (exp(a_total - a_cum) dt x).
    w = jnp.exp(a_total - a_cum) * dt                # (Q,)
    state_ref[...] = jnp.exp(a_total) * state + jax.lax.dot_general(
        Bm, x * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan_fwd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)  — softplus'd
    A: jax.Array,    # (H,)       — negative
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # Pad dt with ZEROS: decay exp(0*A)=1, update dt*...=0 — inert.
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    A2 = A.reshape(H, 1).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, hg=hg: (b, c, h // hg, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c, hg=hg: (b, c, h // hg, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A2, Bm, Cm)
    if pad:
        out = out[:, :S]
    return out
