"""Jit'd public wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import ssd_scan_fwd
from .ref import ssd_ref

__all__ = ["ssd_scan", "ssd_ref"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    return ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
