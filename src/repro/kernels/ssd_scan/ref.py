"""Oracle for the SSD kernel: the validated step-by-step recurrence."""

from repro.models.mamba2 import ssd_recurrent


def ssd_ref(x, dt, A, Bm, Cm):
    y, _ = ssd_recurrent(x, dt, A, Bm, Cm)
    return y
