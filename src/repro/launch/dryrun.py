import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, WITHOUT allocating real arrays:
  * compiled.memory_analysis()  — proves the per-device footprint,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * collective byte counts      — parsed from the compiled HLO text,
and writes one JSON artifact per cell under artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k [--multi-pod] [--variant baseline]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.analysis.hlo_cost import analyze_hlo
from repro.configs import SHAPES, cell_status, get_config, list_archs
from repro.configs.base import ModelConfig
from repro.dist.sharding import (
    DEFAULT_RULES,
    FSDP_POD_RULES,
    PURE_DP_RULES,
    SP_DECODE_RULES,
    ShardingRules,
    activation_sharding,
    make_sharding_fn,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    abstract_state,
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models.model import Model
from repro.optim.optimizers import get_optimizer
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def rules_for(cfg: ModelConfig, variant: str, kind: str) -> ShardingRules:
    if variant == "pure_dp":
        return PURE_DP_RULES
    rules = DEFAULT_RULES
    if cfg.name.startswith("deepseek"):
        rules = rules.replace(embed=("pod", "data"))  # pod-wide ZeRO for 671B
    if kind == "decode" and variant != "no_sp_decode":
        # Sequence-parallel KV caches: the only way 32k x 128 caches fit
        # when kv_heads < the model-axis width (distributed flash-decode).
        rules = rules.replace(act_kv_seq="model")
    return rules


def dp_axes_for(variant: str):
    return ("pod", "data", "model") if variant == "pure_dp" else None


def accum_for(cfg: ModelConfig, kind: str, variant: str = "baseline") -> int:
    """Gradient-accumulation microbatches for train cells (memory)."""
    if kind != "train":
        return 1
    if variant in ("zero1_state_noseq", "accum8"):
        return 8
    if cfg.param_count() > 100e9:
        return 8
    if cfg.d_model >= 8192:
        return 4
    return 1


def seq_axis_for(cfg: ModelConfig, kind: str, variant: str):
    # Megatron-style sequence-parallel activations for the wide archs.
    if variant in ("no_seq_shard", "zero1_state_noseq"):
        return None
    if kind == "train" and cfg.d_model >= 4096:
        return "model"
    return None


def optimizer_for(cfg: ModelConfig):
    # Adafactor for the giant configs (fits 16 GB/chip), AdamW elsewhere.
    if cfg.param_count() > 20e9:
        return get_optimizer("adafactor")
    return get_optimizer("adamw")


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    import dataclasses

    if variant == "baseline":
        return cfg
    if variant == "mla_absorb":
        return dataclasses.replace(cfg, mla_absorb=True)
    if variant == "mla_materialize":
        return dataclasses.replace(cfg, mla_absorb=False)
    if variant == "no_remat":
        return dataclasses.replace(cfg, remat="none")
    if variant == "selective_remat":
        return dataclasses.replace(cfg, remat="selective")
    if variant in ("moe_ep", "moe_grouped"):
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, dispatch="model" if variant == "moe_ep" else "grouped"
            )
        )
    if variant in ("sp_decode", "no_sp_decode", "seq_shard", "no_seq_shard",
                   "zero1", "zero1_state", "zero1_state_noseq", "pure_dp",
                   "accum8"):
        return cfg
    raise ValueError(f"unknown variant {variant}")


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    variant: str = "baseline",
    save: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_status(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{cfg.name}__{shape_name}__{mesh_name}__{variant}"
    if skip is not None:
        result = {"cell": cell_id, "status": "SKIP", "reason": skip}
        if save:
            _save(result)
        return result

    cfg = apply_variant(cfg, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, variant, shape.kind)
    model = Model(cfg)
    t0 = time.time()

    seq_axis = seq_axis_for(cfg, shape.kind, variant)
    accum = accum_for(cfg, shape.kind, variant)
    with jax.set_mesh(mesh), activation_sharding(
        mesh, seq_axis=seq_axis, dp_axes=dp_axes_for(variant), rules=rules
    ):
        if shape.kind == "train":
            optimizer = optimizer_for(cfg)
            if variant.startswith("zero1_state"):
                # TRUE ZeRO-1: the param STATE lives TP-only (replicated
                # over data — affordable for <100B at 256 chips); only the
                # optimizer state + gradient flow stay FSDP-sharded. No
                # per-layer weight gathers exist at all.
                g_rules = rules.replace(embed=None)
                params, _ = abstract_state(model, mesh, g_rules)
                _, opt_state = abstract_state(model, mesh, rules, optimizer)
            else:
                params, opt_state = abstract_state(model, mesh, rules, optimizer)
            accum_dtype = (
                jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32
            )
            gather_shardings = None
            if variant.startswith("zero1_state"):
                # pin grads to the FSDP layout -> reduce-scatter at the
                # boundary; optimizer update runs on shards.
                fsdp_shardings = jax.tree.map(
                    lambda sp: make_sharding_fn(mesh, rules)(sp),
                    model.param_specs(),
                    is_leaf=lambda x: hasattr(x, "axes"),
                )
                step = make_train_step(
                    model, optimizer, accum_steps=accum,
                    accum_dtype=accum_dtype,
                    param_shardings=fsdp_shardings,
                )
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    params, opt_state,
                    train_input_specs(cfg, shape, mesh, rules=rules),
                )
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                return _finish(cfg, shape, mesh, rules, variant, cell_id,
                               mesh_name, compiled, t_lower, t_compile,
                               accum, seq_axis, save)
            if variant == "zero1":
                # ZeRO-1: gather weights once per step (to the TP-only
                # layout), reduce-scatter grads back to the FSDP layout.
                g_rules = rules.replace(embed=None)
                gather_shardings = jax.tree.map(
                    lambda sp: make_sharding_fn(mesh, g_rules)(sp),
                    model.param_specs(),
                    is_leaf=lambda x: hasattr(x, "axes"),
                )
            step = make_train_step(
                model, optimizer, accum_steps=accum, accum_dtype=accum_dtype,
                param_shardings=jax.tree.map(lambda p: p.sharding, params),
                gather_shardings=gather_shardings,
            )
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt_state, train_input_specs(cfg, shape, mesh, rules=rules)
            )
        elif shape.kind == "prefill":
            params, _ = abstract_state(model, mesh, rules)
            step = make_prefill_step(model)
            lowered = jax.jit(step).lower(
                params, **prefill_input_specs(cfg, shape, mesh)
            )
        else:  # decode
            params, _ = abstract_state(model, mesh, rules)
            step = make_decode_step(model)
            ins = decode_input_specs(cfg, shape, mesh, rules)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params, ins["token"], ins["caches"], ins["cache_index"]
            )
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    return _finish(cfg, shape, mesh, rules, variant, cell_id, mesh_name,
                   compiled, t_lower, t_compile, accum, seq_axis, save)


def _finish(cfg, shape, mesh, rules, variant, cell_id, mesh_name, compiled,
            t_lower, t_compile, accum, seq_axis, save):
    shape_name = shape.name
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    loop_cost = analyze_hlo(hlo)  # loop-aware (XLA counts while bodies once)

    n_devices = mesh.size
    result = {
        "cell": cell_id,
        "status": "OK",
        "arch": cfg.name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "variant": variant,
        "n_devices": n_devices,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "accum_steps": accum,
        "seq_axis": seq_axis,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
            ),
        },
        "cost": {
            # XLA's own numbers (while bodies counted ONCE — kept for
            # reference) and the loop-aware re-analysis used by §Roofline.
            "xla_flops": cost.get("flops") if cost else None,
            "xla_bytes_accessed": cost.get("bytes accessed") if cost else None,
            "flops": loop_cost.flops,
            "hbm_bytes": loop_cost.hbm_bytes,
            "unknown_trip_counts": loop_cost.unknown_trip_counts,
        },
        "collectives": loop_cost.as_dict()["collective_bytes"],
        "collective_counts": loop_cost.as_dict()["collective_counts"],
        "collective_top_sources": [
            [src, b] for src, b in loop_cost.top_collective_sources(10)
        ],
    }
    if save:
        _save(result)
    return result


def _save(result: dict):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = ARTIFACTS / f"{result['cell']}.json"
    path.write_text(json.dumps(result, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", type=str, default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    failures = 0
    for arch, shape_name in cells:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        cfg_name = get_config(arch).name
        cell_id = f"{cfg_name}__{shape_name}__{mesh_name}__{args.variant}"
        if args.skip_existing and (ARTIFACTS / f"{cell_id}.json").exists():
            prev = json.loads((ARTIFACTS / f"{cell_id}.json").read_text())
            print(f"[cached] {cell_id}: {prev['status']}", flush=True)
            continue
        try:
            r = dryrun_cell(
                arch, shape_name, multi_pod=args.multi_pod, variant=args.variant
            )
            if r["status"] == "OK":
                mem_gb = r["memory"]["peak_bytes"] / 2**30
                print(
                    f"[ok] {cell_id}: {mem_gb:.2f} GiB/device, "
                    f"flops={r['cost']['flops']:.3e}, "
                    f"hbm={r['cost']['hbm_bytes']:.3e}, "
                    f"coll={sum(r['collectives'].values())/2**30:.3f} GiB "
                    f"(lower {r['lower_s']}s compile {r['compile_s']}s)",
                    flush=True,
                )
            else:
                print(f"[skip] {cell_id}: {r['reason']}", flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures += 1
            print(f"[FAIL] {cell_id}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
