"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — the dry-run
must set XLA_FLAGS before any jax call).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (requires
    --xla_force_host_platform_device_count >= prod(shape))."""
    return jax.make_mesh(shape, axes)
