import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Perf probe: compile one cell and attribute collective/HBM traffic to
source jax ops — the dry-run profiler used by the §Perf iteration loop.

  PYTHONPATH=src python -m repro.launch.perf_probe --arch chameleon-34b \
      --shape train_4k [--variant baseline] [--multi-pod]
"""

import argparse

from repro.launch import dryrun as dr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()

    import jax

    from repro.analysis.hlo_cost import analyze_hlo
    from repro.configs import SHAPES, get_config
    from repro.dist.sharding import activation_sharding
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import (
        abstract_state,
        decode_input_specs,
        prefill_input_specs,
        train_input_specs,
    )
    from repro.models.model import Model
    from repro.runtime.steps import (
        make_decode_step,
        make_prefill_step,
        make_train_step,
    )

    cfg = dr.apply_variant(get_config(args.arch), args.variant)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = dr.rules_for(cfg, args.variant, shape.kind)
    model = Model(cfg)
    seq_axis = dr.seq_axis_for(cfg, shape.kind, args.variant)
    accum = dr.accum_for(cfg, shape.kind)

    import jax.numpy as jnp

    with jax.set_mesh(mesh), activation_sharding(mesh, seq_axis=seq_axis,
                                                 rules=rules):
        if shape.kind == "train":
            opt = dr.optimizer_for(cfg)
            params, opt_state = abstract_state(model, mesh, rules, opt)
            step = make_train_step(
                model, opt, accum_steps=accum,
                accum_dtype=jnp.bfloat16 if cfg.param_count() > 100e9 else jnp.float32,
                param_shardings=jax.tree.map(lambda p: p.sharding, params),
            )
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt_state, train_input_specs(cfg, shape, mesh)
            ).compile()
        elif shape.kind == "prefill":
            params, _ = abstract_state(model, mesh, rules)
            compiled = jax.jit(make_prefill_step(model)).lower(
                params, **prefill_input_specs(cfg, shape, mesh)
            ).compile()
        else:
            params, _ = abstract_state(model, mesh, rules)
            ins = decode_input_specs(cfg, shape, mesh, rules)
            compiled = jax.jit(make_decode_step(model), donate_argnums=(2,)).lower(
                params, ins["token"], ins["caches"], ins["cache_index"]
            ).compile()

    cost = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    print(f"\ncell: {cfg.name} x {args.shape} ({'pod2' if args.multi_pod else 'pod1'}) "
          f"variant={args.variant}")
    print(f"peak GiB/dev: {(mem.temp_size_in_bytes + mem.argument_size_in_bytes)/2**30:.2f}")
    print(f"flops/dev: {cost.flops:.3e}  hbm/dev: {cost.hbm_bytes:.3e}  "
          f"coll/dev: {cost.total_collective_bytes():.3e}")
    print(f"\ntop collective sources (GiB/device/step):")
    for src, b in cost.top_collective_sources(args.top):
        print(f"  {b/2**30:9.2f}  {src[:140]}")


if __name__ == "__main__":
    main()
