"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No allocation: the dry-run lowers against these. Shardings are attached
here so ``jit(...).lower(**specs)`` sees the production layout.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.dist.sharding import ShardingRules, batch_pspec, make_sharding_fn
from repro.models.layers import DTYPES, ParamSpec, abstract_from_specs
from repro.models.model import Model

__all__ = ["train_input_specs", "prefill_input_specs", "decode_input_specs",
           "abstract_state", "n_workers_for"]


def n_workers_for(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))


def train_input_specs(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *, beta: float = 1.0,
    rules: ShardingRules = None,
) -> Dict[str, Any]:
    """Batch stand-ins for train_step. beta scales the per-worker batch
    (the paper's computation-load knob; changes the compiled shape)."""
    n = n_workers_for(mesh)
    B = shape.global_batch
    per_worker = max(int(round(B * beta)) // n, 1)
    Bb = per_worker * n
    S = shape.seq_len
    dp = None
    if rules is not None:
        ab = rules.get("act_batch")
        if ab is not None:
            dp = (ab,) if isinstance(ab, str) else tuple(ab)
    if cfg.input_kind == "tokens":
        inputs = _sds((Bb, S), jnp.int32, mesh, batch_pspec(mesh, Bb, 1, dp_axes=dp))
    else:
        inputs = _sds((Bb, S, cfg.d_model), DTYPES[cfg.dtype], mesh,
                      batch_pspec(mesh, Bb, 2, dp_axes=dp))
    return {
        "inputs": inputs,
        "labels": _sds((Bb, S), jnp.int32, mesh, batch_pspec(mesh, Bb, 1, dp_axes=dp)),
        "worker_mask": _sds((n,), jnp.float32, mesh, P()),
        "lr": jax.ShapeDtypeStruct((), jnp.float32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_kind == "tokens":
        inputs = _sds((B, S), jnp.int32, mesh, batch_pspec(mesh, B, 1))
    else:
        inputs = _sds((B, S, cfg.d_model), DTYPES[cfg.dtype], mesh,
                      batch_pspec(mesh, B, 2))
    return {"inputs": inputs}


def decode_input_specs(
    cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules: ShardingRules
):
    """One-token decode against a cache of length shape.seq_len."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    token = _sds((B, 1), jnp.int32, mesh, batch_pspec(mesh, B, 1))
    caches = abstract_from_specs(
        model.cache_specs(B, S), make_sharding_fn(mesh, rules)
    )
    return {
        "token": token,
        "caches": caches,
        "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def abstract_state(model: Model, mesh: Mesh, rules: ShardingRules, optimizer=None):
    """Abstract (params, opt_state) with production shardings attached."""
    params = model.abstract_params(make_sharding_fn(mesh, rules))
    if optimizer is None:
        return params, None
    opt_state = jax.eval_shape(optimizer.init, params)

    # eval_shape loses shardings; attach by matching shapes against params.
    # Exact-shape matches cover adam m/v; adafactor factored rows
    # (p.shape[:-1]) and cols (p.shape[:-2] + p.shape[-1:]) inherit the
    # param's pspec with the corresponding dim removed.
    param_leaves = jax.tree.leaves(params)
    by_shape = {}
    row_shapes = {}
    col_shapes = {}
    for p in param_leaves:
        by_shape.setdefault(p.shape, p.sharding)
        spec = tuple(p.sharding.spec) + (None,) * (len(p.shape) - len(p.sharding.spec))
        if len(p.shape) >= 2:
            row_shapes.setdefault(p.shape[:-1], P(*spec[:-1]))
            col_shapes.setdefault(
                p.shape[:-2] + p.shape[-1:], P(*(spec[:-2] + spec[-1:]))
            )

    def attach(x):
        if not hasattr(x, "shape"):
            return x
        sh = by_shape.get(x.shape)
        if sh is None and x.shape in row_shapes:
            sh = NamedSharding(mesh, row_shapes[x.shape])
        if sh is None and x.shape in col_shapes:
            sh = NamedSharding(mesh, col_shapes[x.shape])
        if sh is None:
            sh = NamedSharding(mesh, P())
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)

    opt_state = jax.tree.map(attach, opt_state)
    return params, opt_state
