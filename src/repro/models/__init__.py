"""Model zoo: composable JAX definitions for all assigned architectures."""

from .model import Model, build_model, count_params_analytic

__all__ = ["Model", "build_model", "count_params_analytic"]
