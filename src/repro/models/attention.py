"""Attention: GQA (with rope, qk-norm, bias options) and DeepSeek MLA.

Three execution paths share one set of weights:
  * train/prefill: memory-efficient chunked attention (lax.scan over KV
    chunks with online softmax) — O(seq * chunk) activation memory, which
    is what makes the 32k-prefill cells lowerable; optionally routed to
    the Pallas flash kernel (cfg.use_pallas) on TPU.
  * decode: single-query attention against a KV cache, with optional
    sequence-parallel cache (shard the cache over 'model', merge partial
    softmax statistics with psum — flash-decode style).

KV caches are plain pytrees: {"k": (B, S, Hkv, D), "v": ...} for GQA and
{"ckv": (B, S, r_kv), "k_rope": (B, S, r_qk)} for MLA (the latent cache is
exactly MLA's memory saving).

Serving additionally supports PAGED caches (vLLM-style): each leaf's
(batch, seq) front is replaced by a global block arena
(num_blocks + 1, block_size, ...), and a per-sequence ``block_table``
(B, T) of arena indices says which rows belong to whom. Row 0 of the
arena is the reserved NULL sink: never allocated, it absorbs writes from
masked/dead lanes and backs unallocated table entries, so paged updates
need no per-slot masking. The paged decode/prefill paths gather a
contiguous per-sequence view and run the *same* attention math as the
contiguous paths — aligned geometry (``block_size`` dividing the rounded
``max_len``) makes the views shape- and bit-identical, which is the
token-equivalence contract the serve tests enforce.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from .layers import ParamSpec, apply_rope, norm_apply, norm_specs

NEG_INF = -1e30

#: KV cache sequence axes are rounded up to this multiple at allocation
#: time so the flash-decode kernel never pads (= copies) the cache in HBM
#: on the hot path, and so paged block sizes divide the row count evenly.
KV_SEQ_ALIGN = 16

#: Arena row reserved as the write sink for masked/dead lanes and the
#: target of unallocated block-table entries. Never handed out by the
#: BlockManager; its contents are garbage and are never read unmasked.
NULL_BLOCK = 0


def round_kv_len(max_len: int, block: int = KV_SEQ_ALIGN) -> int:
    """Round a cache capacity up to the kernel/paging block multiple."""
    return -(-int(max_len) // block) * block


def paged_kv_view(arena: jax.Array, block_table: jax.Array) -> jax.Array:
    """Gather a contiguous per-sequence view (B, T*block_size, ...) out of
    a block arena (num_blocks+1, block_size, ...) via ``block_table``
    (B, T). Rows past each sequence's length are whatever stale/null
    blocks the table points at — callers mask by length, exactly like the
    contiguous decode paths mask their dead tail rows."""
    g = arena[block_table]  # (B, T, block_size, ...)
    return g.reshape(block_table.shape[0], -1, *arena.shape[2:])


def cache_row_update(
    cache: jax.Array,
    new: jax.Array,
    idx: jax.Array,
    *,
    block_table: Optional[jax.Array] = None,
) -> jax.Array:
    """Write ``new`` (B, S_new, ...) into ``cache`` (B, S, ...) at sequence
    offset ``idx`` — scalar (all rows share one write position: classic
    decode) or per-row ``(B,)`` (slot-pooled serving, where every sequence
    in the batch sits at its own length).

    With ``block_table`` (B, T), ``cache`` is a block arena
    (num_blocks+1, block_size, ...) and the single decode row
    (S_new == 1) is scattered to ``arena[table[b, idx//bs], idx % bs]``.
    Dead lanes carry NULL table entries, so their writes land in the sink
    block — no per-slot masking needed.

    Copy-on-write contract (prefix sharing, DESIGN.md §16): the scatter
    writes blindly through the table, so the CALLER must guarantee every
    targeted block is private (refcount 1) — the pool's
    ``ensure_writable`` forks shared blocks (table swap + device copy)
    before the write reaches here. This function stays fork-oblivious by
    design: forking on the host keeps the jitted scatter shape-stable."""
    new = new.astype(cache.dtype)
    if block_table is not None:
        bs = cache.shape[1]
        B = block_table.shape[0]
        idx = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (B,))
        bid = jnp.take_along_axis(block_table, (idx // bs)[:, None], axis=1)[:, 0]
        return cache.at[bid, idx % bs].set(new[:, 0])
    if jnp.ndim(idx) == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new, idx, axis=1)
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )(cache, new, idx)


def cache_rows_update(
    cache: jax.Array,
    new: jax.Array,
    start: jax.Array,
    *,
    block_table: Optional[jax.Array] = None,
    n_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Bulk prefill write: ``new`` (B, P, ...) rows land at sequence
    positions ``start + [0, P)``. Contiguous caches take one dynamic
    slice update; paged arenas scatter every row through the block table
    (positions whose table entry is still NULL — pad-bucket overhang past
    the reserved blocks — fall into the sink block).

    ``start`` may be per-row ``(B,)`` (speculative verify: every slot
    sits at its own length), in which case the contiguous path switches
    to a scatter whose out-of-bounds rows are DROPPED, never clamped —
    an XLA-clamped write start would silently overwrite valid rows.
    ``n_valid`` (B,) marks how many of the P rows are real per sequence;
    rows past it are dropped (contiguous) or routed to the NULL sink
    (paged), so one fixed-shape verify call can carry ragged per-slot
    draft lengths as data.

    Copy-on-write contract: same as ``cache_row_update`` — callers must
    fork shared blocks in ``[start, start + n_valid)`` first
    (``SlotPool.ensure_writable``). Adopted prefix blocks always sit
    BELOW the write start (prefill resumes after the adopted rows), so
    under the serving engine the only shared row a prefill chunk can
    touch is the full-match re-feed, which forks before the call."""
    new = new.astype(cache.dtype)
    B, P = new.shape[:2]
    start = jnp.asarray(start, jnp.int32)
    if block_table is None:
        if start.ndim == 0 and n_valid is None:
            return jax.lax.dynamic_update_slice_in_dim(cache, new, start, axis=1)
        pos = jnp.broadcast_to(start.reshape(-1, 1), (B, 1)) + jnp.arange(P)
        if n_valid is not None:
            # Out-of-range row index -> scatter-drop.
            pos = jnp.where(jnp.arange(P)[None, :] < n_valid[:, None],
                            pos, cache.shape[1])
        b_idx = jnp.repeat(jnp.arange(B), P)
        rows = new.reshape(B * P, *new.shape[2:])
        return cache.at[b_idx, pos.reshape(-1)].set(rows, mode="drop")
    bs = cache.shape[1]
    if start.ndim == 0:
        pos = start + jnp.arange(P)                   # (P,)
        bid = block_table[:, pos // bs]               # (B, P) gather
        off = jnp.broadcast_to(pos % bs, (B, P))
    else:
        pos = start[:, None] + jnp.arange(P)          # (B, P)
        slot = jnp.clip(pos // bs, 0, block_table.shape[1] - 1)
        bid = jnp.take_along_axis(block_table, slot, axis=1)
        off = pos % bs
    if n_valid is not None:
        # Rows past each sequence's valid count land in the NULL sink.
        bid = jnp.where(jnp.arange(P)[None, :] < n_valid[:, None],
                        bid, NULL_BLOCK)
    rows = new.reshape(B * P, *new.shape[2:])
    return cache.at[bid.reshape(-1), off.reshape(-1)].set(rows)


def decode_lengths(idx: jax.Array, batch: int) -> jax.Array:
    """Valid-prefix lengths (B,) after writing one token at ``idx``."""
    return jnp.broadcast_to(idx + 1, (batch,)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# GQA specs
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype
    out = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"), "scaled", dt),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), "scaled", dt),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim"), "scaled", dt),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"), "scaled", dt),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros", dt)
        out["bk"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), "zeros", dt)
        out["bv"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"), "zeros", dt)
    if cfg.qk_norm:
        out["q_norm"] = norm_specs(hd, "rmsnorm", dt)
        out["k_norm"] = norm_specs(hd, "rmsnorm", dt)
    return out


def _project_qkv(params: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = norm_apply(params["q_norm"], q, "rmsnorm")
        k = norm_apply(params["k_norm"], k, "rmsnorm")
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Memory-efficient chunked attention (pure jnp oracle / baseline path)
# ---------------------------------------------------------------------------

def mea_attention(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Skv, Hkv, D)
    v: jax.Array,          # (B, Skv, Hkv, D)
    *,
    causal: bool,
    chunk: int,
    q_offset: jax.Array = 0,  # absolute position of q[0]: scalar, or (B,)
                              # per-row starts (speculative verify)
) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks.

    Supports distinct K and V head dims (MLA: qk=192, v=128).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, Hkv, G, D)

    chunk = min(chunk, Skv)
    n_chunks = math.ceil(Skv / chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.astype(jnp.float32).reshape(B, n_chunks, chunk, Hkv, Dv)

    q_offset = jnp.asarray(q_offset, jnp.int32)
    q_pos = q_offset[..., None] + jnp.arange(Sq)   # (Sq,) or (B, Sq)

    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        # scores: (B, Sq, Hkv, G, chunk)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qf, kj)
        kv_pos = j * chunk + jnp.arange(chunk)
        valid = kv_pos < Skv
        if causal:
            valid = valid & (q_pos[..., :, None] >= kv_pos)  # (…, Sq, chunk)
            if valid.ndim == 2:
                valid = valid[None]
            s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        else:
            s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bqhgc,bchd->bqhgd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, Hkv, G, Dv), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    # Remat each chunk: backward recomputes the (B,Sq,H,chunk) score tile
    # instead of saving it — the chunked-attention memory win would
    # otherwise be lost to autodiff residuals (flash-attention recompute).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0), (kc_t, vc_t, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,          # (B, 1, H, D)
    k: jax.Array,          # (B, S, Hkv, D) — cache incl. current token
    v: jax.Array,
    *,
    length: Optional[jax.Array] = None,  # valid prefix length per batch elt
) -> jax.Array:
    """Single-token attention against the full cache (decode hot path)."""
    B, _, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = (q * scale).astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    if length is not None:
        pos = jnp.arange(S)
        s = jnp.where(pos[None, None, None, :] < length[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def gqa_apply(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    block_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full GQA block. With a cache, runs one-token decode and returns the
    updated cache; without, runs train/prefill chunked attention. With a
    ``block_table`` the cache leaves are paged arenas; decode attends
    against the gathered per-sequence view — same math, same bits."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    if cache is None:
        causal = cfg.causal and not cfg.is_encoder
        if cfg.use_pallas:
            # TPU hot path: the Pallas flash kernel (interpret=True turns
            # it into a CPU-executable reference for tests/dev boxes).
            from repro.kernels.flash_attention import flash_attention

            interpret = jax.default_backend() != "tpu"
            out = flash_attention(q, k, v, causal=causal, interpret=interpret)
        else:
            out = mea_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
        new_cache = None
    else:
        idx = cache_index  # int32 write position: scalar or per-row (B,)
        ck = cache_row_update(cache["k"], k, idx, block_table=block_table)
        cv = cache_row_update(cache["v"], v, idx, block_table=block_table)
        if block_table is not None:
            kv_k, kv_v = paged_kv_view(ck, block_table), paged_kv_view(cv, block_table)
        else:
            kv_k, kv_v = ck, cv
        out = decode_attention(q, kv_k, kv_v, length=decode_lengths(idx, x.shape[0]))
        new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def gqa_prefill(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Dict,
    start_index: jax.Array,
    block_table: Optional[jax.Array] = None,
    n_valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Cache-writing batched prefill: project the whole (B, S) chunk once,
    write its K/V rows at ``start_index``, and attend causally against the
    cache (rows past the chunk are masked by causality, rows before it are
    an earlier chunk's prefix — chunked-prefill continuation is free).
    Paged mode scatters the chunk's rows through the block table (bulk
    block writes) and attends against the gathered view. ``start_index``
    may be per-row (B,) with ``n_valid`` marking each row's real token
    count (speculative verify; see ``cache_rows_update``)."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    ck = cache_rows_update(cache["k"], k, start_index,
                           block_table=block_table, n_valid=n_valid)
    cv = cache_rows_update(cache["v"], v, start_index,
                           block_table=block_table, n_valid=n_valid)
    if block_table is not None:
        kv_k, kv_v = paged_kv_view(ck, block_table), paged_kv_view(cv, block_table)
    else:
        kv_k, kv_v = ck, cv
    out = mea_attention(
        q, kv_k, kv_v, causal=True, chunk=cfg.attn_chunk, q_offset=start_index
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": ck, "v": cv}


def gqa_cache_spec(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    page: Optional[Tuple[int, int]] = None,
) -> Dict[str, ParamSpec]:
    """``page=(num_blocks, block_size)`` swaps the per-slot (batch, seq)
    stripe for a global arena (num_blocks + 1, block_size, ...) — one
    extra row for the NULL sink block."""
    if page is not None:
        num_blocks, block_size = page
        shape = (num_blocks + 1, block_size, cfg.n_kv_heads, cfg.head_dim)
        axes = ("kv_blocks", "kv_block", "kv_heads", "head_dim")
    else:
        shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        axes = ("act_batch", "act_kv_seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec(shape, axes, "zeros", cfg.dtype),
        "v": ParamSpec(shape, axes, "zeros", cfg.dtype),
    }


# ---------------------------------------------------------------------------
# DeepSeek MLA
# ---------------------------------------------------------------------------

def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = cfg.dtype
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora"), "scaled", dt),
        "q_norm": norm_specs(m.q_lora_rank, "rmsnorm", dt),
        "wq_b": ParamSpec(
            (m.q_lora_rank, h, qk_dim), ("q_lora", "heads", "head_dim"), "scaled", dt
        ),
        "wkv_a": ParamSpec(
            (d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora"), "scaled", dt
        ),
        "kv_norm": norm_specs(m.kv_lora_rank, "rmsnorm", dt),
        "wkv_b": ParamSpec(
            (m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim),
            ("kv_lora", "heads", "head_dim"),
            "scaled",
            dt,
        ),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed"), "scaled", dt),
    }


def _mla_qkv(params: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    m: MLAConfig = cfg.mla
    # Query path.
    q_lat = norm_apply(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]), "rmsnorm")
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    # Latent KV path.
    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = norm_apply(params["kv_norm"], ckv, "rmsnorm")
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    return q_nope, q_rope, ckv, k_rope


def _mla_expand_kv(params: Dict, ckv: jax.Array, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    kv = jnp.einsum("bsr,rhk->bshk", ckv, params["wkv_b"])
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def mla_apply(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    absorb: bool = False,
    block_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """MLA attention. ``absorb=True`` runs decode in latent space (the
    W_UK/W_UV absorption trick) — a §Perf optimization, baseline expands."""
    m: MLAConfig = cfg.mla
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, x, cfg, positions)
    B = x.shape[0]

    if cache is None:
        k_nope, v = _mla_expand_kv(params, ckv, cfg)
        H = cfg.n_heads
        k_rope_b = jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, m.qk_rope_head_dim))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        out = mea_attention(q_full, k_full, v, causal=True, chunk=cfg.attn_chunk)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
        return y, None

    # Decode: cache holds the LATENT stream (B, S, r_kv) + rope keys.
    idx = cache_index
    new_cache = {
        "ckv": cache_row_update(cache["ckv"], ckv, idx, block_table=block_table),
        "k_rope": cache_row_update(
            cache["k_rope"], k_rope[:, :, 0, :], idx, block_table=block_table
        ),
    }
    if block_table is not None:
        c_ckv = paged_kv_view(new_cache["ckv"], block_table)
        c_rope = paged_kv_view(new_cache["k_rope"], block_table)
    else:
        c_ckv, c_rope = new_cache["ckv"], new_cache["k_rope"]
    S = c_ckv.shape[1]
    length = decode_lengths(idx, B)
    pos_mask = jnp.arange(S)[None, :] < length[:, None]

    if absorb:
        # q_nope absorbed through W_UK: scores in latent space, rank r_kv.
        wkv_b = params["wkv_b"]  # (r, H, nope+v)
        w_uk = wkv_b[:, :, : m.qk_nope_head_dim]      # (r, H, nope)
        w_uv = wkv_b[:, :, m.qk_nope_head_dim:]       # (r, H, v)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk)  # (B,1,H,r)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        s = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), c_ckv.astype(jnp.float32))
            + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), c_rope.astype(jnp.float32))
        ) * scale
        s = jnp.where(pos_mask[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p, c_ckv.astype(jnp.float32))
        out = jnp.einsum("bshr,rhk->bshk", o_lat.astype(x.dtype), w_uv)
    else:
        # Baseline: expand the whole latent cache to per-head K/V each step.
        k_nope, v = _mla_expand_kv(params, c_ckv, cfg)
        H = cfg.n_heads
        k_rope_b = jnp.broadcast_to(
            c_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim)
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        scale = 1.0 / math.sqrt(q_full.shape[-1])
        s = jnp.einsum(
            "bshk,bthk->bhst", (q_full * scale).astype(jnp.float32), k_full.astype(jnp.float32)
        )
        s = jnp.where(pos_mask[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhst,bthk->bshk", p, v.astype(jnp.float32)).astype(x.dtype)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def mla_prefill(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Dict,
    start_index: jax.Array,
    block_table: Optional[jax.Array] = None,
    n_valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Cache-writing batched MLA prefill: write the latent stream for the
    whole chunk, then attend via the expanded path (see ``gqa_prefill``)."""
    m: MLAConfig = cfg.mla
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, x, cfg, positions)
    new_cache = {
        "ckv": cache_rows_update(
            cache["ckv"], ckv, start_index,
            block_table=block_table, n_valid=n_valid,
        ),
        "k_rope": cache_rows_update(
            cache["k_rope"], k_rope[:, :, 0, :], start_index,
            block_table=block_table, n_valid=n_valid,
        ),
    }
    if block_table is not None:
        c_ckv = paged_kv_view(new_cache["ckv"], block_table)
        c_rope = paged_kv_view(new_cache["k_rope"], block_table)
    else:
        c_ckv, c_rope = new_cache["ckv"], new_cache["k_rope"]
    k_nope, v = _mla_expand_kv(params, c_ckv, cfg)
    B, S, H = x.shape[0], c_ckv.shape[1], cfg.n_heads
    k_rope_b = jnp.broadcast_to(
        c_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = mea_attention(
        q_full, k_full, v, causal=True, chunk=cfg.attn_chunk, q_offset=start_index
    )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def mla_cache_spec(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    page: Optional[Tuple[int, int]] = None,
) -> Dict[str, ParamSpec]:
    m: MLAConfig = cfg.mla
    if page is not None:
        num_blocks, block_size = page
        front, axes2 = (num_blocks + 1, block_size), ("kv_blocks", "kv_block")
    else:
        front, axes2 = (batch, max_len), ("act_batch", "act_kv_seq")
    return {
        "ckv": ParamSpec(
            (*front, m.kv_lora_rank), (*axes2, None), "zeros", cfg.dtype
        ),
        "k_rope": ParamSpec(
            (*front, m.qk_rope_head_dim), (*axes2, None), "zeros", cfg.dtype
        ),
    }
