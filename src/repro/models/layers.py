"""Common layers, spec-first.

Every layer exposes ``*_specs(...) -> dict[name, ParamSpec]`` describing
shape/dtype/logical-axes/initializer, plus a pure ``apply`` function. The
spec tree drives three consumers:

  * ``init_from_specs``     — materialize real params (CPU smoke tests,
                              small end-to-end training),
  * ``abstract_from_specs`` — ShapeDtypeStruct stand-ins with
                              NamedSharding attached (multi-pod dry-run;
                              no allocation),
  * analytic parameter counting (roofline MODEL_FLOPS).

Logical axis names are mapped to mesh axes by ``repro.dist.sharding``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "init_from_specs",
    "abstract_from_specs",
    "count_specs",
    "batch_axis_of",
    "is_paged_spec",
    "slot_read",
    "slot_write",
    "slot_reset",
    "slot_take",
    "slot_block_copy",
    "slot_mask_select",
    "rms_norm",
    "layer_norm",
    "norm_apply",
    "norm_specs",
    "rope_freqs",
    "apply_rope",
    "mlp_specs",
    "mlp_apply",
    "activation",
    "DTYPES",
]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | scaled(fan_in)
    dtype: str = "bfloat16"
    scale: float = 1.0                # stddev multiplier for normal inits

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes rank mismatch: {self.shape} vs {self.axes}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def _fan_in(shape: Tuple[int, ...]) -> int:
    # Convention: the LAST axis is the output axis; everything else is input.
    return max(int(np.prod(shape[:-1])), 1) if len(shape) > 1 else max(shape[0], 1)


def init_from_specs(rng: jax.Array, specs, dtype_override: Optional[str] = None):
    """Materialize a param pytree from a ParamSpec pytree."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, spec in zip(keys, leaves):
        dt = DTYPES[dtype_override or spec.dtype]
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dt))
        elif spec.init == "normal":
            out.append(
                (jax.random.normal(key, spec.shape, jnp.float32) * 0.02 * spec.scale).astype(dt)
            )
        elif spec.init == "scaled":
            std = spec.scale / math.sqrt(_fan_in(spec.shape))
            out.append(
                (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
            )
        else:
            raise ValueError(f"unknown init {spec.init}")
    return jax.tree.unflatten(treedef, out)


def abstract_from_specs(specs, sharding_for: Callable[[ParamSpec], object]):
    """ShapeDtypeStruct pytree with shardings — zero allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, DTYPES[s.dtype], sharding=sharding_for(s)
        ),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def count_specs(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(s.size for s in leaves)


# ---------------------------------------------------------------------------
# Slot-indexed cache helpers (repro.serve)
#
# Serving caches are pytrees whose leaves each carry an "act_batch" axis —
# NOT always the leading one (stacked-layer segments and the zamba shared
# block put "layers" first). The spec tree is the source of truth for
# where the slot axis lives and what a freshly reset slot contains
# (``init`` is "zeros" for KV rows but "ones" for e.g. the sLSTM
# normalizer), so every helper here walks (values, specs) together.
#
# Paged leaves (block-table KV arenas, axes carrying "kv_blocks" /
# "kv_block" instead of "act_batch"/"act_kv_seq") have NO per-slot rows:
# slot membership lives in the host-side block table, not the array
# layout. Every helper treats them as global state — read passes the
# arena through, write replaces it, reset/take are no-ops (freed blocks
# are recycled by the BlockManager; defrag never moves paged rows), and
# mask-select keeps the new arena (dead-lane writes land in the reserved
# null block by construction, so there is nothing to mask).
# ---------------------------------------------------------------------------

def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def is_paged_spec(spec: ParamSpec) -> bool:
    """True for block-arena cache leaves (slot axis replaced by a
    (kv_blocks, kv_block) pair addressed through a block table)."""
    return "kv_blocks" in spec.axes


def batch_axis_of(spec: ParamSpec) -> int:
    """Index of the slot ("act_batch") axis of a cache leaf."""
    return spec.axes.index("act_batch")


def slot_read(caches, specs, slot) -> "jax.Array":
    """Extract one slot as a batch-1 cache pytree (for chunked prefill
    continuation: read the slot, extend it, write it back). Paged arenas
    pass through whole — the slot's rows are found via its block table."""
    def read(c, s):
        if is_paged_spec(s):
            return c
        ax = batch_axis_of(s)
        return jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=ax)
    return jax.tree.map(read, caches, specs, is_leaf=_is_spec)


def slot_write(caches, specs, slot, slot_caches):
    """Write a batch-1 cache pytree into slot ``slot`` of a pooled cache.
    Paged arena leaves were mutated in place (functionally) by the
    prefill that produced ``slot_caches`` — adopt them wholesale."""
    def write(c, s, v):
        if is_paged_spec(s):
            return v.astype(c.dtype)
        ax = batch_axis_of(s)
        return jax.lax.dynamic_update_slice_in_dim(c, v.astype(c.dtype), slot, axis=ax)
    return jax.tree.map(write, caches, specs, slot_caches, is_leaf=_is_spec)


def slot_reset(caches, specs, slot):
    """Restore one slot to its spec-defined initial value (zeros/ones).
    Paged leaves are untouched: freeing a slot returns its blocks to the
    manager, and stale rows are overwritten on reallocation (the same
    lazy-reuse discipline as contiguous slots)."""
    def reset(c, s):
        if is_paged_spec(s):
            return c
        ax = batch_axis_of(s)
        shape = list(c.shape)
        shape[ax] = 1
        fill = jnp.ones if s.init == "ones" else jnp.zeros
        return jax.lax.dynamic_update_slice_in_dim(
            c, fill(shape, c.dtype), slot, axis=ax
        )
    return jax.tree.map(reset, caches, specs, is_leaf=_is_spec)


def slot_take(caches, specs, perm):
    """Permute slots (defrag: compact live slots to the low indices).
    Paged leaves are a no-op: block tables are host arrays that permute
    for free, so defrag never gathers arena rows."""
    def take(c, s):
        if is_paged_spec(s):
            return c
        return jnp.take(c, perm, axis=batch_axis_of(s))
    return jax.tree.map(take, caches, specs, is_leaf=_is_spec)


def slot_block_copy(caches, specs, src, dst):
    """Copy arena block ``src`` into block ``dst`` on every paged leaf —
    the device half of a copy-on-write fork. The BlockManager swaps the
    writer's table entry to ``dst`` on the host; after this copy the
    subsequent ``cache_row_update``/``cache_rows_update`` scatter lands
    in the private clone, never in the shared original. Contiguous
    leaves pass through untouched (they are never shared)."""
    def cp(c, s):
        if not is_paged_spec(s):
            return c
        ax = s.axes.index("kv_blocks")
        m = jnp.moveaxis(c, ax, 0)
        m = m.at[dst].set(m[src])
        return jnp.moveaxis(m, 0, ax)
    return jax.tree.map(cp, caches, specs, is_leaf=_is_spec)


def slot_mask_select(mask, new_caches, old_caches, specs):
    """Per-slot select: where ``mask`` (n_slots,) is True take the new
    leaf rows, else keep the old — the serving analogue of the fastest-k
    ``worker_mask`` (occupancy enters as data, shapes never change).
    Paged arenas always take the new value: masked lanes' writes were
    routed to the null sink block, so live rows are already correct."""
    def sel(n, o, s):
        if is_paged_spec(s):
            return n
        ax = batch_axis_of(s)
        shape = [1] * n.ndim
        shape[ax] = n.shape[ax]
        return jnp.where(mask.reshape(shape), n, o.astype(n.dtype))
    return jax.tree.map(sel, new_caches, old_caches, specs, is_leaf=_is_spec)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(d: int, kind: str, dtype: str) -> Dict[str, ParamSpec]:
    out = {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype)}
    if kind == "layernorm":
        out["bias"] = ParamSpec((d,), ("embed",), init="zeros", dtype=dtype)
    return out


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: Optional[jax.Array], eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mean) * jax.lax.rsqrt(var + eps)).astype(dt) * scale
    if bias is not None:
        y = y + bias
    return y


def norm_apply(params: Dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params.get("bias"))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim // 2,) in float32."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP / gated FFN
# ---------------------------------------------------------------------------

def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


def mlp_specs(d: int, d_ff: int, glu: bool, dtype: str) -> Dict[str, ParamSpec]:
    out = {
        "w_in": ParamSpec((d, d_ff), ("embed", "ffn"), init="scaled", dtype=dtype),
        "w_out": ParamSpec((d_ff, d), ("ffn", "embed"), init="scaled", dtype=dtype),
    }
    if glu:
        out["w_gate"] = ParamSpec(
            (d, d_ff), ("embed", "ffn"), init="scaled", dtype=dtype
        )
    return out


def mlp_apply(params: Dict, x: jax.Array, act: str, glu: bool) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"])
    if glu:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = activation(act)(g) * h
    else:
        h = activation(act)(h)
    return jnp.einsum("...f,fd->...d", h, params["w_out"])
