"""Mamba2 (SSD) block: chunked state-space duality scan + decode recurrence.

Shapes follow the Mamba2 reference: d_inner = expand * d_model heads of
size P = head_dim, H = d_inner / P heads, G groups sharing B/C projections
(GQA-analogue), state size N = d_state.

Three paths:
  * ``ssd_chunked``   — training/prefill: O(S * chunk) per-position work
                        (within-chunk quadratic + inter-chunk recurrence),
                        this is the jnp oracle for the Pallas ssd kernel;
  * ``ssd_recurrent`` — step-by-step reference (tests) and decode;
  * ``mamba2_decode`` — single-token decode against carried (conv, ssm)
                        state — the long_500k serving path (state is O(1)
                        in sequence length: the whole point of SSM decode).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from .layers import ParamSpec, norm_specs, rms_norm

__all__ = [
    "mamba2_specs",
    "mamba2_apply",
    "mamba2_decode",
    "mamba2_state_spec",
    "ssd_chunked",
    "ssd_recurrent",
]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    ssm: SSMConfig = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    H = d_inner // ssm.head_dim
    return d_inner, H, ssm.head_dim, ssm.n_groups, ssm.d_state


def mamba2_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    ssm: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner, H, P, G, N = _dims(cfg)
    dt = cfg.dtype
    conv_dim = d_inner + 2 * G * N
    return {
        # order: [z, x, B, C, dt]
        "w_in": ParamSpec(
            (d, 2 * d_inner + 2 * G * N + H), ("embed", "ssm_inner"), "scaled", dt
        ),
        "conv_w": ParamSpec((ssm.d_conv, conv_dim), (None, "ssm_inner"), "scaled", dt),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros", dt),
        "a_log": ParamSpec((H,), ("ssm_heads",), "ones", "float32"),
        "dt_bias": ParamSpec((H,), ("ssm_heads",), "zeros", "float32"),
        "d_skip": ParamSpec((H,), ("ssm_heads",), "ones", "float32"),
        "norm": norm_specs(d_inner, "rmsnorm", dt),
        "w_out": ParamSpec((d_inner, d), ("ssm_inner", "embed"), "scaled", dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along time. x: (B,S,D), w: (W,D).

    Returns (y, new_state) where state caches the last W-1 inputs.
    """
    W = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = x_pad[:, -(W - 1):, :] if W > 1 else None
    windows = [x_pad[:, i : i + x.shape[1], :] for i in range(W)]
    y = sum(wi * w[i] for i, wi in enumerate(windows)) + b
    return jax.nn.silu(y), new_state


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, H, P, G, N = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * G * N], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    d_inner, H, P, G, N = _dims(cfg)
    x, B, C = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    Bsz, S = x.shape[0], x.shape[1]
    return (
        x.reshape(Bsz, S, H, P),
        B.reshape(Bsz, S, G, N),
        C.reshape(Bsz, S, G, N),
    )


# ---------------------------------------------------------------------------
# SSD scans
# ---------------------------------------------------------------------------

def ssd_recurrent(
    x: jax.Array,      # (B, S, H, P)  (dt already folded in by caller? no: raw)
    dt: jax.Array,     # (B, S, H) positive
    A: jax.Array,      # (H,) negative
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    state: Optional[jax.Array] = None,  # (B, H, P, N)
):
    """Step-by-step SSM: s_t = exp(dt*A) s_{t-1} + dt * B_t x_t ; y = C_t s_t."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = H // G
    if state is None:
        state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,G,N), (B,G,N)
        decay = jnp.exp(dtt.astype(jnp.float32) * A)[..., None, None]  # (B,H,1,1)
        bt_h = jnp.repeat(bt, hg, axis=1).astype(jnp.float32)          # (B,H,N)
        ct_h = jnp.repeat(ct, hg, axis=1).astype(jnp.float32)
        upd = (dtt.astype(jnp.float32)[..., None, None]
               * xt.astype(jnp.float32)[..., None] * bt_h[:, :, None, :])
        s = decay * s + upd
        y = jnp.einsum("bhpn,bhn->bhp", s, ct_h)
        return s, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    *,
    chunk: int,
    state: Optional[jax.Array] = None,
):
    """Chunked SSD (Mamba2 alg.): quadratic within chunks, scan across."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = H // G
    Q = min(chunk, S)
    n_chunks = math.ceil(S / Q)
    pad = n_chunks * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(Bsz, n_chunks, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, n_chunks, Q, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, n_chunks, Q, G, N), hg, axis=3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(Bsz, n_chunks, Q, G, N), hg, axis=3).astype(f32)

    a = dtc * A  # (B, nc, Q, H) negative increments
    a_cum = jnp.cumsum(a, axis=2)
    a_total = a_cum[:, :, -1, :]  # (B, nc, H)

    # Within-chunk (causal, decay-weighted) attention-like term.
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)
    y_intra = jnp.einsum("bcijh,bcijh,bcjh,bcjhp->bcihp", scores, L, dtc, xc)

    # Per-chunk state contribution: sum_j exp(a_total - a_cum_j) dt_j B_j x_j.
    w = jnp.exp(a_total[:, :, None, :] - a_cum) * dtc        # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", w, Bc, xc)

    # Inter-chunk recurrence over chunk states.
    if state is None:
        s0 = jnp.zeros((Bsz, H, P, N), f32)
    else:
        s0 = state.astype(f32)

    def scan_fn(s, inp):
        cs, at = inp  # (B,H,P,N), (B,H)
        s_out = s                                  # state entering this chunk
        s = jnp.exp(at)[..., None, None] * s + cs
        return s, s_out

    final_state, s_in = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(a_total, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (B, nc, H, P, N)

    # Inter-chunk output: y_i += C_i exp(a_cum_i) s_in.
    y_inter = jnp.einsum(
        "bcihn,bcih,bchpn->bcihp", Cc, jnp.exp(a_cum), s_in
    )

    y = (y_intra + y_inter).reshape(Bsz, n_chunks * Q, H, P)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype), final_state


# ---------------------------------------------------------------------------
# Block-level apply
# ---------------------------------------------------------------------------

def mamba2_apply(
    params: Dict, x: jax.Array, cfg: ModelConfig, *, use_chunked: bool = True
) -> jax.Array:
    """Training/prefill forward of one Mamba2 block (no state carried in)."""
    ssm: SSMConfig = cfg.ssm
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, _ = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xh, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    runner = ssd_chunked if use_chunked else ssd_recurrent
    if use_chunked:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk=ssm.chunk)
    else:
        y, _ = ssd_recurrent(xh, dt, A, Bm, Cm)
    y = y + params["d_skip"].astype(y.dtype)[:, None] * xh
    Bsz, S = x.shape[0], x.shape[1]
    y = y.reshape(Bsz, S, -1)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    return jnp.einsum("bse,ed->bsd", y, params["w_out"])


def mamba2_decode(
    params: Dict,
    x: jax.Array,                   # (B, 1, D)
    cfg: ModelConfig,
    state: Dict[str, jax.Array],    # {"conv": (B, W-1, conv_dim), "ssm": (B,H,P,N)}
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], state=state["conv"]
    )
    xh, Bm, Cm = _split_xbc(cfg, xbc)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    y, ssm_state = ssd_recurrent(xh, dt, A, Bm, Cm, state=state["ssm"])
    y = y + params["d_skip"].astype(y.dtype)[:, None] * xh
    Bsz = x.shape[0]
    y = y.reshape(Bsz, 1, -1)
    y = rms_norm(y * jax.nn.silu(z), params["norm"]["scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba2_state_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    ssm: SSMConfig = cfg.ssm
    d_inner, H, P, G, N = _dims(cfg)
    conv_dim = d_inner + 2 * G * N
    return {
        "conv": ParamSpec(
            (batch, ssm.d_conv - 1, conv_dim),
            ("act_batch", None, "ssm_inner"),
            "zeros",
            cfg.dtype,
        ),
        "ssm": ParamSpec(
            (batch, H, P, N),
            ("act_batch", "ssm_heads", None, None),
            "zeros",
            "float32",
        ),
    }
