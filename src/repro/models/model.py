"""Top-level model: embeddings, stacks, losses, prefill/decode entry points.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
of (params, inputs) — ready for jax.jit/pjit with shardings attached by
the launch layer.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.collectives import masked_weighted_ce
from repro.dist.sharding import constrain_batch
from . import attention as attn
from . import mamba2, moe, xlstm, zamba
from .layers import (
    ParamSpec,
    abstract_from_specs,
    count_specs,
    init_from_specs,
    mlp_apply,
    norm_apply,
    norm_specs,
    slot_mask_select,
)
from .transformer import Segment, block_apply, run_segments, segment_plan, stack_specs

__all__ = ["Model", "build_model", "count_params_analytic"]


# ---------------------------------------------------------------------------
# Per-kind decode-step functions (single token, cache threading)
# ---------------------------------------------------------------------------

def _block_decode(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    positions: jax.Array,
    cache: Dict,
    cache_index: jax.Array,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    if kind in ("dense", "parallel", "moe"):
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        a, new_cache = attn.gqa_apply(
            params["attn"], h, cfg, positions=positions,
            cache=cache, cache_index=cache_index, block_table=block_tables,
        )
        if kind == "parallel":
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
            return x + a + f, new_cache
        x = x + a
        h = norm_apply(params["mlp_norm"], x, cfg.norm)
        if kind == "moe":
            f, _ = moe.moe_apply(params["ffn"], h, cfg)
        else:
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + f, new_cache
    if kind in ("mla_dense", "mla_moe"):
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        a, new_cache = attn.mla_apply(
            params["attn"], h, cfg, positions=positions,
            cache=cache, cache_index=cache_index, absorb=cfg.mla_absorb,
            block_table=block_tables,
        )
        x = x + a
        h = norm_apply(params["mlp_norm"], x, cfg.norm)
        if kind == "mla_moe":
            f, _ = moe.moe_apply(params["ffn"], h, cfg)
        else:
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + f, new_cache
    if kind == "mlstm":
        h = norm_apply(params["norm"], x, cfg.norm)
        y, new_state = xlstm.mlstm_decode(params["mixer"], h, cfg, cache)
        return x + y, new_state
    if kind == "slstm":
        h = norm_apply(params["norm"], x, cfg.norm)
        y, new_state = xlstm.slstm_apply(params["mixer"], h, cfg, state=cache)
        return x + y, new_state
    raise ValueError(f"no decode for block kind {kind}")


#: block kinds with a fused multi-token cache-writing prefill. Recurrent
#: kinds (mlstm/slstm/mamba) prefill through the masked decode scan instead.
_FUSED_PREFILL_KINDS = ("dense", "parallel", "moe", "mla_dense", "mla_moe")


def _block_prefill(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    positions: jax.Array,
    cache: Dict,
    start_index: jax.Array,
    block_tables: Optional[jax.Array] = None,
    n_valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Multi-token block forward that also writes the block's cache rows
    (the serving prefill; mirrors ``_block_decode`` with S > 1)."""
    if kind in ("dense", "parallel", "moe"):
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        a, new_cache = attn.gqa_prefill(
            params["attn"], h, cfg, positions=positions,
            cache=cache, start_index=start_index, block_table=block_tables,
            n_valid=n_valid,
        )
        if kind == "parallel":
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
            return x + a + f, new_cache
        x = x + a
        h = norm_apply(params["mlp_norm"], x, cfg.norm)
        if kind == "moe":
            f, _ = moe.moe_apply(params["ffn"], h, cfg)
        else:
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + f, new_cache
    if kind in ("mla_dense", "mla_moe"):
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        a, new_cache = attn.mla_prefill(
            params["attn"], h, cfg, positions=positions,
            cache=cache, start_index=start_index, block_table=block_tables,
            n_valid=n_valid,
        )
        x = x + a
        h = norm_apply(params["mlp_norm"], x, cfg.norm)
        if kind == "mla_moe":
            f, _ = moe.moe_apply(params["ffn"], h, cfg)
        else:
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + f, new_cache
    raise ValueError(f"no fused prefill for block kind {kind}")


def _block_cache_specs(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, page=None
) -> Optional[Dict]:
    if kind in ("dense", "parallel", "moe"):
        return attn.gqa_cache_spec(cfg, batch, max_len, page)
    if kind in ("mla_dense", "mla_moe"):
        return attn.mla_cache_spec(cfg, batch, max_len, page)
    if kind == "mlstm":
        return xlstm.mlstm_state_spec(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_state_spec(cfg, batch)
    if kind == "encoder":
        return None
    raise ValueError(f"no cache spec for {kind}")


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- specs ---------------------------------------------------------------
    @functools.cached_property
    def segments(self) -> List[Segment]:
        if self.cfg.family in ("ssm", "hybrid"):
            return []  # zamba path
        return segment_plan(self.cfg)

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        dt = cfg.dtype
        specs: Dict[str, Any] = {}
        if cfg.input_kind == "tokens":
            specs["embed"] = ParamSpec(
                (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", dt
            )
        else:  # frames (audio stub): projection + depthwise positional conv
            specs["frame_proj"] = ParamSpec(
                (cfg.d_model, cfg.d_model), ("embed", "embed_out"), "scaled", dt
            )
            specs["pos_conv_w"] = ParamSpec((16, cfg.d_model), (None, "embed"), "scaled", dt)
            specs["pos_conv_b"] = ParamSpec((cfg.d_model,), ("embed",), "zeros", dt)
            specs["embed"] = ParamSpec(  # output head for masked prediction
                (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", dt
            )
        if cfg.family in ("ssm", "hybrid"):
            specs["stack"] = zamba.zamba_specs(cfg)
        else:
            specs["stack"] = [stack_specs(cfg, seg) for seg in self.segments]
        specs["final_norm"] = norm_specs(cfg.d_model, cfg.norm, dt)
        if not cfg.tie_embeddings:
            specs["head"] = ParamSpec(
                (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), "scaled", dt
            )
        if cfg.mtp:
            specs["mtp"] = {
                "proj": ParamSpec(
                    (2 * cfg.d_model, cfg.d_model), ("embed", "embed_out"), "scaled", dt
                ),
                "block": stack_specs(cfg, Segment(self._mtp_kind(), 1)),
                "norm": norm_specs(cfg.d_model, cfg.norm, dt),
            }
        return specs

    def _mtp_kind(self) -> str:
        return "mla_dense" if self.cfg.mla is not None else "dense"

    def init(self, rng: jax.Array, dtype_override: Optional[str] = None):
        return init_from_specs(rng, self.param_specs(), dtype_override)

    def abstract_params(self, sharding_for):
        return abstract_from_specs(self.param_specs(), sharding_for)

    # -- forward -------------------------------------------------------------
    def embed_inputs(self, params: Dict, inputs: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.input_kind == "tokens":
            return params["embed"][inputs]
        x = jnp.einsum("bsd,de->bse", inputs.astype(params["frame_proj"].dtype),
                       params["frame_proj"])
        # Depthwise positional conv (HuBERT-style stub).
        W = params["pos_conv_w"].shape[0]
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        pos = sum(
            x_pad[:, i : i + x.shape[1], :] * params["pos_conv_w"][i] for i in range(W)
        ) + params["pos_conv_b"]
        return x + pos

    def hidden(
        self, params: Dict, inputs: jax.Array, positions: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = constrain_batch(self.embed_inputs(params, inputs))
        if cfg.family in ("ssm", "hybrid"):
            h, aux = zamba.zamba_apply(params["stack"], x, cfg, positions=positions)
        else:
            h, aux = run_segments(
                params["stack"], self.segments, x, cfg, positions=positions
            )
        return norm_apply(params["final_norm"], h, cfg.norm), aux

    def logits(self, params: Dict, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings or cfg.input_kind != "tokens":
            out = jnp.einsum("bsd,vd->bsv", h, params["embed"])
        else:
            out = jnp.einsum("bsd,dv->bsv", h, params["head"])
        if cfg.logit_scale != 1.0:
            out = out * cfg.logit_scale
        if cfg.logit_softcap > 0:
            out = cfg.logit_softcap * jnp.tanh(out / cfg.logit_softcap)
        return out

    # -- training ------------------------------------------------------------
    def train_loss(
        self, params: Dict, batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: inputs (B,S) int32 or (B,S,D) frames, labels (B,S) int32,
        optional mask (B,S)."""
        cfg = self.cfg
        inputs, labels = batch["inputs"], batch["labels"]
        S = labels.shape[1]
        positions = jnp.arange(S)
        h, aux = self.hidden(params, inputs, positions)
        logits = self.logits(params, h)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        ce = _masked_ce(logits, labels, mask)
        loss = ce + cfg.moe.router_aux_weight * aux if cfg.moe else ce
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, h, inputs, labels, mask, positions)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, inputs, labels, mask, positions):
        """DeepSeek-V3 multi-token prediction: one extra block predicts t+2."""
        cfg = self.cfg
        emb_next = params["embed"][jnp.roll(inputs, -1, axis=1)]
        x = jnp.einsum(
            "bsd,de->bse",
            jnp.concatenate([h, emb_next], axis=-1),
            params["mtp"]["proj"],
        )
        x, _ = block_apply(
            params["mtp"]["block"], x, cfg, self._mtp_kind(), positions=positions
        )
        x = norm_apply(params["mtp"]["norm"], x, cfg.norm)
        logits2 = self.logits(params, x)
        labels2 = jnp.roll(labels, -1, axis=1)
        mask2 = mask * (jnp.arange(labels.shape[1]) < labels.shape[1] - 1)
        return _masked_ce(logits2, labels2, mask2)

    # -- serving ---------------------------------------------------------------
    def cache_specs(
        self,
        batch: int,
        max_len: int,
        *,
        block_size: Optional[int] = None,
        num_blocks: int = 0,
    ):
        """Cache spec tree for ``batch`` sequences of up to ``max_len``
        tokens. The sequence axis is rounded up to ``attn.KV_SEQ_ALIGN``
        once, here, at allocation time — so the flash-decode kernel never
        pads (copies) the cache in HBM per tick, and paged block sizes
        tile the rows evenly.

        ``block_size`` switches leaves that carry a sequence axis to the
        paged arena layout ((num_blocks + 1, block_size, ...) addressed
        through block tables); leaves without one — recurrent conv/SSM/
        xLSTM states — keep their contiguous per-slot layout in either
        mode, behind the same pool API."""
        cfg = self.cfg
        max_len = attn.round_kv_len(max_len)
        page = None
        if block_size is not None:
            page = (num_blocks, block_size)
        if cfg.family in ("ssm", "hybrid"):
            return zamba.zamba_cache_specs(cfg, batch, max_len, page)
        out = []
        for seg in self.segments:
            single = _block_cache_specs(cfg, seg.kind, batch, max_len, page)
            if seg.count > 1:
                single = jax.tree.map(
                    lambda s: ParamSpec(
                        (seg.count, *s.shape), ("layers", *s.axes), s.init, s.dtype
                    ),
                    single,
                    is_leaf=lambda x: isinstance(x, ParamSpec),
                )
            out.append(single)
        return out

    def blank_caches(
        self,
        batch: int,
        max_len: int,
        *,
        block_size: Optional[int] = None,
        num_blocks: int = 0,
    ):
        """Freshly initialized caches (cache specs are deterministic
        zeros/ones fills, so no meaningful randomness is consumed)."""
        return init_from_specs(
            jax.random.PRNGKey(0),
            self.cache_specs(
                batch, max_len, block_size=block_size, num_blocks=num_blocks
            ),
        )

    @functools.cached_property
    def fused_prefill(self) -> bool:
        """True when every block has a multi-token cache-writing prefill
        (pure-attention stacks); recurrent/hybrid stacks fall back to the
        masked decode scan in ``prefill_with_cache``."""
        if self.cfg.family in ("ssm", "hybrid"):
            return False
        return all(seg.kind in _FUSED_PREFILL_KINDS for seg in self.segments)

    def prefill(self, params: Dict, inputs: jax.Array) -> jax.Array:
        """Prefill forward -> logits for the last position (no cache
        writing — the dry-run lowers this as the prefill compute; serving
        uses ``prefill_with_cache``)."""
        S = inputs.shape[1]
        positions = jnp.arange(S)
        h, _ = self.hidden(params, inputs, positions)
        return self.logits(params, h[:, -1:, :])

    def prefill_with_cache(
        self,
        params: Dict,
        inputs: jax.Array,                     # (B, P) int32, right-padded
        caches,
        length: Optional[jax.Array] = None,    # (B,) valid tokens per row
        start_index: jax.Array = 0,            # scalar: first write position
        block_tables: Optional[jax.Array] = None,  # (B, T) paged arenas
    ):
        """Batched cache-writing prefill -> (last-valid logits (B,1,V), caches).

        ``inputs`` may be right-padded to a bucket size; ``length`` marks
        each row's true token count. Attention stacks run the fused path
        (one projection for the whole chunk; pad rows are causally inert
        and their stale cache rows are masked by decode's length mask).
        Recurrent/hybrid stacks scan the decode step with per-row update
        masking so pad tokens never touch the state. ``start_index > 0``
        continues a partially prefilled cache (chunked prefill). With
        ``block_tables`` the sequence-axis cache leaves are paged arenas
        and the chunk's rows are written as bulk block scatters."""
        cfg = self.cfg
        B, P = inputs.shape
        start_index = jnp.asarray(start_index, jnp.int32)
        if length is None:
            length = jnp.full((B,), P, jnp.int32)

        if self.fused_prefill:
            positions = start_index + jnp.arange(P)
            h, new_caches = self._fused_prefill_stack(
                params, inputs, caches, positions=positions,
                start_index=start_index, block_tables=block_tables,
            )
            last = jnp.clip(length - 1, 0, P - 1)
            h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)
            return self.logits(params, h_last), new_caches

        # Recurrent/hybrid fallback: scan the decode step over the chunk,
        # masking cache updates (and the returned logits) past each row's
        # true length. Exactly equivalent to feeding the unpadded prompt.
        # (Paged KV leaves skip the mask: pad-token writes land at rows
        # past the row's length, which every read masks out — identical
        # to the contiguous path's masked tail.)
        specs = self.cache_specs(  # axes metadata only; sizes unused
            B, 2, block_size=1 if block_tables is not None else None
        )

        def body(carry, xs):
            caches_c, last_logits = carry
            tok, t = xs
            logits, new_caches = self.decode_step(
                params, tok[:, None], caches_c, start_index + t,
                block_tables=block_tables,
            )
            valid = t < length
            caches_c = slot_mask_select(valid, new_caches, caches_c, specs)
            last_logits = jnp.where(valid[:, None, None], logits, last_logits)
            return (caches_c, last_logits), None

        last0 = jnp.zeros((B, 1, cfg.vocab_size), params["embed"].dtype)
        (caches, last_logits), _ = jax.lax.scan(
            body, (caches, last0), (jnp.moveaxis(inputs, 1, 0), jnp.arange(P))
        )
        return last_logits, caches

    def _fused_prefill_stack(
        self,
        params: Dict,
        inputs: jax.Array,
        caches,
        *,
        positions: jax.Array,
        start_index: jax.Array,
        block_tables: Optional[jax.Array] = None,
        n_valid: Optional[jax.Array] = None,
    ):
        """Shared cache-writing stack walk of the fused (pure-attention)
        path -> (final-norm hidden states (B, S, D), caches). The single
        source of truth for ``prefill_with_cache`` AND
        ``verify_with_cache`` — the byte-identity contract depends on
        those two never diverging in how they traverse the stack."""
        cfg = self.cfg
        x = self.embed_inputs(params, inputs)
        new_caches = []
        h = x
        for seg_params, seg_cache, seg in zip(
            params["stack"], caches, self.segments
        ):
            if seg.count == 1:
                h, nc = _block_prefill(
                    seg_params, h, cfg, seg.kind, positions=positions,
                    cache=seg_cache, start_index=start_index,
                    block_tables=block_tables, n_valid=n_valid,
                )
            else:
                def scan_fn(carry, xs):
                    layer, cache = xs
                    h2, nc = _block_prefill(
                        layer, carry, cfg, seg.kind, positions=positions,
                        cache=cache, start_index=start_index,
                        block_tables=block_tables, n_valid=n_valid,
                    )
                    return h2, nc
                h, nc = jax.lax.scan(scan_fn, h, (seg_params, seg_cache))
            new_caches.append(nc)
        return norm_apply(params["final_norm"], h, cfg.norm), new_caches

    def verify_with_cache(
        self,
        params: Dict,
        inputs: jax.Array,                     # (B, S) int32 draft windows
        caches,
        n_input: jax.Array,                    # (B,) valid inputs per row
        start_indices: jax.Array,              # (B,) first write position
        block_tables: Optional[jax.Array] = None,
        greedy_commit: bool = True,
    ):
        """Batched multi-token verify for speculative decoding ->
        (all-position logits (B, S, V), caches).

        Row ``b`` scores ``inputs[b, :n_input[b]]`` — the pending token
        followed by the draft proposals — starting at its own cache
        position ``start_indices[b]``; rows past ``n_input`` are inert
        pad (their logits are garbage the caller must ignore). Every slot
        sits at its own length, so the per-row start/count enter as DATA
        and one compile per S covers every round (the ``worker_mask``
        discipline).

        Cache commitment is family-specific but the CONTRACT is shared —
        on return the caches are valid for a committed prefix of any
        length ``a+1 <= n_input[b]`` the caller derives from the logits
        by the exact-argmax acceptance rule:

          * attention stacks (fused path): K/V rows are written for all
            ``n_input`` inputs; rows past the accepted prefix are stale
            but DEAD (every read masks by the caller-tracked position),
            so rollback is a host-side position rewind — block-table or
            contiguous alike.
          * recurrent/hybrid stacks (scan path): state cannot rewind, so
            the scan replays the acceptance rule ON DEVICE — step t
            commits its state update only while the greedy chain is
            unbroken (argmax(logits_{t-1}) == inputs[t]), which is
            bit-identical to the host's decision because both argmax the
            same logits. ``greedy_commit=False`` disables the chain and
            commits all ``n_input`` tokens (draft-side replay sync).
        """
        cfg = self.cfg
        B, S = inputs.shape
        start = jnp.asarray(start_indices, jnp.int32)
        n_input = jnp.asarray(n_input, jnp.int32)

        if self.fused_prefill:
            positions = start[:, None] + jnp.arange(S)   # (B, S) rope positions
            h, new_caches = self._fused_prefill_stack(
                params, inputs, caches, positions=positions,
                start_index=start, block_tables=block_tables, n_valid=n_input,
            )
            return self.logits(params, h), new_caches

        # Recurrent/hybrid: scan the decode step, gating state commits by
        # the on-device greedy acceptance chain (see docstring).
        specs = self.cache_specs(  # axes metadata only; sizes unused
            B, 2, block_size=1 if block_tables is not None else None
        )
        nxt = jnp.concatenate(
            [inputs[:, 1:], jnp.zeros((B, 1), inputs.dtype)], axis=1
        )

        def body(carry, xs):
            caches_c, acc = carry
            tok, nxt_tok, t = xs
            logits, new_caches = self.decode_step(
                params, tok[:, None], caches_c, start + t,
                block_tables=block_tables,
            )
            commit = acc & (t < n_input)
            caches_c = slot_mask_select(commit, new_caches, caches_c, specs)
            if greedy_commit:
                g = jnp.argmax(logits[:, -1, :], axis=-1).astype(inputs.dtype)
                acc = acc & ((g == nxt_tok) | (t + 1 >= n_input))
            return (caches_c, acc), logits[:, 0, :]

        (caches, _), ys = jax.lax.scan(
            body,
            (caches, jnp.ones((B,), bool)),
            (jnp.moveaxis(inputs, 1, 0), jnp.moveaxis(nxt, 1, 0),
             jnp.arange(S)),
        )
        return jnp.moveaxis(ys, 0, 1), caches

    def decode_step(
        self,
        params: Dict,
        token: jax.Array,          # (B, 1) int32
        caches,
        cache_index: jax.Array,    # int32 current length: scalar or (B,)
        block_tables: Optional[jax.Array] = None,  # (B, T): paged KV arenas
    ):
        cfg = self.cfg
        x = params["embed"][token]
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim == 0:
            positions = jnp.full((1,), idx, jnp.int32)
        else:
            positions = idx[:, None]  # (B, 1): per-slot rope positions
        cache_index = idx
        if cfg.family in ("ssm", "hybrid"):
            h, new_caches = zamba.zamba_decode(
                params["stack"], x, cfg, caches,
                positions=positions, cache_index=cache_index,
                block_tables=block_tables,
            )
        else:
            new_caches = []
            h = x
            for seg_params, seg_cache, seg in zip(params["stack"], caches, self.segments):
                if seg.count == 1:
                    h, nc = _block_decode(
                        seg_params, h, cfg, seg.kind,
                        positions=positions, cache=seg_cache, cache_index=cache_index,
                        block_tables=block_tables,
                    )
                else:
                    def scan_fn(carry, xs):
                        layer, cache = xs
                        h2, nc = _block_decode(
                            layer, carry, cfg, seg.kind,
                            positions=positions, cache=cache, cache_index=cache_index,
                            block_tables=block_tables,
                        )
                        return h2, nc
                    h, nc = jax.lax.scan(scan_fn, h, (seg_params, seg_cache))
                new_caches.append(nc)
        h = norm_apply(params["final_norm"], h, cfg.norm)
        return self.logits(params, h), new_caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count from the spec tree (exact). active_only: count each
    MoE layer as top_k (+shared) experts instead of all experts."""
    model = build_model(cfg)
    total = count_specs(model.param_specs())
    if active_only and cfg.moe is not None:
        d, de = cfg.d_model, cfg.moe.d_expert
        per_expert = 3 * d * de
        n_moe_layers = cfg.n_layers - cfg.moe.first_k_dense
        total -= (cfg.moe.n_experts - cfg.moe.top_k) * per_expert * n_moe_layers
    return total


def _masked_ce(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    return masked_weighted_ce(logits, labels, mask)[0]
