"""Mixture-of-Experts FFN (top-k routing, capacity-bounded, EP-shardable).

Baseline formulation (v1, used by the dry-run): sort-based dispatch into
per-expert (E, C, d) buffers via scatter, expert compute as a single
batched einsum over the expert dimension, gather-combine. Under pjit the
expert dim shards over 'model' (expert parallelism); the scatter/gather
lower to collectives chosen by SPMD (documented in §Roofline, and the
explicit all-to-all shard_map variant is a §Perf hillclimb).

Faithfulness notes: token-choice top-k routing with softmax gates
(renormalized over the top-k), optional DeepSeek-style shared experts and
leading dense layers, capacity dropping with zero-fill (dropped tokens
pass through the residual stream only), and the standard load-balance
auxiliary loss (Switch/GShard form).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.dist.sharding import constrain_logical
from .layers import ParamSpec, activation, mlp_apply, mlp_specs

__all__ = ["moe_specs", "moe_apply", "moe_capacity"]


def moe_capacity(moe: MoEConfig, tokens: int) -> int:
    """Static per-expert capacity for a given token count. Dropless mode
    (inference) sizes the buffer for the worst case — every token on one
    expert — so routing is token-local and chunk-geometry-invariant."""
    if moe.dropless:
        return max(tokens, moe.top_k)
    cap = int(moe.capacity_factor * tokens * moe.top_k / moe.n_experts)
    return max(cap, moe.top_k)


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    moe = cfg.moe
    d, dt = cfg.d_model, cfg.dtype
    de = moe.d_expert
    specs: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, moe.n_experts), ("embed", None), "scaled", dt),
        "w_in": ParamSpec(
            (moe.n_experts, d, de), ("expert", "embed", "expert_ffn"), "scaled", dt
        ),
        "w_gate": ParamSpec(
            (moe.n_experts, d, de), ("expert", "embed", "expert_ffn"), "scaled", dt
        ),
        "w_out": ParamSpec(
            (moe.n_experts, de, d), ("expert", "expert_ffn", "embed"), "scaled", dt
        ),
    }
    if moe.n_shared_experts > 0:
        d_sh = (moe.d_shared or moe.d_expert) * moe.n_shared_experts
        specs["shared"] = mlp_specs(d, d_sh, glu=True, dtype=dt)
    return specs


def _route(
    x_flat: jax.Array, router: jax.Array, moe: MoEConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights (T,K), experts (T,K) int32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x_flat, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, moe.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Load-balance aux loss: E * sum_e f_e * P_e  (Switch Transformer eq. 4).
    E = moe.n_experts
    f = jnp.zeros((E,), jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p = probs.mean(axis=0)
    aux = E * jnp.sum(f * p)
    return weights.astype(x_flat.dtype), experts, aux


def _dp_group_count(T: int) -> int:
    """Number of data-parallel groups for group-local dispatch (= product
    of the ambient data axes when it divides the token count, else 1)."""
    from repro.dist.sharding import _ACT_CTX  # ambient mesh context

    ctx = _ACT_CTX.get()
    if ctx is None:
        return 1
    g = 1
    for a in ctx.dp:
        g *= ctx.mesh.shape[a]
    return g if g > 1 and T % g == 0 else 1


def moe_apply(
    params: Dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Dispatch formulations (cfg.moe.dispatch — §Perf iterations):
      "data"    : dispatched tokens stay batch-sharded; scatter into the
                  model-sharded (E, C, D) buffer (v1 baseline; XLA
                  replicates + all-reduces the buffer — expensive),
      "model"   : dispatched tokens resharded over the MODEL axis before
                  the scatter, so buffer formation is a same-axis 1-D
                  exchange (all-to-all-shaped, the EP-optimal volume),
      "grouped" : per-data-group capacity buffers (refuted: XLA cannot
                  partition the 2-axis scatter; kept for the record).
    """
    if cfg.moe.dispatch == "grouped":
        return _moe_apply_grouped(params, x, cfg)
    return _moe_apply_flat(params, x, cfg)


def _moe_apply_flat(params, x, cfg):
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    K, E = moe.top_k, moe.n_experts
    C = moe_capacity(moe, T)
    x_flat = x.reshape(T, D)

    weights, experts, aux = _route(x_flat, params["router"], moe)

    flat_e = experts.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted)
    keep = pos < C

    token_idx = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_pos = jnp.where(keep, pos, C - 1)

    disp_axis = "expert" if moe.dispatch == "model" else "act_batch"
    dispatched = jnp.where(keep[:, None], x_flat[token_idx], 0).astype(x.dtype)
    dispatched = constrain_logical(dispatched, (disp_axis, None))
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[safe_e, safe_pos].add(dispatched)
    buf = constrain_logical(buf, ("expert", None, None))

    h_in = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h = activation(cfg.act)(h_gate) * h_in
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_out"])

    y_buf = constrain_logical(y_buf, ("expert", None, None))
    gathered = y_buf[safe_e, safe_pos]                  # (T*K, D)
    gathered = constrain_logical(gathered, (disp_axis, None))
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_flat = weights.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, D), gathered.dtype).at[token_idx].add(gathered * w_flat)

    if moe.n_shared_experts > 0:
        out = out + mlp_apply(params["shared"], x_flat, cfg.act, glu=True)

    return out.reshape(B, S, D), aux.astype(jnp.float32)


def _moe_apply_grouped(params, x, cfg):
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    K, E = moe.top_k, moe.n_experts
    G = _dp_group_count(T)
    Tg = T // G
    C = max(moe_capacity(moe, T) // G, K)
    x_flat = x.reshape(T, D)

    weights, experts, aux = _route(x_flat, params["router"], moe)

    # Rank each (token, choice) within its (group, expert) bucket.
    eg = experts.reshape(G, Tg * K)                     # (G, Tg*K)
    order = jnp.argsort(eg, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(eg, order, axis=-1)
    counts = jnp.zeros((G, E), jnp.int32).at[
        jnp.arange(G)[:, None], eg
    ].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts       # (G, E) exclusive
    rank_sorted = (
        jnp.arange(Tg * K, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=-1)
    )
    pos = jnp.zeros((G, Tg * K), jnp.int32).at[
        jnp.arange(G)[:, None], order
    ].set(rank_sorted)
    keep = pos < C

    g_idx = jnp.repeat(jnp.arange(G, dtype=jnp.int32)[:, None], Tg * K, axis=1)
    tok_in_g = jnp.tile(jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K), (G, 1))
    safe_e = jnp.where(keep, eg, 0)
    safe_pos = jnp.where(keep, pos, C - 1)

    xg = x_flat.reshape(G, Tg, D)
    xg = constrain_logical(xg, ("act_batch", None, None))
    dispatched = jnp.where(
        keep[..., None], jnp.take_along_axis(
            xg, tok_in_g[..., None], axis=1
        ), 0
    ).astype(x.dtype)                                    # (G, Tg*K, D)
    dispatched = constrain_logical(dispatched, ("act_batch", None, None))

    buf = jnp.zeros((G, E, C, D), x.dtype)
    buf = buf.at[g_idx, safe_e, safe_pos].add(dispatched)
    buf = constrain_logical(buf, ("act_batch", "expert", None, None))

    # Expert compute: gated FFN batched over (group, expert).
    h_in = jnp.einsum("gecd,edf->gecf", buf, params["w_in"])
    h_gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    h = activation(cfg.act)(h_gate) * h_in
    y_buf = jnp.einsum("gecf,efd->gecd", h, params["w_out"])
    y_buf = constrain_logical(y_buf, ("act_batch", "expert", None, None))

    # Combine: gather each kept choice back to its group, weight, sum.
    gathered = y_buf[g_idx, safe_e, safe_pos]            # (G, Tg*K, D)
    gathered = constrain_logical(gathered, ("act_batch", None, None))
    gathered = jnp.where(keep[..., None], gathered, 0)
    w_g = weights.reshape(G, Tg * K, 1).astype(gathered.dtype)
    out = jnp.zeros((G, Tg, D), gathered.dtype).at[
        g_idx, tok_in_g
    ].add(gathered * w_g)
    out = out.reshape(T, D)

    if moe.n_shared_experts > 0:
        out = out + mlp_apply(params["shared"], x_flat, cfg.act, glu=True)

    return out.reshape(B, S, D), aux.astype(jnp.float32)
