"""Generic segmented decoder/encoder stack.

A model is a sequence of SEGMENTS, each a homogeneous run of blocks whose
params are stacked along a leading 'layers' axis and executed with
``jax.lax.scan`` (small HLO, fast compile — essential for the 61-layer
cells) under a configurable remat policy. Heterogeneous archs (deepseek's
dense->moe split, xlstm's mlstm/slstm interleave) are expressed as
multiple segments; zamba2's shared-block wiring lives in ``zamba.py``.

Block kinds:
  dense      : pre-norm GQA attn + pre-norm (G)MLP     (llama/qwen/smollm/chameleon)
  parallel   : single norm, attn + MLP in parallel      (command-r)
  encoder    : bidirectional attn + MLP, conv-pos input (hubert)
  moe        : GQA attn + MoE FFN                       (qwen3-moe)
  mla_dense  : MLA attn + dense MLP                     (deepseek first-3)
  mla_moe    : MLA attn + MoE FFN                       (deepseek)
  mlstm/slstm: xLSTM blocks
  mamba      : Mamba2 block
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain_batch
from . import attention as attn
from . import mamba2, moe, xlstm
from .layers import ParamSpec, mlp_apply, mlp_specs, norm_apply, norm_specs

__all__ = ["segment_plan", "stack_specs", "block_specs", "block_apply", "run_segments"]


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int


def segment_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.family in ("dense", "vlm"):
        kind = "parallel" if cfg.parallel_block else "dense"
        return [Segment(kind, cfg.n_layers)]
    if cfg.family in ("encoder", "audio"):
        return [Segment("encoder", cfg.n_layers)]
    if cfg.family == "moe":
        if cfg.mla is not None:
            k = cfg.moe.first_k_dense
            segs = []
            if k:
                segs.append(Segment("mla_dense", k))
            segs.append(Segment("mla_moe", cfg.n_layers - k))
            return segs
        k = cfg.moe.first_k_dense
        segs = []
        if k:
            segs.append(Segment("dense", k))
        segs.append(Segment("moe", cfg.n_layers - k))
        return segs
    if cfg.family == "xlstm":
        xc = cfg.xlstm
        segs: List[Segment] = []
        run = 0
        for i in range(cfg.n_layers):
            if (i + 1) % xc.slstm_every == 0:
                if run:
                    segs.append(Segment("mlstm", run))
                    run = 0
                segs.append(Segment("slstm", 1))
            else:
                run += 1
        if run:
            segs.append(Segment("mlstm", run))
        return segs
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError("ssm/hybrid stacks are built in zamba.py / model.py")
    raise ValueError(f"no segment plan for family {cfg.family}")


# ---------------------------------------------------------------------------
# Per-block specs
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d, dt = cfg.d_model, cfg.dtype
    if kind in ("dense", "parallel", "encoder", "moe"):
        out = {
            "attn_norm": norm_specs(d, cfg.norm, dt),
            "attn": attn.gqa_specs(cfg),
        }
        if kind != "parallel":
            out["mlp_norm"] = norm_specs(d, cfg.norm, dt)
        if kind == "moe":
            out["ffn"] = moe.moe_specs(cfg)
        else:
            out["ffn"] = mlp_specs(d, cfg.d_ff, cfg.glu, dt)
        return out
    if kind in ("mla_dense", "mla_moe"):
        out = {
            "attn_norm": norm_specs(d, cfg.norm, dt),
            "attn": attn.mla_specs(cfg),
            "mlp_norm": norm_specs(d, cfg.norm, dt),
        }
        if kind == "mla_moe":
            out["ffn"] = moe.moe_specs(cfg)
        else:
            out["ffn"] = mlp_specs(d, cfg.d_ff, cfg.glu, dt)
        return out
    if kind == "mamba":
        return {"norm": norm_specs(d, cfg.norm, dt), "mixer": mamba2.mamba2_specs(cfg)}
    if kind == "mlstm":
        return {"norm": norm_specs(d, cfg.norm, dt), "mixer": xlstm.mlstm_specs(cfg)}
    if kind == "slstm":
        return {"norm": norm_specs(d, cfg.norm, dt), "mixer": xlstm.slstm_specs(cfg)}
    raise ValueError(f"unknown block kind {kind}")


def stack_specs(cfg: ModelConfig, seg: Segment):
    """Stack one block's specs along a leading 'layers' axis."""
    single = block_specs(cfg, seg.kind)
    if seg.count == 1:
        return single

    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            (seg.count, *s.shape), ("layers", *s.axes), s.init, s.dtype, s.scale
        )

    return jax.tree.map(stack, single, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Per-block apply (train/prefill; decode lives in model.py)
# ---------------------------------------------------------------------------

def block_apply(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    positions: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "encoder", "moe", "mla_dense", "mla_moe"):
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        if kind.startswith("mla"):
            a, _ = attn.mla_apply(params["attn"], h, cfg, positions=positions)
        else:
            a, _ = attn.gqa_apply(params["attn"], h, cfg, positions=positions)
        x = x + a
        h = norm_apply(params["mlp_norm"], x, cfg.norm)
        if kind in ("moe", "mla_moe"):
            f, aux = moe.moe_apply(params["ffn"], h, cfg)
        else:
            f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + f, aux
    if kind == "parallel":
        h = norm_apply(params["attn_norm"], x, cfg.norm)
        a, _ = attn.gqa_apply(params["attn"], h, cfg, positions=positions)
        f = mlp_apply(params["ffn"], h, cfg.act, cfg.glu)
        return x + a + f, aux
    if kind == "mamba":
        h = norm_apply(params["norm"], x, cfg.norm)
        return x + mamba2.mamba2_apply(params["mixer"], h, cfg), aux
    if kind == "mlstm":
        h = norm_apply(params["norm"], x, cfg.norm)
        return x + xlstm.mlstm_apply(params["mixer"], h, cfg), aux
    if kind == "slstm":
        h = norm_apply(params["norm"], x, cfg.norm)
        y, _ = xlstm.slstm_apply(params["mixer"], h, cfg)
        return x + y, aux
    raise ValueError(f"unknown block kind {kind}")


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat {cfg.remat}")


def run_segments(
    seg_params: List[Dict],
    segs: List[Segment],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Forward through all segments; scan within multi-block segments."""
    total_aux = jnp.zeros((), jnp.float32)
    for params, seg in zip(seg_params, segs):
        body = _remat_wrap(
            lambda p, h: block_apply(p, h, cfg, seg.kind, positions=positions), cfg
        )
        if seg.count == 1 or not cfg.scan_layers:
            if seg.count == 1:
                x, aux = body(params, x)
                x = constrain_batch(x)
                total_aux = total_aux + aux
            else:
                for i in range(seg.count):
                    layer = jax.tree.map(lambda t: t[i], params)
                    x, aux = body(layer, x)
                    x = constrain_batch(x)
                    total_aux = total_aux + aux
        else:
            def scan_fn(h, layer):
                h2, aux = body(layer, h)
                return constrain_batch(h2), aux
            x, auxes = jax.lax.scan(scan_fn, x, params)
            total_aux = total_aux + auxes.sum()
    return x, total_aux
