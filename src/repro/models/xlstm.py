"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM trains with the stabilized parallel (quadratic) form and decodes
with the O(1) recurrent form; sLSTM is inherently recurrent (hidden-to-
hidden connections) and always scans over time. Both follow the xLSTM
paper's pre-/post-up-projection block wiring.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from .layers import ParamSpec, norm_specs, rms_norm

__all__ = [
    "mlstm_specs", "mlstm_apply", "mlstm_decode", "mlstm_state_spec",
    "slstm_specs", "slstm_apply", "slstm_state_spec",
    "mlstm_parallel", "mlstm_recurrent",
]

NEG_INF = -1e30


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    xc: XLSTMConfig = cfg.xlstm
    d_in = int(cfg.d_model * xc.mlstm_proj_factor)
    H = cfg.n_heads
    return d_in, H, d_in // H


# ---------------------------------------------------------------------------
# mLSTM core math
# ---------------------------------------------------------------------------

def mlstm_parallel(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, S, H) input-gate preactivation
    f_pre: jax.Array,  # (B, S, H) forget-gate preactivation
) -> jax.Array:
    """Stabilized parallel form (xLSTM paper eq. 19-27)."""
    B, S, H, D = q.shape
    f32 = jnp.float32
    log_f = jax.nn.log_sigmoid(f_pre.astype(f32))         # (B,S,H)
    F = jnp.cumsum(log_f, axis=1)
    # Dtilde[t, s] = F_t - F_s + i_s   (s <= t)
    dmat = F[:, :, None, :] - F[:, None, :, :] + i_pre.astype(f32)[:, None, :, :]
    idx = jnp.arange(S)
    causal = idx[:, None] >= idx[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, NEG_INF)
    m = dmat.max(axis=2)                                   # (B,S,H) row max
    dexp = jnp.exp(dmat - m[:, :, None, :])
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(f32) * scale, k.astype(f32))
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(w.sum(axis=2)), jnp.exp(-m))  # (B,S,H)
    h = jnp.einsum("btsh,bshd->bthd", w, v.astype(f32)) / norm[..., None]
    return h.astype(q.dtype)


def mlstm_recurrent(
    q: jax.Array, k: jax.Array, v: jax.Array,
    i_pre: jax.Array, f_pre: jax.Array,
    state: Tuple[jax.Array, jax.Array, jax.Array],  # C (B,H,D,D), n (B,H,D), m (B,H)
):
    """Recurrent stepping over a (possibly length-1) sequence."""
    B, S, H, D = q.shape
    f32 = jnp.float32
    scale = 1.0 / math.sqrt(D)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        log_f = jax.nn.log_sigmoid(ft.astype(f32))         # (B,H)
        m_new = jnp.maximum(log_f + m, it.astype(f32))
        f_act = jnp.exp(log_f + m - m_new)[..., None]
        i_act = jnp.exp(it.astype(f32) - m_new)[..., None]
        kf = kt.astype(f32) * scale
        C = f_act[..., None] * C + i_act[..., None] * (
            kf[..., :, None] * vt.astype(f32)[..., None, :]
        )
        n = f_act * n + i_act * kf
        qf = qt.astype(f32)
        num = jnp.einsum("bhd,bhde->bhe", qf, C)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_pre, f_pre))
    (C, n, m), hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype), (C, n, m)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    d_in, H, Dh = _mlstm_dims(cfg)
    dt = cfg.dtype
    return {
        "w_up": ParamSpec((d, 2 * d_in), ("embed", "ffn"), "scaled", dt),
        "conv_w": ParamSpec((xc.conv1d_kernel, d_in), (None, "ffn"), "scaled", dt),
        "conv_b": ParamSpec((d_in,), ("ffn",), "zeros", dt),
        "wq": ParamSpec((d_in, d_in), ("ffn", "ffn_out"), "scaled", dt),
        "wk": ParamSpec((d_in, d_in), ("ffn", "ffn_out"), "scaled", dt),
        "wv": ParamSpec((d_in, d_in), ("ffn", "ffn_out"), "scaled", dt),
        "w_if": ParamSpec((d_in, 2 * H), ("ffn", None), "scaled", dt),
        "b_if": ParamSpec((2 * H,), (None,), "zeros", "float32"),
        "norm": norm_specs(d_in, "rmsnorm", dt),
        "w_down": ParamSpec((d_in, d), ("ffn", "embed"), "scaled", dt),
    }


def _mlstm_qkvif(params: Dict, x: jax.Array, cfg: ModelConfig,
                 conv_state: Optional[jax.Array] = None):
    from .mamba2 import _causal_conv  # shared depthwise causal conv helper

    d_in, H, Dh = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, params["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = _causal_conv(xm, params["conv_w"], params["conv_b"], state=conv_state)
    B, S = x.shape[0], x.shape[1]
    q = jnp.einsum("bse,ef->bsf", xc, params["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bse,ef->bsf", xc, params["wk"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bse,ef->bsf", xm, params["wv"]).reshape(B, S, H, Dh)
    gates = jnp.einsum("bse,eg->bsg", xc, params["w_if"]) + params["b_if"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    return q, k, v, i_pre, f_pre, z, new_conv


def mlstm_apply(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    d_in, H, Dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z, _ = _mlstm_qkvif(params, x, cfg)
    h = mlstm_parallel(q, k, v, i_pre, f_pre)
    B, S = x.shape[0], x.shape[1]
    h = h.reshape(B, S, d_in)
    h = rms_norm(h, params["norm"]["scale"]) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h, params["w_down"])


def mlstm_decode(params: Dict, x: jax.Array, cfg: ModelConfig, state: Dict):
    d_in, H, Dh = _mlstm_dims(cfg)
    q, k, v, i_pre, f_pre, z, conv_state = _mlstm_qkvif(
        params, x, cfg, conv_state=state["conv"]
    )
    h, (C, n, m) = mlstm_recurrent(
        q, k, v, i_pre, f_pre, (state["C"], state["n"], state["m"])
    )
    B, S = x.shape[0], x.shape[1]
    h = h.reshape(B, S, d_in)
    h = rms_norm(h, params["norm"]["scale"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"])
    return out, {"conv": conv_state, "C": C, "n": n, "m": m}


def mlstm_state_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    xc: XLSTMConfig = cfg.xlstm
    d_in, H, Dh = _mlstm_dims(cfg)
    return {
        "conv": ParamSpec(
            (batch, xc.conv1d_kernel - 1, d_in), ("act_batch", None, "ffn"),
            "zeros", cfg.dtype,
        ),
        "C": ParamSpec((batch, H, Dh, Dh), ("act_batch", "heads", None, None),
                       "zeros", "float32"),
        "n": ParamSpec((batch, H, Dh), ("act_batch", "heads", None),
                       "zeros", "float32"),
        "m": ParamSpec((batch, H), ("act_batch", "heads"), "zeros", "float32"),
    }


# ---------------------------------------------------------------------------
# sLSTM block (recurrent; block-diagonal per-head hidden-to-hidden)
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    H = cfg.n_heads
    Dh = d // H
    dt = cfg.dtype
    d_up = int(d * xc.slstm_proj_factor)
    return {
        # gates: z, i, f, o — input projections
        "w_x": ParamSpec((d, 4 * d), ("embed", "ffn"), "scaled", dt),
        # recurrent per-head block-diagonal weights (H, Dh, 4*Dh)
        "w_h": ParamSpec((H, Dh, 4 * Dh), ("heads", None, None), "scaled", dt),
        "bias": ParamSpec((4 * d,), ("ffn",), "zeros", "float32"),
        "norm": norm_specs(d, "rmsnorm", dt),
        # post-block gated MLP (proj factor 4/3)
        "up_w": ParamSpec((d, 2 * d_up), ("embed", "ffn"), "scaled", dt),
        "down_w": ParamSpec((d_up, d), ("ffn", "embed"), "scaled", dt),
    }


def slstm_apply(
    params: Dict, x: jax.Array, cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    B, S, d = x.shape
    H = cfg.n_heads
    Dh = d // H
    f32 = jnp.float32

    x_gates = jnp.einsum("bsd,dg->bsg", x, params["w_x"]).astype(f32) + params["bias"]

    if state is None:
        state = {
            "h": jnp.zeros((B, H, Dh), f32),
            "c": jnp.zeros((B, H, Dh), f32),
            "n": jnp.ones((B, H, Dh), f32),
            "m": jnp.zeros((B, H, Dh), f32),
        }

    w_h = params["w_h"].astype(f32)  # (H, Dh, 4Dh)

    def step(carry, gx):
        h, c, n, m = carry["h"], carry["c"], carry["n"], carry["m"]
        rec = jnp.einsum("bhd,hdg->bhg", h, w_h)           # (B,H,4Dh)
        g = gx.reshape(B, H, 4 * Dh) + rec
        z_pre, i_pre, f_pre, o_pre = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        log_f = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        i_act = jnp.exp(i_pre - m_new)
        f_act = jnp.exp(log_f + m - m_new)
        c = f_act * c + i_act * z
        n = f_act * n + i_act
        h_new = o * c / jnp.maximum(n, 1e-6)
        new = {"h": h_new, "c": c, "n": n, "m": m_new}
        return new, h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(x_gates, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(y, params["norm"]["scale"])
    up = jnp.einsum("bsd,de->bse", y, params["up_w"])
    u, g = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bse,ed->bsd", u * jax.nn.gelu(g, approximate=True), params["down_w"])
    return y, state


def slstm_state_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    H = cfg.n_heads
    Dh = cfg.d_model // H
    ax = ("act_batch", "heads", None)
    return {
        "h": ParamSpec((batch, H, Dh), ax, "zeros", "float32"),
        "c": ParamSpec((batch, H, Dh), ax, "zeros", "float32"),
        "n": ParamSpec((batch, H, Dh), ax, "ones", "float32"),
        "m": ParamSpec((batch, H, Dh), ax, "zeros", "float32"),
    }
