"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

Faithful structure (arXiv:2405.16712 / 2411.15242, simplified where noted):
  * n_layers Mamba2 blocks form the backbone;
  * ONE shared transformer block (attention + MLP over width 2*d_model,
    fed concat([hidden, original_embedding])) is invoked every
    ``attn_every`` Mamba blocks — weights shared across invocations;
  * each invocation gets its own LoRA adapters on the attention input
    projection and the MLP input projection (Zamba2's trick to
    de-correlate reused weights at negligible parameter cost);
  * the shared block's output is projected back to d_model and added to
    the residual stream.

Simplifications (DESIGN.md §6): rotary attention inside the shared block
(Zamba2 does the same), single shared block (1.2B variant), LoRA rank
fixed at 64 on two projections (Zamba2 adapts every linear).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain_batch
from . import attention as attn
from . import mamba2
from .layers import ParamSpec, activation, norm_apply, norm_specs

__all__ = [
    "zamba_specs",
    "zamba_apply",
    "zamba_decode",
    "zamba_cache_specs",
    "n_shared_invocations",
]

LORA_RANK = 64


def n_shared_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def _shared_width(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model


def zamba_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, dt = cfg.d_model, cfg.dtype
    dw = _shared_width(cfg)
    n_inv = n_shared_invocations(cfg)
    h, hd = cfg.n_heads, dw // cfg.n_heads

    mamba_single = {
        "norm": norm_specs(d, cfg.norm, dt),
        "mixer": mamba2.mamba2_specs(cfg),
    }
    mamba_stack = jax.tree.map(
        lambda s: ParamSpec(
            (cfg.n_layers, *s.shape), ("layers", *s.axes), s.init, s.dtype, s.scale
        ),
        mamba_single,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )

    shared = {
        "norm": norm_specs(dw, cfg.norm, dt),
        "wqkv": ParamSpec(
            (dw, 3, h, hd), ("embed", None, "heads", "head_dim"), "scaled", dt
        ),
        "wo": ParamSpec((h, hd, dw), ("heads", "head_dim", "embed"), "scaled", dt),
        "mlp_norm": norm_specs(dw, cfg.norm, dt),
        "w_in": ParamSpec((dw, cfg.d_ff), ("embed", "ffn"), "scaled", dt),
        "w_gate": ParamSpec((dw, cfg.d_ff), ("embed", "ffn"), "scaled", dt),
        "w_out": ParamSpec((cfg.d_ff, dw), ("ffn", "embed"), "scaled", dt),
        "proj_down": ParamSpec((dw, d), ("embed", None), "scaled", dt),
        # Per-invocation LoRA adapters (stacked over invocations).
        "lora_qkv_a": ParamSpec((n_inv, dw, LORA_RANK), ("layers", "embed", None), "scaled", dt),
        "lora_qkv_b": ParamSpec((n_inv, LORA_RANK, 3 * h * hd), ("layers", None, None), "zeros", dt),
        "lora_mlp_a": ParamSpec((n_inv, dw, LORA_RANK), ("layers", "embed", None), "scaled", dt),
        "lora_mlp_b": ParamSpec((n_inv, LORA_RANK, cfg.d_ff), ("layers", None, None), "zeros", dt),
    }
    return {"mamba": mamba_stack, "shared": shared}


def _shared_block(
    params: Dict,
    h: jax.Array,
    x0: jax.Array,
    cfg: ModelConfig,
    lora: Dict,
    *,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    block_table: Optional[jax.Array] = None,
):
    """One invocation of the shared attention+MLP block. ``lora`` holds
    this invocation's adapters (already sliced from the stacks)."""
    dw = _shared_width(cfg)
    H, hd = cfg.n_heads, dw // cfg.n_heads
    t = jnp.concatenate([h, x0], axis=-1)
    tn = norm_apply(params["norm"], t, cfg.norm)

    qkv = jnp.einsum("bsd,dchk->bschk", tn, params["wqkv"])
    lora_in = jnp.einsum("bsd,dr->bsr", tn, lora["qkv_a"])
    qkv = qkv + jnp.einsum("bsr,re->bse", lora_in, lora["qkv_b"]).reshape(
        *tn.shape[:2], 3, H, hd
    )
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        o = attn.mea_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    else:
        ck = attn.cache_row_update(cache["k"], k, cache_index, block_table=block_table)
        cv = attn.cache_row_update(cache["v"], v, cache_index, block_table=block_table)
        if block_table is not None:
            kv_k = attn.paged_kv_view(ck, block_table)
            kv_v = attn.paged_kv_view(cv, block_table)
        else:
            kv_k, kv_v = ck, cv
        o = attn.decode_attention(
            q, kv_k, kv_v, length=attn.decode_lengths(cache_index, h.shape[0])
        )
        new_cache = {"k": ck, "v": cv}
    t = t + jnp.einsum("bshk,hkd->bsd", o, params["wo"])

    tn = norm_apply(params["mlp_norm"], t, cfg.norm)
    gate = jnp.einsum("bsd,df->bsf", tn, params["w_gate"])
    up = jnp.einsum("bsd,df->bsf", tn, params["w_in"])
    up = up + jnp.einsum(
        "bsr,rf->bsf",
        jnp.einsum("bsd,dr->bsr", tn, lora["mlp_a"]),
        lora["mlp_b"],
    )
    t = t + jnp.einsum("bsf,fd->bsd", activation(cfg.act)(gate) * up, params["w_out"])
    return jnp.einsum("bsd,de->bse", t, params["proj_down"]), new_cache


def _lora_slice(shared: Dict, idx) -> Dict:
    return {
        "qkv_a": shared["lora_qkv_a"][idx],
        "qkv_b": shared["lora_qkv_b"][idx],
        "mlp_a": shared["lora_mlp_a"][idx],
        "mlp_b": shared["lora_mlp_b"][idx],
    }


def zamba_apply(
    params: Dict, x: jax.Array, cfg: ModelConfig, *, positions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward: scan over (attn_every mamba blocks +
    one shared-block invocation) groups with per-block remat — small HLO,
    activation memory O(layers) block inputs only."""
    x0 = x
    h = x
    ae = cfg.attn_every or cfg.n_layers
    groups = n_shared_invocations(cfg) if cfg.attn_every else 0
    rem = cfg.n_layers - groups * ae

    def mamba_block(layer, h):
        hn = norm_apply(layer["norm"], h, cfg.norm)
        return constrain_batch(h + mamba2.mamba2_apply(layer["mixer"], hn, cfg))

    def shared_block(shared, lora, h):
        delta, _ = _shared_block(
            shared, h, x0, cfg, lora, positions=positions
        )
        return constrain_batch(h + delta)

    remat = jax.checkpoint if cfg.remat != "none" else (lambda f: f)
    mamba_block_r = remat(mamba_block)
    shared_block_r = remat(shared_block)

    if groups:
        grouped = jax.tree.map(
            lambda t: t[: groups * ae].reshape(groups, ae, *t.shape[1:]),
            params["mamba"],
        )
        lora_stack = _lora_slice(params["shared"], slice(None))

        def group_fn(carry, xs):
            layers6, lora = xs
            def layer_fn(hh, lp):
                return mamba_block_r(lp, hh), None
            hh, _ = jax.lax.scan(layer_fn, carry, layers6)
            hh = shared_block_r(params["shared"], lora, hh)
            return hh, None

        h, _ = jax.lax.scan(group_fn, h, (grouped, lora_stack))

    for i in range(cfg.n_layers - rem, cfg.n_layers):
        layer = jax.tree.map(lambda t: t[i], params["mamba"])
        h = mamba_block_r(layer, h)
    return h, jnp.zeros((), jnp.float32)


def zamba_decode(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    caches: Dict,
    *,
    positions: jax.Array,
    cache_index: jax.Array,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    x0 = x
    h = x
    inv = 0
    new_mamba_states = []
    new_attn_caches = []
    for i in range(cfg.n_layers):
        layer = jax.tree.map(lambda t: t[i], params["mamba"])
        state = jax.tree.map(lambda t: t[i], caches["mamba"])
        hn = norm_apply(layer["norm"], h, cfg.norm)
        delta, new_state = mamba2.mamba2_decode(layer["mixer"], hn, cfg, state)
        h = h + delta
        new_mamba_states.append(new_state)
        if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
            if inv < n_shared_invocations(cfg):
                cache = jax.tree.map(lambda t: t[inv], caches["attn"])
                delta, new_cache = _shared_block(
                    params["shared"], h, x0, cfg, _lora_slice(params["shared"], inv),
                    positions=positions, cache=cache, cache_index=cache_index,
                    block_table=block_tables,
                )
                h = h + delta
                new_attn_caches.append(new_cache)
                inv += 1
    stack = lambda trees: jax.tree.map(lambda *ts: jnp.stack(ts), *trees)
    return h, {"mamba": stack(new_mamba_states), "attn": stack(new_attn_caches)}


def zamba_cache_specs(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    page: Optional[Tuple[int, int]] = None,
) -> Dict:
    """Hybrid cache: recurrent Mamba2 states stay contiguous per-slot in
    every mode (no sequence axis to page); only the shared block's KV
    rows move into a block arena when ``page=(num_blocks, block_size)``
    is given — one arena row per (invocation, block)."""
    n_inv = n_shared_invocations(cfg)
    dw = _shared_width(cfg)
    hd = dw // cfg.n_heads
    mamba_state = jax.tree.map(
        lambda s: ParamSpec((cfg.n_layers, *s.shape), ("layers", *s.axes), s.init, s.dtype),
        mamba2.mamba2_state_spec(cfg, batch),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    if page is not None:
        num_blocks, block_size = page
        front = (n_inv, num_blocks + 1, block_size)
        axes = ("layers", "kv_blocks", "kv_block", "heads", "head_dim")
    else:
        front = (n_inv, batch, max_len)
        axes = ("layers", "act_batch", "act_kv_seq", "heads", "head_dim")
    attn_cache = {
        "k": ParamSpec((*front, cfg.n_heads, hd), axes, "zeros", cfg.dtype),
        "v": ParamSpec((*front, cfg.n_heads, hd), axes, "zeros", cfg.dtype),
    }
    return {"mamba": mamba_state, "attn": attn_cache}
