"""Unified observability plane: tracing, metrics, decisions, structured log.

One :class:`Observability` bundle travels through a run — the serve
engine/frontend/router, the train loop, the benchmarks all take an
optional ``obs`` and default to the shared :data:`NULL_OBS` singleton,
whose sub-components are all disabled no-ops. Enabling observability is
therefore a call-site decision (demos, tests, trace_report), never a
code-path fork, and the instrumented hot paths cost one attribute check
when it is off.

Components (each usable standalone):

* :class:`~repro.obs.trace.Tracer` — virtual-clock span/event tracer
  with Chrome/Perfetto ``trace_event`` export (``docs/observability.md``).
* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  streaming histograms, snapshot-able into ``BENCH_*.json``.
* :class:`~repro.obs.decisions.DecisionLog` — every adaptive
  (k, beta, gamma, n_h) reprice with the telemetry it was priced from.
* :class:`~repro.obs.log.StructuredLog` — typed run records; stdout is
  a formatted view of the same records (used by the examples).
"""

from __future__ import annotations

import json
from typing import Any, Dict

from repro.obs.decisions import Decision, DecisionLog
from repro.obs.log import LogRecord, StructuredLog
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import TID_MAIN, Tracer, validate_trace

__all__ = [
    "Observability",
    "NULL_OBS",
    "Tracer",
    "validate_trace",
    "TID_MAIN",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DecisionLog",
    "Decision",
    "StructuredLog",
    "LogRecord",
]


class Observability:
    """Bundle of tracer + metrics + decision log + structured log.

    ``enabled`` is True iff any recording component is on; hot paths use
    it to skip building args dicts entirely. The structured log is
    always constructed (it is cheap and the examples drive it directly)
    but records only when the bundle is enabled (or ``log_echo`` asks
    for it) and echoes to stdout only when asked.
    """

    def __init__(
        self,
        *,
        trace: bool = True,
        metrics: bool = True,
        decisions: bool = True,
        log_echo: bool = False,
    ):
        self.tracer = Tracer(enabled=trace)
        self.metrics = MetricsRegistry(enabled=metrics)
        self.decisions = DecisionLog(enabled=decisions)
        self.enabled = bool(trace or metrics or decisions)
        # A fully-disabled bundle (NULL_OBS) must not accumulate records
        # either — emit becomes a pure constructor.
        self.log = StructuredLog(echo=log_echo,
                                 enabled=self.enabled or log_echo)

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(trace=False, metrics=False, decisions=False)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able cross-component summary (metrics + decisions +
        structured records + trace size). Trace events themselves are
        exported separately via ``tracer.export`` — they can be large."""
        return {
            "metrics": self.metrics.snapshot(),
            "decisions": self.decisions.to_jsonable(),
            "log": self.log.to_jsonable(),
            "trace_events": len(self.tracer.events),
            "open_spans": list(self.tracer.open_spans),
        }

    def export_snapshot(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)


#: Shared disabled bundle — the default ``obs`` everywhere. Do not
#: mutate; instruments handed out by its registry are stateless nulls.
NULL_OBS = Observability.disabled()
