"""Controller decision log: every adaptive reprice, with its inputs.

The paper's whole contribution is a sequence of *decisions* — which
(k, beta) stage to run, how wide to draft (gamma), how far to fan a
hedge out (n_h) — each priced from noisy, censored telemetry. A run
that merely *executes* those decisions is unexplainable after the fact;
this log records each one WITH the inputs it was priced from (fitted
lambda from the censored MLE, sample/censor counts, acceptance
estimates, slowdown vectors, stage index), so "why did the controller
switch at step 83?" has a machine-readable answer.

Domains used by the instrumented planes:

* ``train.stage``  — Controller stage walk: decision {k, beta},
  inputs {stage_idx, n, lambda_hat, rt_samples, rt_censored, ...}
* ``serve.hedge``  — HedgedRouter fan-out: decision {n_h, k, replicas},
  inputs {slowdowns, n_alive, beta}
* ``serve.gamma``  — SpecController draft length: decision {gamma, n_h},
  inputs {p, observations, rounds, cost_per_token}

Producers log a decision when it CHANGES (a reprice), not on every
evaluation of an unchanged policy — the log stays proportional to the
number of adaptation events, and a bounded ``cap`` guards against a
pathological flip-flopping controller (drops are counted, never
silent).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

__all__ = ["Decision", "DecisionLog"]


@dataclasses.dataclass(frozen=True)
class Decision:
    domain: str                   # e.g. "train.stage", "serve.gamma"
    decision: Dict[str, Any]      # what was chosen
    inputs: Dict[str, Any]        # the telemetry it was priced from
    step: Optional[int] = None    # producer-local step/round index
    vtime: Optional[float] = None  # virtual time of the reprice

    def to_jsonable(self) -> Dict[str, Any]:
        return {
            "domain": self.domain,
            "step": self.step,
            "vtime": self.vtime,
            "decision": dict(self.decision),
            "inputs": dict(self.inputs),
        }


class DecisionLog:
    def __init__(self, enabled: bool = True, cap: int = 10_000):
        self.enabled = bool(enabled)
        self.cap = int(cap)
        self.entries: List[Decision] = []
        self.dropped = 0              # entries past cap (never silent)

    def record(
        self,
        domain: str,
        decision: Dict[str, Any],
        inputs: Dict[str, Any],
        *,
        step: Optional[int] = None,
        vtime: Optional[float] = None,
    ) -> None:
        if not self.enabled:
            return
        if len(self.entries) >= self.cap:
            self.dropped += 1
            return
        self.entries.append(Decision(domain, decision, inputs, step, vtime))

    def by_domain(self, domain: str) -> List[Decision]:
        return [d for d in self.entries if d.domain == domain]

    def to_jsonable(self) -> Dict[str, Any]:
        return {
            "entries": [d.to_jsonable() for d in self.entries],
            "dropped": self.dropped,
        }
