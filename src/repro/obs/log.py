"""Structured run log: typed records first, stdout as a formatted view.

The demos (``examples/elastic_serving.py``, ``examples/elastic_failover.py``)
used to report with raw ``print`` — human-readable, machine-opaque. A
``StructuredLog`` inverts that: callers emit RECORDS (kind + fields, an
optional virtual timestamp), assertions and post-hoc analysis read the
records, and stdout output — when ``echo`` is on — is just a formatted
rendering of the very same records. Nothing is printed that is not also
captured.
"""

from __future__ import annotations

import dataclasses
import json
import sys
from typing import Any, Dict, List, Optional, TextIO

__all__ = ["LogRecord", "StructuredLog"]


@dataclasses.dataclass(frozen=True)
class LogRecord:
    kind: str
    fields: Dict[str, Any]
    t: Optional[float] = None     # virtual time, when the producer has one

    def format(self) -> str:
        head = f"[{self.kind}]"
        if self.t is not None:
            head = f"t={self.t:10.4f} {head}"
        body = " ".join(f"{k}={_fmt(v)}" for k, v in self.fields.items())
        return f"{head} {body}".rstrip()

    def to_jsonable(self) -> Dict[str, Any]:
        return {"kind": self.kind, "t": self.t, "fields": dict(self.fields)}


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4f}"
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(_fmt(x) for x in v) + "]"
    return str(v)


class StructuredLog:
    def __init__(
        self,
        echo: bool = False,
        stream: Optional[TextIO] = None,
        enabled: bool = True,
    ):
        """``enabled=False`` makes ``emit`` a pure constructor: nothing
        is stored or echoed. The shared ``NULL_OBS`` bundle uses this so
        un-instrumented runs cannot grow global state."""
        self.echo = bool(echo)
        self.enabled = bool(enabled)
        self.stream = stream or sys.stdout
        self.records: List[LogRecord] = []

    def emit(self, kind: str, t: Optional[float] = None, **fields: Any) -> LogRecord:
        rec = LogRecord(kind, fields, t)
        if not self.enabled:
            return rec
        self.records.append(rec)
        if self.echo:
            print(rec.format(), file=self.stream, flush=True)
        return rec

    def by_kind(self, kind: str) -> List[LogRecord]:
        return [r for r in self.records if r.kind == kind]

    def last(self, kind: str) -> Optional[LogRecord]:
        for r in reversed(self.records):
            if r.kind == kind:
                return r
        return None

    def to_jsonable(self) -> List[Dict[str, Any]]:
        return [r.to_jsonable() for r in self.records]

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_jsonable(), f, indent=2, sort_keys=True)
