"""Metrics registry: counters, gauges, and streaming histograms.

The registry is the numbers-over-a-run companion to the tracer's
timeline: arena block occupancy, slot high-water, draft acceptance,
hedge win/cancel ratios, censoring fraction, per-step train wait/compute
split. Everything is plain host arithmetic — no jax, no device sync —
and a disabled registry hands out shared null instruments whose methods
are no-ops, so instrumented hot paths cost one attribute call when
observability is off.

Determinism: histograms keep an exact count/sum/min/max and a bounded
sample reservoir for quantiles. The reservoir decimates
DETERMINISTICALLY (sort, keep every other sample) when it exceeds its
cap — no RNG — so two identical runs snapshot identical p50/p99 and
benchmark JSON stays reproducible.

Instrument names are dotted paths (``engine.generated_tokens``,
``sched.queue_wait``); a name is bound to one instrument kind for the
registry's lifetime (reusing it as a different kind raises).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Union

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone event count (``inc`` by any non-negative amount)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, v: Union[int, float] = 1) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v

    def snapshot(self) -> Union[int, float]:
        return self.value


class Gauge:
    """Point-in-time level plus its high-water mark (slot occupancy,
    arena blocks in use, queue depth)."""

    __slots__ = ("name", "value", "high_water")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0
        self.high_water: float = 0.0

    def set(self, v: Union[int, float]) -> None:
        self.value = v
        if v > self.high_water:
            self.high_water = v

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value, "high_water": self.high_water}


class Histogram:
    """Streaming distribution: exact count/sum/min/max, quantiles from a
    deterministically decimated reservoir (default cap 4096 samples)."""

    __slots__ = ("name", "cap", "count", "total", "min", "max", "_values")

    def __init__(self, name: str, cap: int = 4096):
        self.name = name
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._values: List[float] = []

    def observe(self, v: Union[int, float]) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self._values.append(v)
        if len(self._values) > self.cap:
            self._values.sort()
            self._values = self._values[::2]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        if not self._values:
            return float("nan")
        return float(np.percentile(np.asarray(self._values), q))

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": round(self.total, 9),
            "min": self.min if self.count else "nan",
            "max": self.max if self.count else "nan",
            "mean": round(self.mean, 9) if self.count else "nan",
            "p50": round(self.percentile(50), 9) if self.count else "nan",
            "p99": round(self.percentile(99), 9) if self.count else "nan",
        }


class _NullCounter:
    __slots__ = ()

    def inc(self, v: Union[int, float] = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0
    high_water = 0.0

    def set(self, v: Union[int, float]) -> None:
        pass


class _NullHistogram:
    __slots__ = ()

    def observe(self, v: Union[int, float]) -> None:
        pass


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Get-or-create registry of named instruments. Disabled registries
    hand out shared null instruments (no state, no allocation)."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        if not self.enabled:
            return _NULL_HISTOGRAM
        return self._get(name, Histogram, cap=cap)

    def get(self, name: str) -> Optional[object]:
        """The instrument registered under ``name``, or None."""
        return self._instruments.get(name)

    def snapshot(self) -> Dict[str, object]:
        """JSON-able view of every instrument, sorted by name — this is
        what benchmarks embed in their ``BENCH_*.json`` payloads."""
        return {
            name: inst.snapshot()
            for name, inst in sorted(self._instruments.items())
        }
