"""Span/event tracer keyed on the repo's VIRTUAL clocks.

Every plane in this repo already runs on deterministic virtual time (the
serve ``EventClock`` / ``FaultyClock`` family, the train loop's
``sim_time``), which makes runs perfectly replayable — and, until now,
perfectly opaque. The tracer turns those clocks into an inspectable
timeline: callers stamp spans and instants with virtual seconds, and the
tracer exports Chrome/Perfetto ``trace_event`` JSON (open
``chrome://tracing`` or https://ui.perfetto.dev and drop the file in).

Design rules (docs/observability.md):

* **Virtual time is the timeline.** ``ts`` fields are virtual
  microseconds. Wall-clock (``time.perf_counter``) is captured per event
  in a parallel buffer and merged into ``args`` only on
  ``to_json(include_wall=True)`` — the default export contains no wall
  time, so two runs with identical seeds produce BYTE-IDENTICAL JSON
  (pinned in tests/test_obs.py).
* **Zero cost when disabled.** A disabled tracer's methods return
  immediately (one attribute check); hot paths may additionally guard
  arg-dict construction on ``tracer.enabled``.
* **Span hygiene is checkable.** Request-lifecycle spans are async
  ("b"/"e") events with tracer-assigned ids; ``open_spans`` lists every
  begun-but-unclosed span so tests can assert none leak, even under
  chaos (cancel / deadline-expiry / migration paths must close them).
* **Tracks are processes.** Each engine replica, the frontend, and the
  train loop register a Chrome "process" (``register_process``) so the
  timeline renders one lane per virtual clock; within a process, action
  events (prefill chunks, decode ticks, spec rounds, idle jumps) are
  complete ("X") events on tid 0, emitted in clock order — which is the
  monotonicity invariant ``validate_trace`` enforces.

Event vocabulary used by the instrumented planes (all optional — the
tracer itself is name-agnostic):

==============  ====  =====================================================
name            ph    emitted by
==============  ====  =====================================================
``request``     b/e   engine per local request; frontend per logical gid
``prefill``     X     one prefill chunk (args: rid, start, n_tokens, done)
``decode``      X     one pool-wide decode tick (args: lanes)
``spec_round``  X     one draft+verify round (args: gamma, lanes, committed)
``idle``        X     clock jump to the next arrival
``train_step``  X     one fastest-k training step (args: step, k, beta, ...)
``cancel``      i     explicit cancel / deadline expiry (args: rid, reason)
``migrate_out`` i     request exported as a MigrationTicket
``migrate_in``  i     ticket restored into an engine
``dispatch``    i     frontend hedge fan-out (args: gid, replicas)
``fault``       i     chaos FaultEvent applied (args: kind, worker)
==============  ====  =====================================================
"""

from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "validate_trace", "TID_MAIN"]

#: default track id inside a registered process (one lane per clock).
TID_MAIN = 0


def _us(t: float) -> float:
    """Virtual seconds -> trace microseconds, rounded so JSON stays
    compact and stable (sub-nanosecond float dust would still be
    deterministic, but renders horribly in Perfetto tooltips)."""
    return round(float(t) * 1e6, 3)


class Tracer:
    """Chrome ``trace_event`` collector over virtual clocks."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.events: List[Dict[str, Any]] = []
        self._wall: List[float] = []        # perf_counter per event (parallel)
        self._wall0 = time.perf_counter()
        self._procs: Dict[int, str] = {}    # pid -> display name
        self._next_pid = 1
        self._next_sid = 1
        self._open: Dict[int, Dict[str, Any]] = {}   # sid -> begin event

    # -- low-level emit ------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        self.events.append(ev)
        self._wall.append(time.perf_counter() - self._wall0)

    # -- processes (one per virtual clock) -----------------------------------
    def register_process(self, name: str) -> int:
        """Allocate a trace process (= timeline lane) and name it. Safe
        to call on a disabled tracer (returns pid 0, emits nothing).
        Names need not be unique; pids always are."""
        if not self.enabled:
            return 0
        pid = self._next_pid
        self._next_pid += 1
        self._procs[pid] = name
        self._emit({
            "ph": "M", "name": "process_name", "pid": pid, "tid": TID_MAIN,
            "args": {"name": name},
        })
        return pid

    # -- spans (async: request lifecycles overlap across slots) --------------
    def begin_span(
        self, name: str, pid: int, ts: float,
        args: Optional[Dict[str, Any]] = None, cat: str = "lifecycle",
    ) -> int:
        """Open an async span; returns the span id to close it with.
        Disabled tracers return 0 (``end_span(0, ...)`` is a no-op)."""
        if not self.enabled:
            return 0
        sid = self._next_sid
        self._next_sid += 1
        ev = {
            "ph": "b", "cat": cat, "name": name, "pid": pid, "tid": TID_MAIN,
            "id": sid, "ts": _us(ts),
        }
        if args:
            ev["args"] = args
        self._emit(ev)
        self._open[sid] = ev
        return sid

    def end_span(
        self, sid: int, ts: float, args: Optional[Dict[str, Any]] = None,
    ) -> None:
        if not self.enabled or sid == 0:
            return
        begin = self._open.pop(sid, None)
        if begin is None:
            raise ValueError(f"end_span for unknown/closed span id {sid}")
        ev = {
            "ph": "e", "cat": begin["cat"], "name": begin["name"],
            "pid": begin["pid"], "tid": TID_MAIN, "id": sid, "ts": _us(ts),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    @property
    def open_spans(self) -> List[str]:
        """Names of begun-but-unclosed spans (must be [] after a clean
        run — the span-hygiene invariant)."""
        return [ev["name"] for ev in self._open.values()]

    # -- complete events (engine actions: one per clock advance) -------------
    def complete(
        self, name: str, pid: int, t0: float, t1: float,
        args: Optional[Dict[str, Any]] = None, cat: str = "action",
    ) -> None:
        if not self.enabled:
            return
        ev = {
            "ph": "X", "cat": cat, "name": name, "pid": pid, "tid": TID_MAIN,
            "ts": _us(t0), "dur": round(_us(t1) - _us(t0), 3),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- instants -------------------------------------------------------------
    def instant(
        self, name: str, pid: int, ts: float,
        args: Optional[Dict[str, Any]] = None, cat: str = "event",
    ) -> None:
        if not self.enabled:
            return
        ev = {
            "ph": "i", "cat": cat, "name": name, "pid": pid, "tid": TID_MAIN,
            "ts": _us(ts), "s": "p",
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- counter series -------------------------------------------------------
    def counter(
        self, name: str, pid: int, ts: float, values: Dict[str, float],
    ) -> None:
        """Chrome counter ("C") sample — renders as a stacked area chart
        under the process (e.g. arena block occupancy over time)."""
        if not self.enabled:
            return
        self._emit({
            "ph": "C", "name": name, "pid": pid, "tid": TID_MAIN,
            "ts": _us(ts), "args": dict(values),
        })

    # -- export ---------------------------------------------------------------
    def to_json(self, include_wall: bool = False) -> str:
        """Chrome ``trace_event`` JSON. Without ``include_wall`` the
        output is a pure function of the virtual execution — identical
        seeds produce byte-identical strings."""
        if include_wall:
            events = []
            for ev, w in zip(self.events, self._wall):
                ev = dict(ev)
                args = dict(ev.get("args", ()))
                args["wall_s"] = round(w, 6)
                ev["args"] = args
                events.append(ev)
        else:
            events = self.events
        return json.dumps(
            {"traceEvents": events, "displayTimeUnit": "ms"},
            sort_keys=True, separators=(",", ":"),
        )

    def export(self, path: str, include_wall: bool = False) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(include_wall))


def validate_trace(events: List[Dict[str, Any]]) -> List[str]:
    """Structural invariants a healthy trace must satisfy. Returns a
    list of human-readable violations (empty = valid). Enforced by the
    obs-smoke CI job and tests/test_obs.py.

    1. every async "b" has exactly one matching "e" (same pid/cat/id)
       with ``end.ts >= begin.ts`` — no orphan or inverted spans;
    2. every "X" has ``dur >= 0``;
    3. per (pid, tid), "X" and "i" timestamps are non-decreasing in file
       order — each process is one virtual clock, and clocks only move
       forward.
    """
    errors: List[str] = []
    open_spans: Dict[tuple, Dict[str, Any]] = {}
    last_ts: Dict[tuple, float] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None or "pid" not in ev:
            errors.append(f"event {i}: missing ph/pid: {ev}")
            continue
        key = (ev["pid"], ev.get("tid", 0))
        if ph == "b":
            sk = (ev["pid"], ev.get("cat"), ev.get("id"))
            if sk in open_spans:
                errors.append(f"event {i}: duplicate open span {sk}")
            open_spans[sk] = ev
        elif ph == "e":
            sk = (ev["pid"], ev.get("cat"), ev.get("id"))
            begin = open_spans.pop(sk, None)
            if begin is None:
                errors.append(f"event {i}: orphan span end {sk}")
            elif ev["ts"] < begin["ts"]:
                errors.append(
                    f"event {i}: span {begin['name']!r} ends at {ev['ts']} "
                    f"before it begins at {begin['ts']}"
                )
        elif ph == "X":
            if ev.get("dur", 0) < 0:
                errors.append(f"event {i}: negative duration: {ev}")
            if ev["ts"] < last_ts.get(key, float("-inf")):
                errors.append(
                    f"event {i}: non-monotone ts on track {key}: "
                    f"{ev['ts']} < {last_ts[key]} ({ev.get('name')})"
                )
            last_ts[key] = ev["ts"]
        elif ph == "i":
            if ev["ts"] < last_ts.get(key, float("-inf")):
                errors.append(
                    f"event {i}: non-monotone ts on track {key}: "
                    f"{ev['ts']} < {last_ts[key]} ({ev.get('name')})"
                )
            last_ts[key] = ev["ts"]
    for sk, begin in open_spans.items():
        errors.append(f"unclosed span {begin.get('name')!r} {sk}")
    return errors
