"""Pure-JAX optimizers (optax is not available offline): SGD, momentum,
AdamW, Adafactor.

API mirrors optax: ``init(params) -> state``, ``update(grads, state,
params) -> (updates, state)``; apply with ``apply_updates``. All states
are pytrees of arrays sharded like their params (the launch layer attaches
the shardings), so ZeRO-style optimizer-state sharding falls out of the
param sharding rules for free.

Adafactor is the default for the 671B config: factored second moments cut
optimizer state from 2x fp32 params to ~(row+col) sums, which is what
makes the deepseek train cells fit 16 GB/chip at 512 chips (see
EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "sgd",
    "momentum",
    "adamw",
    "adafactor",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "get_optimizer",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (updates, new_state)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype),
                        params, updates)


# ---------------------------------------------------------------------------

def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(mu: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        new_m = jax.tree.map(
            lambda m, g: mu * m + g.astype(jnp.float32), state, grads
        )
        if nesterov:
            upd = jax.tree.map(
                lambda m, g: -lr * (mu * m + g.astype(jnp.float32)), new_m, grads
            )
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class _AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype: Any = jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return _AdamState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd_m(m, g):
            return (b1 * m + (1 - b1) * g.astype(jnp.float32)).astype(state_dtype)

        def upd_v(v, g):
            gf = g.astype(jnp.float32)
            return (b2 * v + (1 - b2) * gf * gf).astype(state_dtype)

        new_m = jax.tree.map(upd_m, state.m, grads)
        new_v = jax.tree.map(upd_v, state.v, grads)

        def step_fn(m, v, p):
            mh = m.astype(jnp.float32) / c1
            vh = v.astype(jnp.float32) / c2
            u = -lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return u

        upd = jax.tree.map(step_fn, new_m, new_v, params)
        return upd, _AdamState(step=step, m=new_m, v=new_v)

    return Optimizer(init, update)


class _FactorState(NamedTuple):
    step: jax.Array
    # per-leaf dict: {"row": ..., "col": ...} (factored) or {"v": ...} (full).
    # Dict keys live in the treedef, not the leaves, so the state is jit-safe.
    states: Any


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_factored: int = 128,
) -> Optimizer:
    """Adafactor (Shazeer & Stern) without LR warmup logic (schedules are
    external). Matrices with both trailing dims >= min_dim_factored use
    factored second moments; everything else stores a full fp32 v."""

    def _is_factored(p) -> bool:
        return (
            p.ndim >= 2
            and p.shape[-1] >= min_dim_factored
            and p.shape[-2] >= min_dim_factored
        )

    def init(params):
        def one(p):
            if _is_factored(p):
                return {
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),   # reduce last
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return _FactorState(
            step=jnp.zeros((), jnp.int32),
            states=jax.tree.map(one, params, is_leaf=lambda x: hasattr(x, "shape")),
        )

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def factored_math(gf, row, col):
            g2 = gf * gf + eps
            new_row = beta * row + (1 - beta) * g2.mean(axis=-1)
            new_col = beta * col + (1 - beta) * g2.mean(axis=-2)
            row_mean = new_row.mean(axis=-1, keepdims=True)
            r = new_row / jnp.maximum(row_mean, eps)
            vhat = r[..., None] * new_col[..., None, :]
            u = gf / jnp.sqrt(jnp.maximum(vhat, eps))
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return u, new_row, new_col

        # Leaves above this many elements (the stacked-layer MoE weights
        # reach 2e11) are updated per-layer via lax.map so the fp32 temps
        # are one layer, not the whole stack — without this the optimizer
        # update transiently allocates several fp32 copies of a ~0.9 TB
        # tensor's shard and blows the per-device peak.
        MAP_ELEMS = 2 ** 31

        def one(g, s, p):
            gf = g.astype(jnp.float32)
            if "row" in s:
                if p.size >= MAP_ELEMS and p.ndim >= 3:
                    # Per-layer slices; emit the stacked update in the
                    # param dtype so no fp32 copy of the full stack exists.
                    def _sliced(args):
                        u_l, r_l, c_l = factored_math(
                            args[0].astype(jnp.float32), args[1], args[2]
                        )
                        return u_l.astype(p.dtype), r_l, c_l

                    u, new_row, new_col = jax.lax.map(
                        _sliced, (g, s["row"], s["col"])
                    )
                else:
                    u, new_row, new_col = factored_math(gf, s["row"], s["col"])
                new_s = {"row": new_row, "col": new_col}
            else:
                g2 = gf * gf + eps
                new_v = beta * s["v"] + (1 - beta) * g2
                u = gf / jnp.sqrt(jnp.maximum(new_v, eps))
                rms = jnp.sqrt(jnp.mean(u * u))
                u = u / jnp.maximum(1.0, rms / clip_threshold)
                new_s = {"v": new_v}
            return -lr * u, new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state.states)
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        upd = treedef.unflatten([o[0] for o in outs])
        new_states = treedef.unflatten([o[1] for o in outs])
        return upd, _FactorState(step=step, states=new_states)

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name}")
