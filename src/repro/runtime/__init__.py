from .checkpoint import CheckpointManager
from .faults import FaultEvent, schedule_by_step
from .steps import make_decode_step, make_prefill_step, make_train_step
from .telemetry import StragglerTracker

__all__ = [
    "CheckpointManager",
    "FaultEvent",
    "schedule_by_step",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "StragglerTracker",
]
