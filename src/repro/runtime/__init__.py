from .checkpoint import CheckpointManager
from .steps import make_decode_step, make_prefill_step, make_train_step
from .telemetry import StragglerTracker

__all__ = [
    "CheckpointManager",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "StragglerTracker",
]
