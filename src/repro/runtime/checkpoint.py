"""Checkpointing: atomic, async, resumable (orbax is not available offline).

Layout (one directory per step):
    <root>/step_000123/
        arrays.npz          — flattened pytree leaves (host numpy)
        meta.json           — step, controller state, RNG, treedef repr
    <root>/LATEST           — atomically updated pointer file

Guarantees:
  * atomicity  — writes land in a tmp dir, fsync'd, then os.rename (POSIX
    atomic) + pointer update; a crash mid-save never corrupts LATEST;
  * async      — ``save_async`` snapshots to host memory synchronously
    (cheap) and writes in a daemon thread, overlapping the next steps;
  * resume     — ``restore_latest`` reloads (params, opt_state, extras),
    re-sharding leaves onto the CURRENT mesh (elastic restarts onto a
    different topology re-use the same files);
  * retention  — keep_last N checkpoints, older ones pruned post-save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointError", "CheckpointManager", "CHECKPOINT_SCHEMA"]

#: bump when the on-disk layout changes incompatibly. Checkpoints written
#: before the field existed load as version 1.
CHECKPOINT_SCHEMA = 1


class CheckpointError(RuntimeError):
    """A checkpoint on disk cannot be loaded: truncated or corrupt
    ``arrays.npz``/``meta.json``, or a schema version this build does not
    understand. Always names the offending path — the recovery action
    (delete the directory, fall back to an older step, upgrade the code)
    depends on WHICH file is bad."""


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _fsync_dir(path: Path) -> None:
    """fsync a DIRECTORY: durably commit its entries (the renames).

    File-content fsyncs alone do not make an os.rename durable — the
    new directory entry lives in the parent directory's data, which has
    its own fd to sync. No-op on platforms without directory fds.
    """
    flags = os.O_RDONLY | getattr(os, "O_DIRECTORY", 0)
    try:
        fd = os.open(path, flags)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, root: str | Path, keep_last: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extras: Optional[dict] = None) -> Path:
        """Synchronous atomic save of a pytree + json-serializable extras."""
        arrays = _flatten_with_paths(state)
        tmp = self.root / f".tmp_step_{step:09d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **arrays)
        meta = {"step": step, "time": time.time(),
                "schema": CHECKPOINT_SCHEMA, "extras": extras or {}}
        (tmp / "meta.json").write_text(json.dumps(meta))
        # Durability order: file contents -> tmp dir entries -> atomic
        # rename -> parent dir entry (the rename itself) -> LATEST.
        for f in tmp.iterdir():
            with open(f, "rb") as fh:
                os.fsync(fh.fileno())
        _fsync_dir(tmp)
        final = self.root / f"step_{step:09d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.root)
        self._update_latest(final.name)
        self._prune()
        return final

    def save_async(self, step: int, state, extras: Optional[dict] = None):
        """Snapshot to host memory now; write in the background."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self.save(step, host_state, extras)
            except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = self.root / "LATEST"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.root / name).exists():
            return None
        try:
            return int(name.split("_")[-1])
        except ValueError as e:
            raise CheckpointError(
                f"corrupt LATEST pointer {ptr}: {name!r} is not a "
                "step_NNNNNNNNN directory name"
            ) from e

    def restore(
        self,
        step: int,
        like,
        device_put_fn: Optional[Callable[[np.ndarray, Any], Any]] = None,
    ) -> Tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). device_put_fn(leaf, like_leaf) can re-shard
        onto the current mesh (elastic restart)."""
        d = self.root / f"step_{step:09d}"
        if not d.is_dir():
            raise CheckpointError(f"no checkpoint directory at {d}")
        arrays_path, meta_path = d / "arrays.npz", d / "meta.json"
        try:
            with np.load(arrays_path) as data:
                arrays = {k: data[k] for k in data.files}
        except FileNotFoundError as e:
            raise CheckpointError(f"checkpoint missing {arrays_path}") from e
        except Exception as e:  # zipfile.BadZipFile, OSError, ValueError, ...
            raise CheckpointError(
                f"truncated or corrupt checkpoint arrays at {arrays_path}: {e}"
            ) from e
        try:
            meta = json.loads(meta_path.read_text())
        except FileNotFoundError as e:
            raise CheckpointError(f"checkpoint missing {meta_path}") from e
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointError(
                f"truncated or corrupt checkpoint metadata at {meta_path}: {e}"
            ) from e
        schema = meta.get("schema", 1)
        if schema != CHECKPOINT_SCHEMA:
            raise CheckpointError(
                f"checkpoint {meta_path} has schema version {schema!r}; this "
                f"build reads version {CHECKPOINT_SCHEMA} — load it with a "
                "matching build instead of guessing at the layout"
            )

        leaves_with_paths = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        out = []
        for path, leaf in leaves_with_paths:
            key = "/".join(str(p) for p in path)
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key}")
            val = arrays[key]
            if device_put_fn is not None:
                val = device_put_fn(val, leaf)
            out.append(val)
        return jax.tree_util.tree_unflatten(treedef, out), meta["extras"]

    def restore_latest(self, like, device_put_fn=None):
        step = self.latest_step()
        if step is None:
            return None
        state, extras = self.restore(step, like, device_put_fn)
        return step, state, extras

    # -- internals ------------------------------------------------------------
    def _update_latest(self, name: str):
        ptr_tmp = self.root / ".LATEST_tmp"
        with open(ptr_tmp, "w") as fh:
            fh.write(name)
            fh.flush()
            os.fsync(fh.fileno())
        os.rename(ptr_tmp, self.root / "LATEST")
        _fsync_dir(self.root)  # the pointer flip must survive a crash too

    def _prune(self):
        steps = sorted(
            p for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("step_")
        )
        for old in steps[: -self.keep_last]:
            shutil.rmtree(old, ignore_errors=True)
