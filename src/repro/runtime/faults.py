"""Declarative fault schedules shared by the training and serving planes.

One schema describes chaos for both workloads: a ``FaultEvent`` names a
unit of capacity (a training *worker* or a serving *replica* — the field
is ``worker`` for historical reasons), a step at which the event fires,
and what happens to it:

  * ``"fail"``   — the unit dies (permanent unless it rejoins);
  * ``"rejoin"`` — a previously removed unit comes back healthy
    (capacity += 1, telemetry history reset so stale slowness cannot
    re-demote it);
  * ``"slow"``   — the unit's response times are multiplied by
    ``factor`` from this step on (1.0 = recovered);
  * ``"drain"``  — serving plane only: graceful decommission — every
    in-flight request migrates off (KV block handoff) before the unit
    leaves the fleet; the training loop ignores this kind.

``step`` is whatever discrete clock the consuming loop advances: the
training loop counts optimizer steps (``runtime.train_loop``), the
serving plane counts engine actions (``serve.frontend``). Both consume
the schedule through :func:`schedule_by_step`.

The schema is intentionally *injection only*: it describes what the
environment does to the fleet. How the control plane reacts — censored
telemetry, demotion, re-pricing ``(k, beta)`` or ``(n_h, k)`` from the
shrunken fleet — must come from observations alone, never from reading
this schedule (that is the oracle-free contract both chaos demos pin).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

__all__ = ["FaultEvent", "schedule_by_step"]


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """A scheduled chaos event: unit ``worker`` at step ``step``.

    Validated at CONSTRUCTION — a malformed event (unknown kind,
    negative step/worker, non-positive slow factor) raises here, at the
    point where the schedule is written, instead of failing deep inside
    the consuming plane's event loop."""

    step: int
    kind: str
    worker: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in ("fail", "rejoin", "slow", "drain"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.worker < 0:
            raise ValueError(f"fault worker must be >= 0, got {self.worker}")
        if self.factor <= 0:
            raise ValueError(
                f"slow factor must be > 0, got {self.factor} "
                "(use kind='fail' to remove a unit, factor=1.0 to restore)"
            )

    def as_dict(self) -> dict:
        """JSON-serializable form (chaos-search repro schedules)."""
        return {"step": self.step, "kind": self.kind,
                "worker": self.worker, "factor": self.factor}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        return cls(step=int(d["step"]), kind=str(d["kind"]),
                   worker=int(d["worker"]), factor=float(d.get("factor", 1.0)))


def schedule_by_step(events: Iterable[FaultEvent]) -> Dict[int, List[FaultEvent]]:
    """Index a flat event list by step, preserving in-step order."""
    by_step: Dict[int, List[FaultEvent]] = {}
    for ev in events:
        by_step.setdefault(ev.step, []).append(ev)
    return by_step
