"""The jitted step functions: train_step / prefill_step / decode_step.

These are the units the dry-run lowers and the production loop executes.
``make_train_step`` builds a pure function

    (params, opt_state, batch) -> (params, opt_state, metrics)

where batch = {inputs, labels, [mask], worker_mask, lr}. The fastest-k
worker mask enters as DATA (recompile-free across stages with the same
shapes); per-stage beta changes the batch shape and hits the compile
cache keyed by shape — by design (DESIGN.md §2.3).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.collectives import contributors, masked_weighted_ce
from repro.dist.sharding import activation_sharding
from repro.models.model import Model
from repro.optim.optimizers import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_slot_prefill_step",
    "make_decode_step",
    "make_slot_decode_step",
    "make_slot_verify_step",
    "make_slot_replay_step",
    "make_init_fn",
]


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    clip_norm: Optional[float] = 1.0,
    accum_steps: int = 1,
    accum_dtype=jnp.float32,
    param_shardings=None,
    gather_shardings=None,
) -> Callable:
    """param_shardings: optional pytree of NamedShardings matching params;
    gradients are constrained to them (scatter-formed grads — embedding
    rows in particular — otherwise come out replicated under SPMD).

    gather_shardings: ZeRO-1 mode — params are all-gathered ONCE per step
    to this (non-FSDP) layout and reused across every remat pass and
    accumulation microbatch; gradients reduce-scatter back to the sharded
    layout at the boundary. Kills the per-layer-per-microbatch FSDP weight
    re-gather traffic (§Perf)."""
    cfg = model.cfg

    def _gather(params):
        if gather_shardings is None:
            return params
        return jax.tree.map(
            lambda p, sh: jax.lax.with_sharding_constraint(p, sh),
            params, gather_shardings,
        )

    def _pin(grads):
        if param_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            grads, param_shardings,
        )

    def loss_fn(params, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        positions = jnp.arange(labels.shape[1])
        h, aux = model.hidden(params, inputs, positions)
        logits = model.logits(params, h)
        ce, denom = masked_weighted_ce(
            logits, labels, batch.get("mask"), batch.get("worker_mask")
        )
        loss = ce
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
        if cfg.mtp:
            mask = batch.get("mask")
            if mask is None:
                mask = jnp.ones(labels.shape, jnp.float32)
            mtp = model._mtp_loss(params, h, inputs, labels, mask, positions)
            loss = loss + 0.3 * mtp
        return loss, {"ce": ce, "aux": aux, "denom": denom}

    def _grads_direct(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            _gather(params), batch
        )
        return loss, metrics, _pin(grads)

    def _grads_accum(params, batch):
        """Microbatched gradient accumulation (scan over A slices).

        The batch is worker-major; each worker's b_w examples are split
        evenly across the A microbatches so the fastest-k example
        weighting stays exact. Per-microbatch gradients are combined
        weighted by their masked token counts (metrics['denom']), which
        reproduces the single-big-batch gradient bit-for-bit in exact
        arithmetic."""
        A = accum_steps
        n = batch["worker_mask"].shape[0]
        B = batch["inputs"].shape[0]
        bw = B // n
        assert bw % A == 0, f"per-worker batch {bw} not divisible by accum {A}"

        def resh(x):
            x = x.reshape(n, A, bw // A, *x.shape[1:])
            x = jnp.moveaxis(x, 1, 0)
            return x.reshape(A, n * (bw // A), *x.shape[3:])

        mb = {k: resh(batch[k]) for k in ("inputs", "labels") if k in batch}
        if batch.get("mask") is not None:
            mb["mask"] = resh(batch["mask"])

        params_g = _gather(params)  # ZeRO-1: one gather, reused by all microbatches

        def body(carry, xs):
            gsum, lsum, dsum, auxsum = carry
            micro = dict(xs)
            micro["worker_mask"] = batch["worker_mask"]
            micro["lr"] = batch["lr"]
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params_g, micro
            )
            grads = _pin(grads)
            w = metrics["denom"]
            gsum = jax.tree.map(
                lambda a, g: (a + w * g.astype(jnp.float32)).astype(accum_dtype),
                gsum, grads,
            )
            return (gsum, lsum + w * loss, dsum + w, auxsum + metrics["aux"]), None

        gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (gsum, lsum, dsum, auxsum), _ = jax.lax.scan(
            body, (gsum0, 0.0, jnp.float32(0.0), jnp.float32(0.0)), mb
        )
        dsum = jnp.maximum(dsum, 1.0)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / dsum, gsum)
        loss = lsum / dsum
        metrics = {"ce": loss, "aux": auxsum / accum_steps, "denom": dsum}
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if accum_steps > 1:
            loss, metrics, grads = _grads_accum(params, batch)
        else:
            loss, metrics, grads = _grads_direct(params, batch)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        updates, opt_state = optimizer.update(grads, opt_state, params, batch["lr"])
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics.update(
            loss=loss,
            grad_norm=gnorm,
            contributors=(
                contributors(batch["worker_mask"])
                if batch.get("worker_mask") is not None
                else jnp.asarray(0.0)
            ),
        )
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, inputs):
        return model.prefill(params, inputs)

    return prefill_step


def make_slot_prefill_step(model: Model) -> Callable:
    """Cache-writing batched prefill for the serving engine.

    (params, inputs (B,P) right-padded, caches, length (B,), start_index,
    [block_tables]) -> (last-valid logits (B,1,V), caches). Like the
    fastest-k ``worker_mask``, the ragged-length information enters as
    DATA — one compile per (B, P-bucket) shape, re-used across every
    admission. ``block_tables`` (B, T) routes the chunk's cache rows
    through paged arenas (None = contiguous slot stripes)."""

    def slot_prefill_step(params, inputs, caches, length, start_index,
                          block_tables=None):
        return model.prefill_with_cache(
            params, inputs, caches, length=length, start_index=start_index,
            block_tables=block_tables,
        )

    return slot_prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, token, caches, cache_index):
        return model.decode_step(params, token, caches, cache_index)

    return decode_step


def make_slot_decode_step(model: Model) -> Callable:
    """One decode tick over the whole slot pool.

    ``cache_index`` is the per-slot position vector (n_slots,) — every
    slot sits at its own length; free slots ride along as masked lanes
    (their writes land in dead rows and are overwritten at allocation),
    so occupancy never changes the compiled shape."""

    def slot_decode_step(params, tokens, caches, cache_index, block_tables=None):
        return model.decode_step(
            params, tokens, caches, cache_index, block_tables=block_tables
        )

    return slot_decode_step


def make_slot_verify_step(model: Model) -> Callable:
    """Speculative verify over the whole slot pool: one fused multi-token
    call scores every lane's draft window at its own position.

    (params, tokens (B, S), caches, n_input (B,), positions (B,),
    [block_tables]) -> (greedy tokens (B, S) int32, caches). Per-lane
    draft lengths ride along as DATA (``n_input``; 0 = free lane, 1 =
    plain decode, 1 + gamma = speculating) — one compile per window
    width S covers every round. The caches come back committed per the
    family-specific contract of ``Model.verify_with_cache``: the caller
    applies the exact-argmax acceptance rule to the returned greedy
    tokens and rewinds its per-slot positions to the accepted prefix."""

    def slot_verify_step(params, tokens, caches, n_input, positions,
                         block_tables=None):
        logits, caches = model.verify_with_cache(
            params, tokens, caches, n_input, positions,
            block_tables=block_tables,
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    return slot_verify_step


def make_slot_replay_step(model: Model) -> Callable:
    """Draft-side resync after a verify round: commit exactly ``n_input``
    already-known tokens per lane into the caches (no acceptance chain —
    the tokens ARE the committed stream). Same shapes as
    ``make_slot_verify_step``; returns only the caches."""

    def slot_replay_step(params, tokens, caches, n_input, positions,
                         block_tables=None):
        _, caches = model.verify_with_cache(
            params, tokens, caches, n_input, positions,
            block_tables=block_tables, greedy_commit=False,
        )
        return caches

    return slot_replay_step


def make_init_fn(model: Model, optimizer: Optimizer) -> Callable:
    """(rng) -> (params, opt_state); jit-able so init can be sharded."""

    def init(rng):
        params = model.init(rng)
        return params, optimizer.init(params)

    return init
