"""Worker response-time telemetry: censoring-aware EWMA + straggler detection.

The controller consumes raw response times for delay-model fitting; this
module adds the ops-level view: per-worker mean-response-time estimates,
relative slowdown scores, and persistent-straggler detection used for
demotion (a worker that is consistently slower than the fleet median by
a large factor is removed from n — the paper's order statistics then
reprice every stage decision automatically).

Censoring discipline
--------------------
On real hardware a fastest-k step observes only the k winners' times; an
alive worker outside the fastest k is *censored* at the step's k-th
order statistic (all we learn is "slower than z_(k)"). A plain EWMA over
observed times can never flag a true persistent straggler — it is never
observed, so its estimate never moves. Instead each worker keeps a
decayed *total-time-on-test* pair (the per-worker analogue of the
censored MLE ``fit_simplified_mle_censored`` uses fleet-wide):

    T_w <- (1 - a) T_w + a * (observed time, or the censor level)
    D_w <- (1 - a) D_w + a * (1 if observed else 0)
    mean_w = T_w / D_w

For a worker that is always observed this reduces exactly to the EWMA of
its times (D_w == 1). For a worker that stops being observed, D_w decays
toward 0 while T_w tracks the censor level, so mean_w grows without
bound — the honest statement that only lower bounds are known.

Because a worker with NO observation ever has an unbounded estimate, the
demotion test adds a fairness guard: a never-observed worker is only
flagged once its *expected* win count under exchangeable response times
(sum of k_t / n_t over its eligible rounds) reaches ``min_expected_wins``
— i.e. only when being shut out is statistically damning (P <= e^-4
under fairness), not merely unlucky.

Both accumulators are seeded per worker on that worker's FIRST eligible
round — never globally — so a worker that joins (or is first observed)
late starts from its own data instead of crawling up from 0 and being
misread as fast.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

__all__ = ["StragglerTracker"]


class StragglerTracker:
    def __init__(
        self,
        n_workers: int,
        alpha: float = 0.1,
        warmup: int = 16,
        min_expected_wins: float = 4.0,
        metrics=None,
    ):
        """``metrics``: optional duck-typed ``repro.obs.MetricsRegistry``
        (kept optional so this module stays dependency-free for the
        training runtime). When set, every ``observe`` feeds the
        ``telemetry.censored_fraction`` histogram — the fraction of
        eligible workers whose time was a censor level, the quantity the
        censored MLE's accuracy hinges on."""
        self.n = n_workers
        self.alpha = alpha
        self.warmup = warmup
        self.min_expected_wins = min_expected_wins
        self.ttt = np.zeros(n_workers)      # decayed total time on test
        self.obs = np.zeros(n_workers)      # decayed observed-completion weight
        self.rounds = np.zeros(n_workers, np.int64)  # eligible rounds per worker
        self.wins = np.zeros(n_workers, np.int64)    # actual observations
        self.expw = np.zeros(n_workers)     # expected wins under fairness
        self._h_censored = (
            metrics.histogram("telemetry.censored_fraction")
            if metrics is not None else None
        )

    def observe(
        self,
        response_times: np.ndarray,
        alive: np.ndarray,
        observed: Optional[np.ndarray] = None,
        censor_level: Optional[float] = None,
    ) -> None:
        """Record one step of telemetry.

        ``response_times[w]`` is meaningful only where ``observed[w]``
        (a worker the step actually waited for). With a ``censor_level``
        (the step's k-th order statistic), alive-but-unobserved workers
        contribute that level to their time-on-test — the lower bound
        real hardware knows.

        Back-compat: with ``observed=None`` every finite, alive time is
        treated as observed and nothing is censored (full-information
        telemetry, e.g. the hedged router observing every completion).
        """
        z = np.asarray(response_times, dtype=np.float64)
        alive = np.asarray(alive, dtype=bool)
        if observed is None:
            observed = np.isfinite(z) & alive
        else:
            observed = np.asarray(observed, dtype=bool) & alive
        # Without a censor level unobserved workers carry no information;
        # with one, every alive worker accrues time-on-test.
        eligible = observed if censor_level is None else alive
        contrib = np.where(
            observed, z, 0.0 if censor_level is None else float(censor_level)
        )
        fresh = eligible & (self.rounds == 0)
        cont = eligible & ~fresh
        # Per-worker seed on the first eligible round (never global).
        self.ttt[fresh] = contrib[fresh]
        self.obs[fresh] = observed[fresh].astype(np.float64)
        a = self.alpha
        self.ttt[cont] += a * (contrib[cont] - self.ttt[cont])
        self.obs[cont] += a * (observed[cont].astype(np.float64) - self.obs[cont])
        self.rounds[eligible] += 1
        self.wins[observed] += 1
        if censor_level is None:
            self.expw[observed] += 1.0
        else:
            n_t = int(eligible.sum())
            if n_t:
                self.expw[eligible] += float(observed.sum()) / n_t
        if self._h_censored is not None:
            n_e = int(eligible.sum())
            if n_e:
                self._h_censored.observe(
                    1.0 - float(observed[eligible].sum()) / n_e
                )

    def reset_worker(self, w: int) -> None:
        """Forget a worker's history (e.g. it rejoined after recovery)."""
        self.ttt[w] = 0.0
        self.obs[w] = 0.0
        self.rounds[w] = 0
        self.wins[w] = 0
        self.expw[w] = 0.0

    def mean_estimate(self) -> np.ndarray:
        """Per-worker censoring-corrected mean response time.

        nan = no data yet; a worker with eligible rounds but no
        observation has an effectively unbounded estimate (only lower
        bounds are known), which is exactly what the slowdown test
        should see.
        """
        est = self.ttt / np.maximum(self.obs, 1e-12)
        return np.where(self.rounds > 0, est, np.nan)

    def slowdown(self) -> np.ndarray:
        """Per-worker mean estimate / fleet median (1.0 = typical).

        The median is taken over workers with at least one real
        observation, so never-observed stragglers cannot drag the
        reference level up.
        """
        est = self.mean_estimate()
        seen = np.isfinite(est) & (est > 0) & (self.wins > 0)
        med = float(np.median(est[seen])) if seen.any() else 1.0
        return est / max(med, 1e-12)

    def persistent_stragglers(self, threshold: float) -> List[int]:
        ready = self.rounds >= self.warmup
        slow = self.slowdown() > threshold  # nan compares False: no data, no flag
        # Fairness guard: a worker with zero observations is only
        # damning once it *should* have won several times.
        fair = (self.wins > 0) | (self.expw >= self.min_expected_wins)
        return [int(i) for i in np.nonzero(ready & slow & fair)[0]]

    # -- checkpoint round-trip ----------------------------------------------
    def state_dict(self) -> dict:
        return {
            "n": self.n,
            "ttt": self.ttt.tolist(),
            "obs": self.obs.tolist(),
            "rounds": self.rounds.tolist(),
            "wins": self.wins.tolist(),
            "expw": self.expw.tolist(),
        }

    def load_state_dict(self, d: dict) -> None:
        if int(d["n"]) != self.n:
            raise ValueError(
                f"tracker sized for {self.n} workers, state has {d['n']}"
            )
        self.ttt = np.asarray(d["ttt"], np.float64)
        self.obs = np.asarray(d["obs"], np.float64)
        self.rounds = np.asarray(d["rounds"], np.int64)
        self.wins = np.asarray(d["wins"], np.int64)
        self.expw = np.asarray(d["expw"], np.float64)
