"""Worker response-time telemetry: EWMA tracking + straggler detection.

The controller consumes raw response times for delay-model fitting; this
module adds the ops-level view: per-worker EWMAs, relative slowdown
scores, and persistent-straggler detection used for demotion (a worker
that is consistently slower than the fleet median by a large factor is
removed from n — the paper's order statistics then reprice every stage
decision automatically).
"""

from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["StragglerTracker"]


class StragglerTracker:
    def __init__(self, n_workers: int, alpha: float = 0.1, warmup: int = 16):
        self.n = n_workers
        self.alpha = alpha
        self.warmup = warmup
        self.ewma = np.zeros(n_workers)
        self.count = 0

    def observe(self, response_times: np.ndarray, alive: np.ndarray) -> None:
        z = np.asarray(response_times, dtype=np.float64)
        finite = np.isfinite(z) & alive
        if self.count == 0:
            self.ewma[finite] = z[finite]
        else:
            self.ewma[finite] += self.alpha * (z[finite] - self.ewma[finite])
        self.count += 1

    def slowdown(self) -> np.ndarray:
        """Per-worker EWMA / fleet median (1.0 = typical)."""
        med = np.median(self.ewma[self.ewma > 0]) if (self.ewma > 0).any() else 1.0
        return self.ewma / max(med, 1e-12)

    def persistent_stragglers(self, threshold: float) -> List[int]:
        if self.count < self.warmup:
            return []
        return [int(i) for i in np.nonzero(self.slowdown() > threshold)[0]]
