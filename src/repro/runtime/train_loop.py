"""Production training loop: the paper's controller driving a JAX model.

Wires together:
  * ``Controller`` (adaptive-(k,beta) stages, stationarity diagnostics,
    online delay-model estimation from CENSORED telemetry),
  * per-stage compiled train steps (compile cache keyed by batch shape),
  * masked fastest-k aggregation (the worker mask is DATA — no recompile
    across straggler subsets; per-stage beta batch shape is the only
    recompile axis),
  * async checkpointing + exact resume (full control state, telemetry,
    and RNG streams round-trip, so a resumed run replays the exact
    history the uninterrupted run would have produced),
  * fault handling: worker failure -> permanent mask + controller n-=1;
    persistent straggler demotion via censoring-aware telemetry; worker
    REJOIN -> controller n+=1 (``Controller.add_worker``).

Censoring discipline (DESIGN.md §2.5): a fastest-k step only ever
observes the k response times it waited for. The controller receives
exactly those k order statistics plus the count of censored workers, and
fits the delay model with the censored MLE — feeding it the full
uncensored sample (including times of workers the step never waited for)
is physically impossible on real hardware and was the bug this loop
used to have.

On real hardware the response times come from per-host step telemetry;
in this container they are sampled from the paper's delay models — the
control path is identical (DESIGN.md §2).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import Controller, Stage, StrategyConfig
from repro.core.order_stats import DelayModel
from repro.data.pipeline import StagedBatcher
from repro.dist.collectives import check_worker_major
from repro.dist.sharding import activation_sharding
from repro.models.model import Model
from repro.obs import NULL_OBS, Observability
from repro.optim.optimizers import Optimizer
from repro.runtime.checkpoint import CheckpointManager
# FaultEvent moved to repro.runtime.faults (PR 7) so the serving plane can
# consume the same chaos schema; re-exported here for compatibility.
from repro.runtime.faults import FaultEvent, schedule_by_step
from repro.runtime.steps import make_train_step
from repro.runtime.telemetry import StragglerTracker

__all__ = ["FaultEvent", "TrainLoopConfig", "train"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    lr: float = 3e-4
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    estimate_model: bool = True      # fit delay model from (censored) telemetry
    oracle_to_controller: bool = True  # False: controller sees ONLY telemetry
    fail_worker_at: Optional[int] = None   # legacy single-failure injection
    fail_worker_id: int = 0
    demote_after_ewma: Optional[float] = None  # straggler demotion threshold
    events: Sequence[FaultEvent] = ()          # chaos schedule


def _event_schedule(cfg: TrainLoopConfig) -> Dict[int, List[FaultEvent]]:
    events = list(cfg.events)
    if cfg.fail_worker_at is not None:
        events.append(FaultEvent(cfg.fail_worker_at, "fail", cfg.fail_worker_id))
    return schedule_by_step(events)


def train(
    model: Model,
    optimizer: Optimizer,
    strategy: StrategyConfig,
    delay_model: DelayModel,
    batcher: StagedBatcher,
    loop_cfg: TrainLoopConfig,
    mesh=None,
    obs: Optional[Observability] = None,
) -> Dict[str, Any]:
    """Run the adaptive-(k,beta) training loop. Returns history dict.

    ``obs``: observability bundle (``repro.obs``). When enabled, every
    step lands as a ``train_step`` complete event on the loop's
    ``sim_time`` lane, chaos/demotion transitions as ``fault`` instants,
    the per-step wait/compute split as histograms, and every stage
    switch as a ``train.stage`` decision-log entry carrying the censored
    telemetry it was priced from."""
    obs = obs or NULL_OBS
    tr_obs = obs.tracer
    pid = tr_obs.register_process("train")
    rng = np.random.default_rng(loop_cfg.seed)
    ctrl = Controller(
        strategy,
        model=delay_model if loop_cfg.oracle_to_controller else None,
        estimate_model=loop_cfg.estimate_model,
    )
    n0 = strategy.n  # fleet size at loop start; worker ids are 0..n0-1
    tracker = StragglerTracker(
        n0, metrics=obs.metrics if obs.enabled else None
    )
    schedule = _event_schedule(loop_cfg)
    h_step = obs.metrics.histogram("train.step_time")
    # Wait = how long the FASTEST observed worker idled for the k-th
    # (the straggler tax fastest-k is buying down); compute = the mean
    # observed response time (what the workers were actually doing).
    h_wait = obs.metrics.histogram("train.wait")
    h_compute = obs.metrics.histogram("train.compute")
    g_workers = obs.metrics.gauge("train.n_workers")

    step_fn_cache: Dict[tuple, Callable] = {}
    base_step = make_train_step(model, optimizer)

    def compiled_step(shape):
        if shape not in step_fn_cache:
            step_fn_cache[shape] = jax.jit(base_step, donate_argnums=(0, 1))
        return step_fn_cache[shape]

    params, opt_state = model.init(jax.random.PRNGKey(loop_cfg.seed)), None
    opt_state = optimizer.init(params)

    ckpt = (
        CheckpointManager(loop_cfg.checkpoint_dir)
        if loop_cfg.checkpoint_dir
        else None
    )
    alive = np.ones(n0, bool)
    slow_factor = np.ones(n0)
    sim_time = 0.0
    start_step = 0
    if ckpt is not None:
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, state, extras = restored
            params, opt_state = state["params"], state["opt"]
            if extras.get("controller"):
                # Full control-state resume: controller (stage walk +
                # diagnostic + telemetry), straggler tracker, fleet
                # membership, the event clock, and both RNG streams.
                ctrl.load_state_dict(extras["controller"])
                tracker.load_state_dict(extras["tracker"])
                alive = np.asarray(extras["alive"], bool)
                slow_factor = np.asarray(extras["slow_factor"], np.float64)
                sim_time = float(extras["sim_time"])
                rng.bit_generator.state = extras["rng_state"]
                batcher.stream.rng.bit_generator.state = extras["stream_rng_state"]
            elif extras.get("stage"):
                # Older checkpoints carried only the stage pair.
                ctrl.stage = Stage(**extras["stage"])

    history: List[Dict[str, float]] = []

    ctx = activation_sharding(mesh) if mesh is not None else contextlib.nullcontext()
    with ctx:
        for step in range(start_step, loop_cfg.total_steps):
            # ---- chaos events -------------------------------------------
            for ev in schedule.get(step, ()):
                applied = False
                if ev.kind == "fail" and alive[ev.worker]:
                    alive[ev.worker] = False
                    ctrl.remove_worker()
                    applied = True
                elif ev.kind == "rejoin" and not alive[ev.worker]:
                    alive[ev.worker] = True
                    slow_factor[ev.worker] = ev.factor
                    tracker.reset_worker(ev.worker)
                    ctrl.add_worker()
                    applied = True
                elif ev.kind == "slow":
                    slow_factor[ev.worker] = ev.factor
                    applied = True
                if applied and obs.enabled:
                    obs.metrics.counter(f"train.fault.{ev.kind}").inc()
                    tr_obs.instant(
                        "fault", pid, sim_time,
                        args={"kind": ev.kind, "worker": ev.worker,
                              "step": step},
                    )

            # ---- pending demotions from telemetry -----------------------
            if loop_cfg.demote_after_ewma is not None:
                for w in tracker.persistent_stragglers(loop_cfg.demote_after_ewma):
                    if alive[w] and alive.sum() > 1:
                        alive[w] = False
                        ctrl.remove_worker()
                        if obs.enabled:
                            obs.metrics.counter("train.demotions").inc()
                            tr_obs.instant(
                                "demote", pid, sim_time,
                                args={"worker": int(w), "step": step},
                            )

            # ---- the n-contract: controller and fleet must agree --------
            n_active = int(alive.sum())
            if n_active != ctrl.cfg.n:
                raise RuntimeError(
                    f"fleet/controller divergence: {n_active} alive workers "
                    f"but controller prices n={ctrl.cfg.n}"
                )
            active_ids = np.nonzero(alive)[0]
            stage = ctrl.stage

            # ---- response times + fastest-k mask ------------------------
            # Sample the FULL original fleet every step so the RNG stream
            # consumption is independent of membership (exact resume and
            # run-to-run comparability), then restrict to active workers.
            z_full = delay_model.sample(rng, n0, stage.beta) * slow_factor
            z_act = z_full[active_ids]
            k_eff = min(stage.k, n_active)
            order = np.argpartition(z_act, k_eff - 1)[:k_eff]
            t_step = float(z_act[order].max())
            t0_step = sim_time
            sim_time += t_step
            mask = np.zeros(n_active, np.float32)
            mask[order] = 1.0

            # ---- censored telemetry -------------------------------------
            # Only the k waited-for times are observable on real hardware;
            # everyone else is censored at the step time z_(k).
            selected = np.zeros(n0, bool)
            selected[active_ids[order]] = True
            tracker.observe(z_full, alive, observed=selected, censor_level=t_step)

            # ---- batch sized for the CURRENT fleet ----------------------
            np_batch = batcher.batch_for_stage(stage.beta, n_workers=n_active)
            check_worker_major(np_batch["inputs"].shape[0], n_active)
            batch = {
                "inputs": jnp.asarray(np_batch["inputs"]),
                "labels": jnp.asarray(np_batch["labels"]),
                "worker_mask": jnp.asarray(mask),
                "lr": jnp.float32(loop_cfg.lr),
            }
            fn = compiled_step(np_batch["inputs"].shape)
            params, opt_state, metrics = fn(params, opt_state, batch)

            loss = float(metrics["loss"])
            ctrl.observe(
                loss=loss,
                response_times=np.sort(z_act[order]),
                n_unobserved=n_active - k_eff,
            )
            switched = ctrl.maybe_advance()

            if obs.enabled:
                observed = np.sort(z_act[order])
                h_step.observe(t_step)
                h_wait.observe(t_step - float(observed[0]))
                h_compute.observe(float(observed.mean()))
                g_workers.set(n_active)
                tr_obs.complete(
                    "train_step", pid, t0_step, sim_time,
                    args={"step": step, "k": stage.k,
                          "beta": float(stage.beta),
                          "n_workers": n_active,
                          "loss": round(loss, 6)},
                )
                if switched is not None:
                    tr_obs.instant(
                        "stage_switch", pid, sim_time,
                        args={"step": step, "k": switched.k,
                              "beta": float(switched.beta)},
                    )
                    fitted = ctrl.current_model()
                    obs.decisions.record(
                        "train.stage",
                        {"k": switched.k, "beta": float(switched.beta)},
                        {"stage_idx": ctrl.stage_idx,
                         "n": ctrl.cfg.n,
                         "rt_samples": len(ctrl._rt_samples),
                         "rt_censored": int(sum(ctrl._rt_censored)),
                         "lambda_y": (
                             round(float(fitted.lambda_y), 6)
                             if fitted is not None else None
                         )},
                        step=step, vtime=sim_time,
                    )

            history.append(
                {
                    "step": step,
                    "loss": loss,
                    "k": stage.k,
                    "beta": stage.beta,
                    "n_workers": n_active,
                    "sim_time": sim_time,
                    "contributors": float(metrics["contributors"]),
                    "grad_norm": float(metrics["grad_norm"]),
                }
            )
            if switched is not None:
                history[-1]["switched_to"] = (switched.k, switched.beta)

            if ckpt is not None and (step + 1) % loop_cfg.checkpoint_every == 0:
                ckpt.save_async(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    extras={
                        "stage": dataclasses.asdict(ctrl.stage),  # legacy key
                        "controller": ctrl.state_dict(),
                        "tracker": tracker.state_dict(),
                        "alive": [int(a) for a in alive],
                        "slow_factor": [float(f) for f in slow_factor],
                        "sim_time": sim_time,
                        "rng_state": rng.bit_generator.state,
                        "stream_rng_state": batcher.stream.rng.bit_generator.state,
                    },
                )

            if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                # The structured record is the source of truth; the
                # legacy print stays as its stdout view unless the log
                # is already echoing its own rendering.
                obs.log.emit(
                    "train_step", t=sim_time, step=step,
                    loss=round(loss, 4), k=stage.k,
                    beta=float(stage.beta), workers=n_active,
                )
                if not obs.log.echo:
                    print(
                        f"step {step:5d} loss {loss:8.4f} k={stage.k:2d} "
                        f"beta={stage.beta:4.2f} t={sim_time:9.2f} "
                        f"workers={n_active}",
                        flush=True,
                    )

    if ckpt is not None:
        ckpt.wait()
    return {
        "history": history,
        "params": params,
        "opt_state": opt_state,
        "controller": ctrl,
        "tracker": tracker,
        "alive": alive,
        "compiled_shapes": list(step_fn_cache.keys()),
        "sim_time": sim_time,
    }
