"""Production training loop: the paper's controller driving a JAX model.

Wires together:
  * ``Controller`` (adaptive-(k,beta) stages, stationarity diagnostics,
    online delay-model estimation from telemetry),
  * per-stage compiled train steps (compile cache keyed by batch shape),
  * masked fastest-k aggregation (worker mask from simulated/observed
    response times),
  * async checkpointing + exact resume,
  * fault handling: worker failure -> permanent mask + controller n-=1;
    persistent straggler demotion via response-time EWMA.

On real hardware the response times come from per-host step telemetry;
in this container they are sampled from the paper's delay models — the
control path is identical (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import Controller, StrategyConfig
from repro.core.order_stats import DelayModel
from repro.data.pipeline import StagedBatcher
from repro.dist.sharding import activation_sharding
from repro.models.model import Model
from repro.optim.optimizers import Optimizer
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.steps import make_train_step
from repro.runtime.telemetry import StragglerTracker

__all__ = ["TrainLoopConfig", "train"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 200
    lr: float = 3e-4
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    estimate_model: bool = True      # fit delay model from telemetry
    fail_worker_at: Optional[int] = None   # inject a permanent failure
    fail_worker_id: int = 0
    demote_after_ewma: Optional[float] = None  # straggler demotion threshold


def train(
    model: Model,
    optimizer: Optimizer,
    strategy: StrategyConfig,
    delay_model: DelayModel,
    batcher: StagedBatcher,
    loop_cfg: TrainLoopConfig,
    mesh=None,
) -> Dict[str, Any]:
    """Run the adaptive-(k,beta) training loop. Returns history dict."""
    rng = np.random.default_rng(loop_cfg.seed)
    ctrl = Controller(
        strategy,
        model=delay_model,
        estimate_model=loop_cfg.estimate_model,
    )
    tracker = StragglerTracker(strategy.n)

    step_fn_cache: Dict[tuple, Callable] = {}
    base_step = make_train_step(model, optimizer)

    def compiled_step(shape):
        if shape not in step_fn_cache:
            step_fn_cache[shape] = jax.jit(base_step, donate_argnums=(0, 1))
        return step_fn_cache[shape]

    params, opt_state = model.init(jax.random.PRNGKey(loop_cfg.seed)), None
    opt_state = optimizer.init(params)

    ckpt = (
        CheckpointManager(loop_cfg.checkpoint_dir)
        if loop_cfg.checkpoint_dir
        else None
    )
    start_step = 0
    if ckpt is not None:
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, state, extras = restored
            params, opt_state = state["params"], state["opt"]
            if extras.get("stage"):
                from repro.core.controller import Stage

                ctrl.stage = Stage(**extras["stage"])

    alive = np.ones(strategy.n, bool)
    history: List[Dict[str, float]] = []
    sim_time = 0.0

    ctx = activation_sharding(mesh) if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, loop_cfg.total_steps):
            stage = ctrl.stage
            # ---- failure injection -------------------------------------
            if loop_cfg.fail_worker_at is not None and step == loop_cfg.fail_worker_at:
                alive[loop_cfg.fail_worker_id] = False
                ctrl.remove_worker()

            # ---- response times + fastest-k mask ------------------------
            z = delay_model.sample(rng, strategy.n, stage.beta)
            z = np.where(alive, z, np.inf)
            k_eff = min(stage.k, int(alive.sum()))
            order = np.argpartition(z, k_eff - 1)
            mask = np.zeros(strategy.n, np.float32)
            mask[order[:k_eff]] = 1.0
            sim_time += float(z[order[:k_eff]].max())
            tracker.observe(z, alive)
            if loop_cfg.demote_after_ewma is not None:
                for w in tracker.persistent_stragglers(loop_cfg.demote_after_ewma):
                    if alive[w] and alive.sum() > 1:
                        alive[w] = False
                        ctrl.remove_worker()

            # ---- batch for this stage's beta ----------------------------
            np_batch = batcher.batch_for_stage(stage.beta)
            batch = {
                "inputs": jnp.asarray(np_batch["inputs"]),
                "labels": jnp.asarray(np_batch["labels"]),
                "worker_mask": jnp.asarray(
                    mask[: np_batch["inputs"].shape[0]]
                    if strategy.n > np_batch["inputs"].shape[0]
                    else mask
                ),
                "lr": jnp.float32(loop_cfg.lr),
            }
            fn = compiled_step(np_batch["inputs"].shape)
            params, opt_state, metrics = fn(params, opt_state, batch)

            loss = float(metrics["loss"])
            ctrl.observe(loss=loss, response_times=z[np.isfinite(z)])
            switched = ctrl.maybe_advance()

            history.append(
                {
                    "step": step,
                    "loss": loss,
                    "k": stage.k,
                    "beta": stage.beta,
                    "sim_time": sim_time,
                    "contributors": float(metrics["contributors"]),
                    "grad_norm": float(metrics["grad_norm"]),
                }
            )
            if switched is not None:
                history[-1]["switched_to"] = (switched.k, switched.beta)

            if ckpt is not None and (step + 1) % loop_cfg.checkpoint_every == 0:
                ckpt.save_async(
                    step + 1,
                    {"params": params, "opt": opt_state},
                    extras={"stage": dataclasses.asdict(ctrl.stage)},
                )

            if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                print(
                    f"step {step:5d} loss {loss:8.4f} k={stage.k:2d} "
                    f"beta={stage.beta:4.2f} t={sim_time:9.2f} "
                    f"workers={int(alive.sum())}",
                    flush=True,
                )

    if ckpt is not None:
        ckpt.wait()
    return {
        "history": history,
        "params": params,
        "opt_state": opt_state,
        "controller": ctrl,
        "compiled_shapes": list(step_fn_cache.keys()),
        "sim_time": sim_time,
    }


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
