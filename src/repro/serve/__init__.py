"""repro.serve — continuous-batching inference with order-statistics
hedged dispatch (DESIGN.md §10, end-to-end guide in docs/serving.md).

The training side of this repo prices every scheduling decision with the
expected k-th order statistic of worker response times; this package
applies the same machinery to a second workload: serving. A fixed-shape
slot pool + masked decode tick give recompile-free continuous batching
(engine/kv_pool/scheduler), the KV cache optionally pages into a global
block arena with admit-by-budget admission so memory tracks live tokens
(kv_pool.BlockManager, DESIGN.md §11), a multi-replica router prices
hedged dispatch with ``expected_kth`` against EWMA straggler telemetry
(router), and a draft model over a twin slot pool turns decode ticks
into draft-then-verify rounds with an adaptively priced draft length
(speculative, DESIGN.md §12). On top of all that sits a REAL serving
plane: N independent engine replicas with their own faultable clocks
(replica) behind an async frontend (frontend) that dispatches hedges
concurrently, actually frees loser slots and paged blocks on
cancellation, polices per-request deadlines with bounded
retry-and-requeue, degrades gracefully as the live fleet shrinks, and
migrates in-flight requests between replicas by KV block handoff
(DESIGN.md §13, chaos-tested in tests/test_replicas.py). Since PR 9 the
frontend↔replica hop is an explicit, faultable message transport
(transport): submits / cancels / stream chunks / migration tickets are
wire messages a declarative fault plan can drop, duplicate, reorder,
delay, or partition away, and an idempotent at-least-once layer (acks,
receiver dedup, telemetry-priced retransmission, ticket integrity
checksums) keeps every zero-drop / byte-identity guarantee intact —
property-searched by tools/chaos_search.py (DESIGN.md §15,
docs/chaos.md).

Public API contract: modules split cleanly into SPEC-DRIVEN (engine,
kv_pool, speculative — generic over any ``model.cache_specs`` tree; no
per-architecture code) and MODEL-AGNOSTIC (scheduler, router — pure
host logic that never touches arrays). Model-specific behavior enters
only through the ``Model`` serving methods (``cache_specs``,
``prefill_with_cache``, ``decode_step``, ``verify_with_cache``) and is
pinned per registered family by tests/test_serve.py and
tests/test_speculative.py's byte-identity suites.
"""

from .engine import (
    EngineStats,
    MigrationTicket,
    ServeEngine,
    TicketIntegrityError,
    generate_offline,
    run_static,
    ticket_checksum,
)
from .frontend import Frontend, FrontendRequest
from .kv_pool import (
    ArenaExhausted,
    BlockManager,
    PrefixIndex,
    SlotPool,
    SlotSnapshot,
)
from .replica import FaultyClock, Replica, ReplicaPort
from .router import DispatchOutcome, HedgedRouter, HedgePlan, ReplicaSet
from .scheduler import CostModel, EventClock, Request, Scheduler, next_bucket
from .speculative import DraftRunner, GammaPlan, SpecController, hedged_round_cost
from .transport import (
    FaultDirective,
    Partition,
    Transport,
    TransportFaults,
    TransportGaveUp,
)

__all__ = [
    "ServeEngine",
    "EngineStats",
    "MigrationTicket",
    "TicketIntegrityError",
    "ticket_checksum",
    "generate_offline",
    "run_static",
    "Transport",
    "TransportFaults",
    "TransportGaveUp",
    "FaultDirective",
    "Partition",
    "ReplicaPort",
    "SlotPool",
    "SlotSnapshot",
    "BlockManager",
    "ArenaExhausted",
    "PrefixIndex",
    "Replica",
    "FaultyClock",
    "Frontend",
    "FrontendRequest",
    "Scheduler",
    "Request",
    "CostModel",
    "EventClock",
    "next_bucket",
    "HedgedRouter",
    "HedgePlan",
    "DispatchOutcome",
    "ReplicaSet",
    "SpecController",
    "GammaPlan",
    "DraftRunner",
    "hedged_round_cost",
]
