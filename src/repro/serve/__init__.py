"""repro.serve — continuous-batching inference with order-statistics
hedged dispatch (DESIGN.md §10).

The training side of this repo prices every scheduling decision with the
expected k-th order statistic of worker response times; this package
applies the same machinery to a second workload: serving. A fixed-shape
slot pool + masked decode tick give recompile-free continuous batching
(engine/kv_pool/scheduler), the KV cache optionally pages into a global
block arena with admit-by-budget admission so memory tracks live tokens
(kv_pool.BlockManager, DESIGN.md §11), and a multi-replica router
prices hedged dispatch with ``expected_kth`` against EWMA straggler
telemetry (router).
"""

from .engine import EngineStats, ServeEngine, generate_offline, run_static
from .kv_pool import BlockManager, SlotPool
from .router import DispatchOutcome, HedgedRouter, HedgePlan, ReplicaSet
from .scheduler import CostModel, EventClock, Request, Scheduler, next_bucket

__all__ = [
    "ServeEngine",
    "EngineStats",
    "generate_offline",
    "run_static",
    "SlotPool",
    "BlockManager",
    "Scheduler",
    "Request",
    "CostModel",
    "EventClock",
    "next_bucket",
    "HedgedRouter",
    "HedgePlan",
    "DispatchOutcome",
    "ReplicaSet",
]
