"""Continuous-batching inference engine over a fixed slot pool.

The pool's ``n_slots`` lanes are one fixed-shape jitted decode call; slot
occupancy enters as DATA (a mask + per-slot position vector), exactly
like the fastest-k ``worker_mask`` in ``repro.runtime.steps`` — so
requests join and leave mid-flight with zero recompiles. Admission runs
the batched cache-writing prefill (``model.prefill_with_cache``) into a
batch-1 cache that is then installed into the freed slot with one
spec-driven slice write; prompts are padded to power-of-two buckets so a
handful of compiles cover every length.

Decode is greedy (argmax) by design: tests assert the continuous-batched
token stream is identical to a per-request offline decode, which is the
correctness contract that makes the scheduler/pool machinery trustable.

``run_static`` is the baseline the benchmarks compare against: same
kernels, same pool, but admissions barrier until the whole previous
batch drains (classic static batching — finished lanes ride dead until
the longest request completes).

Paged mode (``block_size=...``): sequence-axis cache leaves live in a
global block arena addressed through per-slot block tables, admission
switches from "a free slot" to "enough free blocks for the request's
whole token budget" (admit-by-budget: requests queue under arena
pressure and re-enter as finishing requests return blocks), and KV
memory tracks live tokens instead of ``n_slots * max_len`` stripes.
Greedy tokens stay byte-identical to the contiguous engine and to
offline decode — paging is a layout change, not a math change.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec, is_paged_spec, slot_mask_select
from repro.runtime.steps import make_slot_decode_step, make_slot_prefill_step

from .kv_pool import SlotPool, model_scoped_cache
from .scheduler import CostModel, EventClock, Request, Scheduler, next_bucket

__all__ = ["ServeEngine", "EngineStats", "generate_offline", "run_static"]


@dataclasses.dataclass
class EngineStats:
    generated_tokens: int = 0
    decode_ticks: int = 0
    prefill_calls: int = 0
    prefill_tokens: int = 0
    virtual_seconds: float = 0.0
    wall_seconds: float = 0.0

    @property
    def tokens_per_vsec(self) -> float:
        return self.generated_tokens / max(self.virtual_seconds, 1e-12)

    @property
    def tokens_per_wsec(self) -> float:
        return self.generated_tokens / max(self.wall_seconds, 1e-12)


@model_scoped_cache
def _engine_steps(model, n_slots: int, max_len: int,
                  block_size: Optional[int], arena_blocks: int):
    """Jitted prefill/decode shared across every engine of the same
    geometry on the same model (per-instance jax.jit closures would
    re-trace each time a new engine is built — benchmarks build
    several). Cached on the model instance, not a module global, so a
    dropped model releases its traces."""
    specs = model.cache_specs(
        n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
    )
    prefill = make_slot_prefill_step(model)
    decode = make_slot_decode_step(model)

    def decode_tick(params, tokens, caches, positions, mask, tables=None):
        logits, new_caches = decode(params, tokens, caches, positions, tables)
        # Lanes not decoding (free / mid-prefill) must not mutate
        # state: recurrent leaves would otherwise absorb garbage.
        # (Paged leaves skip the select — dead-lane writes went to the
        # NULL sink block via their zeroed block tables.)
        return logits, slot_mask_select(mask, new_caches, caches, specs)

    return jax.jit(prefill), jax.jit(decode_tick)


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        scheduler: Optional[Scheduler] = None,
        prefill_bucket: int = 16,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
    ):
        """``block_size`` turns on paged KV (see module docstring);
        ``arena_blocks`` caps the arena below full capacity to serve
        under an explicit memory budget (admit-by-budget queuing)."""
        if model.cfg.is_encoder:
            raise ValueError("serving needs a causal decoder architecture")
        self.model = model
        self.params = params
        self.pool = SlotPool(
            model, n_slots, max_len,
            block_size=block_size, arena_blocks=arena_blocks,
        )
        self.sched = scheduler or Scheduler(n_slots)
        self.prefill_bucket = prefill_bucket
        self.stats = EngineStats()
        self.events: List[Tuple[str, float, int]] = []  # (action, vtime, rid)
        self._requests: Dict[int, Request] = {}
        self._next_rid = 0
        # Per-slot decode state (host side).
        self._pending = np.zeros(n_slots, np.int32)   # next token to feed
        self._decoding = np.zeros(n_slots, bool)      # prefill done, generating
        # Fresh batch-1 caches for a slot's first prefill chunk. Paged
        # mode keeps only the contiguous (recurrent-state) leaves — the
        # arena leaves are stand-ins (num_blocks=0 = just the NULL row)
        # swapped for the pool's real arenas at call time.
        self._blank1 = model.blank_caches(
            1, max_len, block_size=block_size, num_blocks=0
        )
        self._prefill, self._decode = _engine_steps(
            model, n_slots, max_len, block_size,
            0 if self.pool.manager is None else self.pool.manager.num_blocks,
        )

    # -- submission ----------------------------------------------------------
    def submit(
        self, prompt, max_new_tokens: int, arrival: float = 0.0
    ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt({prompt.size}) + max_new_tokens({max_new_tokens}) "
                f"exceeds max_len({self.pool.max_len})"
            )
        if self.pool.paged:
            mgr = self.pool.manager
            need = mgr.blocks_for(prompt.size + max_new_tokens)
            if need > mgr.num_blocks:
                # Reject outright: a request bigger than the whole arena
                # could never be admitted, even with the pool idle.
                raise ValueError(
                    f"request needs {need} blocks but the arena has only "
                    f"{mgr.num_blocks} — raise arena_blocks or block_size"
                )
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, int(max_new_tokens), float(arrival))
        self._requests[rid] = req
        self.sched.submit(req)
        return rid

    # -- actions -------------------------------------------------------------
    def _slot_of(self, rid: int) -> int:
        return self.pool.owner.index(rid)

    @staticmethod
    def _budget(req: Request) -> int:
        """Cache rows a request can touch over its whole lifetime —
        reserved in full at admission so decode never stalls on blocks."""
        return req.prompt_len + req.max_new_tokens

    def _can_admit(self, req: Request) -> bool:
        return self.pool.can_admit(self._budget(req))

    def _fresh_slot_caches(self):
        """Batch-1 caches for a first prefill chunk: blank contiguous
        leaves, the pool's live arenas for paged leaves (pure pytree
        re-composition — no device work)."""
        if not self.pool.paged:
            return self._blank1
        return jax.tree.map(
            lambda s, pooled, blank: pooled if is_paged_spec(s) else blank,
            self.pool.specs, self.pool.caches, self._blank1,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )

    def _do_prefill(self, req: Request) -> None:
        sched, pool = self.sched, self.pool
        if req.prefilled == 0:
            sched.on_admit(req)
            slot = pool.allocate(owner=req.rid, n_tokens=self._budget(req))
            assert slot is not None, "scheduler admitted without slot/blocks"
            slot_caches = self._fresh_slot_caches()
        else:
            slot = self._slot_of(req.rid)
            slot_caches = pool.read_slot(slot)

        start, n_tok = sched.chunk_for(req)
        # Cap the pad bucket at the slot capacity past `start`: an oversized
        # chunk would crash (update wider than the cache) or, worse, let
        # XLA clamp the write start and silently overwrite valid rows.
        # submit() guarantees n_tok <= max_len - start.
        bucket = min(next_bucket(n_tok, self.prefill_bucket), pool.max_len - start)
        chunk = np.zeros((1, bucket), np.int32)
        chunk[0, :n_tok] = req.prompt[start : start + n_tok]
        # Lazily grow the slot's block table to cover the chunk's real
        # rows (bucket overhang past them falls into the NULL sink).
        pool.ensure_rows(slot, start + n_tok)
        logits, slot_caches = self._prefill(
            self.params,
            jnp.asarray(chunk),
            slot_caches,
            jnp.asarray([n_tok], jnp.int32),
            jnp.int32(start),
            pool.tables_device(slot),
        )
        pool.write_slot(slot, slot_caches, position=start + n_tok)
        done = start + n_tok >= req.prompt_len
        sched.on_prefill_chunk(req, n_tok, done)
        self.stats.prefill_calls += 1
        self.stats.prefill_tokens += n_tok
        if done:
            tok = int(jnp.argmax(logits[0, -1]))
            self._emit(req, tok)
            if self._finished(req):     # max_new_tokens == 1
                pool.free(slot)
            else:
                self._pending[slot] = tok
                self._decoding[slot] = True
        self.events.append(("prefill", self.sched.clock.now, req.rid))

    def _do_decode(self) -> None:
        pool = self.pool
        mask = self._decoding.copy()
        tokens = jnp.asarray(self._pending[:, None])
        positions = jnp.asarray(np.clip(pool.positions, 0, pool.max_len - 1))
        # Each decoding lane writes one row at its position: grow its
        # block table first. Never fails — admission committed the whole
        # budget, so the blocks are guaranteed to be available.
        for slot in np.nonzero(mask)[0]:
            pool.ensure_rows(int(slot), int(pool.positions[slot]) + 1)
        logits, pool.caches = self._decode(
            self.params, tokens, pool.caches, positions, jnp.asarray(mask),
            pool.tables_device(),
        )
        self.sched.on_decode_tick()
        self.stats.decode_ticks += 1
        next_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        for slot in np.nonzero(mask)[0]:
            slot = int(slot)
            pool.positions[slot] += 1
            req = self._requests[pool.owner[slot]]
            self._emit(req, int(next_tok[slot]))
            if self._finished(req):
                self._decoding[slot] = False
                pool.free(slot)
            else:
                self._pending[slot] = next_tok[slot]
        self.events.append(("decode", self.sched.clock.now, -1))

    def _emit(self, req: Request, tok: int) -> None:
        if not req.tokens:
            req.t_first_token = self.sched.clock.now
        req.tokens.append(tok)
        self.stats.generated_tokens += 1

    def _finished(self, req: Request) -> bool:
        if len(req.tokens) >= req.max_new_tokens:
            if req.t_done is None:
                req.t_done = self.sched.clock.now
            return True
        return False

    def defrag(self) -> Dict[int, int]:
        """Compact the pool's live slots and remap the engine's per-slot
        decode state to match — safe mid-flight (bare ``pool.defrag()``
        would silently desync ``_pending``/``_decoding``)."""
        moves = self.pool.defrag()
        if moves:
            inv = {new: old for old, new in moves.items()}
            pending, decoding = self._pending, self._decoding
            self._pending = np.zeros_like(pending)
            self._decoding = np.zeros_like(decoding)
            for s in np.nonzero(self.pool.active)[0]:
                src = inv.get(int(s), int(s))
                self._pending[s] = pending[src]
                self._decoding[s] = decoding[src]
        return moves

    # -- driver --------------------------------------------------------------
    def step(self) -> str:
        """Run one scheduler action; returns its kind."""
        kind, req = self.sched.next_action(
            self.pool.n_active, self.pool.n_free, self._can_admit
        )
        if kind == "prefill":
            self._do_prefill(req)
        elif kind == "decode":
            self._do_decode()
        elif kind == "idle":
            self.sched.on_idle()
            self.events.append(("idle", self.sched.clock.now, -1))
        return kind

    def run(self) -> Dict[int, Request]:
        """Drive until every submitted request completes."""
        t0 = time.perf_counter()
        while self.step() != "done":
            pass
        self.stats.wall_seconds += time.perf_counter() - t0
        self.stats.virtual_seconds = self.sched.clock.now
        return dict(self._requests)


# ---------------------------------------------------------------------------
# References: per-request offline decode + static batching baseline
# ---------------------------------------------------------------------------

@model_scoped_cache
def _offline_decode(model):
    return jax.jit(model.decode_step)


def generate_offline(
    model, params, prompt, max_new_tokens: int, max_len: int
) -> List[int]:
    """Single-request greedy generation with batch-1 caches — the token
    stream the continuous-batching engine must reproduce exactly."""
    prompt = np.asarray(prompt, np.int32).reshape(1, -1)
    P = prompt.shape[1]
    caches = model.blank_caches(1, max_len)
    logits, caches = model.prefill_with_cache(
        params, jnp.asarray(prompt), caches,
        length=jnp.asarray([P], jnp.int32), start_index=jnp.int32(0),
    )
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    decode = _offline_decode(model)
    for t in range(P, P + max_new_tokens - 1):
        logits, caches = decode(
            params, jnp.asarray([[tok]], jnp.int32), caches, jnp.int32(t)
        )
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


class _StaticScheduler(Scheduler):
    """Static batching: admissions barrier until the pool fully drains."""

    def __init__(self, n_slots: int, *, clock: Optional[EventClock] = None):
        super().__init__(n_slots, clock=clock)
        self._barrier_open = True

    def next_action(self, n_active: int, n_free: int, can_admit=None):
        if n_active == 0:
            self._barrier_open = True
        if self.running:
            return "prefill", self.running[0]
        req = self._eligible()
        if (req is not None and n_free > 0 and self._barrier_open
                and (can_admit is None or can_admit(req))):
            return "prefill", req
        if n_active > 0:
            self._barrier_open = False
            return "decode", None
        if self._next_arrival() is not None:
            return "idle", None
        return "done", None


def run_static(
    model,
    params,
    requests: List[Tuple[np.ndarray, int, float]],   # (prompt, max_new, arrival)
    *,
    n_slots: int,
    max_len: int,
    cost: Optional[CostModel] = None,
    prefill_bucket: int = 16,
) -> Tuple[Dict[int, Request], EngineStats]:
    """Same kernels/pool, static-batch admission (the baseline)."""
    sched = _StaticScheduler(n_slots, clock=EventClock(cost))
    eng = ServeEngine(
        model, params, n_slots=n_slots, max_len=max_len,
        scheduler=sched, prefill_bucket=prefill_bucket,
    )
    for prompt, m, arr in requests:
        eng.submit(prompt, m, arrival=arr)
    return eng.run(), eng.stats
