"""Continuous-batching inference engine over a fixed slot pool.

The pool's ``n_slots`` lanes are one fixed-shape jitted decode call; slot
occupancy enters as DATA (a mask + per-slot position vector), exactly
like the fastest-k ``worker_mask`` in ``repro.runtime.steps`` — so
requests join and leave mid-flight with zero recompiles. Admission runs
the batched cache-writing prefill (``model.prefill_with_cache``) into a
batch-1 cache that is then installed into the freed slot with one
spec-driven slice write; prompts are padded to power-of-two buckets so a
handful of compiles cover every length.

Decode is greedy (argmax) by design: tests assert the continuous-batched
token stream is identical to a per-request offline decode, which is the
correctness contract that makes the scheduler/pool machinery trustable.

``run_static`` is the baseline the benchmarks compare against: same
kernels, same pool, but admissions barrier until the whole previous
batch drains (classic static batching — finished lanes ride dead until
the longest request completes).

Paged mode (``block_size=...``): sequence-axis cache leaves live in a
global block arena addressed through per-slot block tables, admission
switches from "a free slot" to "enough free blocks for the request's
whole token budget" (admit-by-budget: requests queue under arena
pressure and re-enter as finishing requests return blocks), and KV
memory tracks live tokens instead of ``n_slots * max_len`` stripes.
Greedy tokens stay byte-identical to the contiguous engine and to
offline decode — paging is a layout change, not a math change.

Speculative mode (``draft_model=...``): decode actions become
draft-then-verify rounds (DESIGN.md §12, ``serve.speculative``) with
the same byte-identity contract — speculation only moves throughput.

Public API contract: the engine is SPEC-DRIVEN — it talks to caches
only through ``SlotPool`` and the jitted steps built from
``model.cache_specs``/``prefill_with_cache``/``decode_step``/
``verify_with_cache``, so any registered arch family serves unchanged
(attention KV, MLA latent, recurrent, hybrid). Model-specific behavior
lives entirely behind those Model methods; the one family-visible
distinction (fused vs scan verify commit) is documented on
``Model.verify_with_cache`` and tested per family.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import NULL_BLOCK
from repro.models.layers import ParamSpec, is_paged_spec, slot_mask_select
from repro.obs import NULL_OBS, Observability
from repro.runtime.steps import (
    make_slot_decode_step,
    make_slot_prefill_step,
    make_slot_verify_step,
)

from .kv_pool import ArenaExhausted, SlotPool, SlotSnapshot, model_scoped_cache
from .scheduler import CostModel, EventClock, Request, Scheduler, next_bucket
from .speculative import DraftRunner, SpecController

__all__ = [
    "ServeEngine", "EngineStats", "MigrationTicket", "TicketIntegrityError",
    "ticket_checksum", "generate_offline", "run_static",
]


class TicketIntegrityError(ValueError):
    """A :class:`MigrationTicket` failed its end-to-end integrity check
    at import: the payload was mutated between ``export_request`` (which
    seals the checksum) and ``import_request`` (which verifies it).
    Resuming from a corrupt ticket would silently diverge the greedy
    stream — the importer must reject it and the owner requeue from the
    last trusted prefix instead."""


@dataclasses.dataclass(frozen=True)
class MigrationTicket:
    """Everything needed to resume a mid-decode request on ANOTHER engine
    of the same model + pool geometry: the immutable submission, the
    tokens emitted so far, the next token to feed (``pending``), and the
    slot's cache state as a :class:`SlotSnapshot`. Restoring re-admits
    the request with its prefix already in cache — no re-prefill — and
    the greedy continuation is byte-identical to never having moved
    (pinned per arch family in tests)."""

    prompt: np.ndarray
    max_new_tokens: int
    arrival: float
    deadline: Optional[float]
    tokens: Tuple[int, ...]       # emitted so far (stream prefix)
    pending: int                  # next token to feed (last emitted)
    snapshot: SlotSnapshot
    #: end-to-end integrity seal over every resume-relevant field,
    #: computed at export (``ticket_checksum``) and verified at import.
    #: ``None`` = unsealed (hand-built test tickets): import skips the
    #: check, matching pre-checksum tickets.
    checksum: Optional[str] = None


def ticket_checksum(ticket: "MigrationTicket") -> str:
    """SHA-256 over the ticket's resume-relevant content: prompt bytes,
    budget, emitted tokens, pending token, and every snapshot cache leaf
    (shape + dtype + raw bytes). Deliberately EXCLUDES ``deadline`` —
    the owner legitimately rewrites it in flight (absolute deadlines are
    clock-local, so migration carries remaining budget instead), and a
    re-seal hook on the transfer path would be exactly the kind of
    mutable-in-transit field an integrity seal must not cover."""
    h = hashlib.sha256()
    prompt = np.ascontiguousarray(np.asarray(ticket.prompt, np.int32))
    h.update(prompt.tobytes())
    h.update(np.int64(ticket.max_new_tokens).tobytes())
    h.update(np.asarray(ticket.tokens, np.int64).tobytes())
    h.update(np.int64(ticket.pending).tobytes())
    snap = ticket.snapshot
    h.update(np.int64(snap.position).tobytes())
    h.update(np.int64(snap.n_blocks).tobytes())
    for leaf in jax.tree_util.tree_leaves(snap.data):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str((a.shape, a.dtype.str)).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class EngineStats:
    generated_tokens: int = 0
    decode_ticks: int = 0
    prefill_calls: int = 0
    prefill_tokens: int = 0
    spec_rounds: int = 0          # speculation rounds (draft + verify)
    draft_ticks: int = 0          # sequential draft decode ticks
    spec_accepted: int = 0        # draft tokens the target accepted
    cancelled_requests: int = 0   # deadline expiries + explicit cancels
    preempted_requests: int = 0   # evict-and-requeue events (prefix sharing)
    prefix_hits: int = 0          # admissions that adopted a trie chain
    prefix_rows_shared: int = 0   # cache rows skipped via adoption
    migrated_out: int = 0         # requests exported as MigrationTickets
    migrated_in: int = 0          # tickets restored into this engine
    virtual_seconds: float = 0.0
    wall_seconds: float = 0.0

    @property
    def tokens_per_vsec(self) -> float:
        return self.generated_tokens / max(self.virtual_seconds, 1e-12)

    @property
    def tokens_per_wsec(self) -> float:
        return self.generated_tokens / max(self.wall_seconds, 1e-12)


@model_scoped_cache
def _engine_steps(model, n_slots: int, max_len: int,
                  block_size: Optional[int], arena_blocks: int):
    """Jitted prefill/decode shared across every engine of the same
    geometry on the same model (per-instance jax.jit closures would
    re-trace each time a new engine is built — benchmarks build
    several). Cached on the model instance, not a module global, so a
    dropped model releases its traces."""
    specs = model.cache_specs(
        n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
    )
    prefill = make_slot_prefill_step(model)
    decode = make_slot_decode_step(model)

    def decode_tick(params, tokens, caches, positions, mask, tables=None):
        logits, new_caches = decode(params, tokens, caches, positions, tables)
        # Lanes not decoding (free / mid-prefill) must not mutate
        # state: recurrent leaves would otherwise absorb garbage.
        # (Paged leaves skip the select — dead-lane writes went to the
        # NULL sink block via their zeroed block tables.)
        return logits, slot_mask_select(mask, new_caches, caches, specs)

    # Speculative verify (only traced when an engine actually has a
    # draft model — jax.jit is lazy). Needs no extra masking: dead-lane
    # writes are dropped/sunk by ``n_input`` and recurrent commits are
    # gated on-device (Model.verify_with_cache).
    verify = jax.jit(make_slot_verify_step(model))

    return jax.jit(prefill), jax.jit(decode_tick), verify


class ServeEngine:
    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        scheduler: Optional[Scheduler] = None,
        prefill_bucket: int = 16,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
        prefix_sharing: bool = False,
        draft_model=None,
        draft_params=None,
        gamma_max: int = 4,
        spec_controller: Optional[SpecController] = None,
        obs: Optional[Observability] = None,
        obs_name: Optional[str] = None,
    ):
        """``block_size`` turns on paged KV (see module docstring);
        ``arena_blocks`` caps the arena below full capacity to serve
        under an explicit memory budget (admit-by-budget queuing).

        ``prefix_sharing`` (paged only, DESIGN.md §16) switches the
        arena to copy-on-write sharing with preempt-and-requeue:
        admissions adopt trie-matched prompt blocks instead of
        recomputing them, shared blocks fork before any write, and
        arena pressure evicts the cheapest lane (recompute-vs-hold
        priced by the cost model) rather than queuing. Greedy streams
        stay byte-identical to offline decode — including preempted
        requests, which replay from the longest resident prefix.

        ``draft_model``/``draft_params`` turn on speculative decoding
        (DESIGN.md §12): decode actions become draft-then-verify rounds
        whose draft length is adapted by ``spec_controller`` (default:
        ``SpecController(gamma_max)``). Greedy output stays byte-identical
        to the non-speculative engine and to offline decode — acceptance
        is exact argmax match, so speculation is purely a throughput
        bet.

        ``obs``: observability bundle (``repro.obs``) — defaults to the
        disabled ``NULL_OBS`` singleton, in which case every hook below
        is a no-op costing one attribute check. ``obs_name`` labels this
        engine's trace lane (replicas pass ``"replica <id>"``)."""
        if model.cfg.is_encoder:
            raise ValueError("serving needs a causal decoder architecture")
        if prefix_sharing and draft_model is not None:
            raise ValueError(
                "prefix_sharing and speculative decoding are mutually "
                "exclusive: the draft twin pool does not track the target's "
                "copy-on-write forks, so lockstep would silently break"
            )
        if (prefix_sharing and model.cfg.moe is not None
                and not model.cfg.moe.dropless):
            raise ValueError(
                "prefix_sharing requires dropless MoE routing "
                "(cfg.moe.dropless=True): adopting a prefix changes how "
                "many tokens share the suffix prefill call, and "
                "capacity-dropped routing makes logits depend on that "
                "count — byte-identity to offline decode would silently "
                "break"
            )
        self.model = model
        self.params = params
        self.prefix_sharing = bool(prefix_sharing)
        self.pool = SlotPool(
            model, n_slots, max_len,
            block_size=block_size, arena_blocks=arena_blocks,
            prefix_sharing=prefix_sharing,
        )
        #: chaos-search teeth only (tools/chaos_search.py --leak-blocks):
        #: when set, a CANCELLED slot's last block is dropped instead of
        #: freed — a seeded refcount bug the block-conservation oracle
        #: must catch and ddmin must shrink to the one cancel atom.
        self._chaos_leak_blocks = False
        self.sched = scheduler or Scheduler(n_slots)
        self.prefill_bucket = prefill_bucket
        self.stats = EngineStats()
        self.events: List[Tuple[str, float, int]] = []  # (action, vtime, rid)
        # -- observability ----------------------------------------------------
        self.obs = obs or NULL_OBS
        self._tr = self.obs.tracer
        self.pid = self._tr.register_process(obs_name or "engine")
        self._span_ids: Dict[int, int] = {}   # rid -> open lifecycle span
        if self.sched.obs is NULL_OBS:
            self.sched.bind_obs(self.obs)
        m = self.obs.metrics
        self._m_tokens = m.counter("engine.generated_tokens")
        self._m_prefill_tokens = m.counter("engine.prefill_tokens")
        self._m_decode_ticks = m.counter("engine.decode_ticks")
        self._g_slots = m.gauge("engine.slots_active")
        self._g_blocks = m.gauge("engine.arena_blocks_used")
        self._requests: Dict[int, Request] = {}
        self._next_rid = 0
        # Per-slot decode state (host side).
        self._pending = np.zeros(n_slots, np.int32)   # next token to feed
        self._decoding = np.zeros(n_slots, bool)      # prefill done, generating
        # Fresh batch-1 caches for a slot's first prefill chunk. Paged
        # mode keeps only the contiguous (recurrent-state) leaves — the
        # arena leaves are stand-ins (num_blocks=0 = just the NULL row)
        # swapped for the pool's real arenas at call time.
        self._blank1 = model.blank_caches(
            1, max_len, block_size=block_size, num_blocks=0
        )
        self._prefill, self._decode, self._verify = _engine_steps(
            model, n_slots, max_len, block_size,
            0 if self.pool.manager is None else self.pool.manager.num_blocks,
        )
        # -- speculation (optional) ------------------------------------------
        self.draft: Optional[DraftRunner] = None
        self.spec: Optional[SpecController] = None
        if draft_model is not None:
            if draft_params is None:
                raise ValueError("draft_model needs draft_params")
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError(
                    "draft and target models must share a vocabulary "
                    f"({draft_model.cfg.vocab_size} != {model.cfg.vocab_size})"
                )
            self.draft = DraftRunner(draft_model, draft_params, n_slots, max_len)
            self.spec = spec_controller or SpecController(gamma_max)
            self.spec.draft_fused = draft_model.fused_prefill
            if self.spec.obs is NULL_OBS:
                self.spec.obs = self.obs

    @property
    def speculative(self) -> bool:
        return self.draft is not None

    # -- submission ----------------------------------------------------------
    def submit(
        self, prompt, max_new_tokens: int, arrival: float = 0.0,
        deadline: Optional[float] = None,
    ) -> int:
        """``deadline``: absolute virtual-time deadline; None defers to
        the scheduler's ``deadline_ticks`` default (stamped at
        admission)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new_tokens > self.pool.max_len:
            raise ValueError(
                f"prompt({prompt.size}) + max_new_tokens({max_new_tokens}) "
                f"exceeds max_len({self.pool.max_len})"
            )
        if self.pool.paged:
            mgr = self.pool.manager
            need = mgr.blocks_for(prompt.size + max_new_tokens)
            if need > mgr.num_blocks:
                # Reject outright: a request bigger than the whole arena
                # could never be admitted, even with the pool idle.
                raise ValueError(
                    f"request needs {need} blocks but the arena has only "
                    f"{mgr.num_blocks} — raise arena_blocks or block_size"
                )
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid, prompt, int(max_new_tokens), float(arrival),
            deadline=deadline,
        )
        self._requests[rid] = req
        self.sched.submit(req)
        if self._tr.enabled:
            # The span opens at this engine's LOCAL clock, not at the
            # logical arrival: a hedge copy can be handed to a replica
            # whose clock is behind the arrival stamp, and span ends
            # must never precede their begins.
            self._span_ids[rid] = self._tr.begin_span(
                "request", self.pid, self.sched.clock.now,
                args={"rid": rid, "arrival": float(arrival),
                      "prompt_len": int(prompt.size),
                      "max_new_tokens": int(max_new_tokens)},
            )
        return rid

    def _end_request_span(self, req: Request, outcome: str, ts: float) -> None:
        """Close a request's lifecycle span exactly once, whatever path
        retired it (done / cancelled / deadline / migrated) — leaked
        spans under chaos are a test failure (tests/test_obs.py)."""
        sid = self._span_ids.pop(req.rid, None)
        if sid:
            self._tr.end_span(
                sid, ts,
                args={"outcome": outcome, "n_tokens": len(req.tokens)},
            )

    # -- cancellation / deadlines --------------------------------------------
    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Tear down an unfinished request NOW, wherever it is in its
        lifecycle, actually freeing what it holds: a waiting request
        leaves the queue; a mid-prefill or decoding request frees its
        slot — and, in paged mode, returns its arena blocks, which is
        what lets a queued request admit (hedged-loser cancellation is
        only affordable because of this). Returns False if the request
        is unknown, already finished, or already cancelled. The partial
        token stream is kept on the request."""
        req = self._requests.get(rid)
        if req is None or req.t_done is not None or req.cancelled:
            return False
        self.sched.drop(req)
        if rid in self.pool.owner:              # holds a slot (prefill/decode)
            slot = self._slot_of(rid)
            self._decoding[slot] = False
            if self._chaos_leak_blocks and self.pool.paged:
                # Seeded bug (chaos teeth): drop the slot's last block on
                # the cancel path without freeing it. Only cancel-bearing
                # schedules trip the conservation oracle, so ddmin can
                # shrink the repro to exactly that one atom.
                mgr = self.pool.manager
                owned = mgr._owned[slot]
                if owned:
                    bid = owned.pop()
                    mgr.tables[slot, len(owned)] = NULL_BLOCK
                    mgr.refcount[bid] -= 1
            self._free_slot(slot)
        req.t_cancelled = self.sched.clock.now
        req.cancel_reason = reason
        self.stats.cancelled_requests += 1
        self.events.append(("cancel", self.sched.clock.now, rid))
        now = self.sched.clock.now
        self._end_request_span(req, reason, now)
        if self.obs.enabled:
            self.obs.metrics.counter(f"engine.cancel.{reason}").inc()
            self._tr.instant("cancel", self.pid, now,
                             args={"rid": rid, "reason": reason})
        return True

    def _expire_deadlines(self) -> List[int]:
        """Cancel every unfinished request past its deadline (reason
        ``"deadline"``); returns their rids so a frontend can requeue
        them elsewhere and record the expiry as censored telemetry."""
        now = self.sched.clock.now
        expired = [
            rid for rid, req in self._requests.items()
            if req.t_done is None and not req.cancelled
            and req.deadline is not None and req.deadline <= now
        ]
        for rid in expired:
            self.cancel(rid, reason="deadline")
        return expired

    # -- migration -----------------------------------------------------------
    def export_request(self, rid: int) -> MigrationTicket:
        """Snapshot a decoding request into a :class:`MigrationTicket`
        and release everything it holds here (reason ``"migrated"``).

        Only DECODING requests carry cache state worth handing off;
        waiting / mid-prefill requests migrate by plain resubmission.
        Speculative engines refuse: the draft pool's twin state is not
        part of the snapshot, and a desynced draft would poison
        lockstep. The position invariant checked here is the engine's
        decode bookkeeping contract: after ``m`` emitted tokens the slot
        has ``prompt_len + m - 1`` rows written and ``pending`` = token
        ``m``, so the importing engine's next decode tick emits token
        ``m + 1`` of the identical greedy stream."""
        if self.speculative:
            raise ValueError("cannot export from a speculative engine "
                             "(draft twin state is not snapshotted)")
        req = self._requests.get(rid)
        if req is None or req.t_done is not None or req.cancelled:
            raise ValueError(f"request {rid} is not live")
        slot = self._slot_of(rid)
        if not self._decoding[slot]:
            raise ValueError(f"request {rid} is not decoding "
                             "(migrate queued requests by resubmission)")
        expect = req.prompt_len + len(req.tokens) - 1
        assert int(self.pool.positions[slot]) == expect, (
            f"slot {slot} position {self.pool.positions[slot]} != {expect}"
        )
        ticket = MigrationTicket(
            prompt=req.prompt,
            max_new_tokens=req.max_new_tokens,
            arrival=req.arrival,
            deadline=req.deadline,
            tokens=tuple(req.tokens),
            pending=int(self._pending[slot]),
            snapshot=self.pool.snapshot_slot(slot),
        )
        # Seal AFTER the ticket is complete: the checksum covers every
        # resume-relevant field (not the clock-local deadline, which the
        # owner rewrites in flight — see ticket_checksum).
        ticket = dataclasses.replace(ticket, checksum=ticket_checksum(ticket))
        self._decoding[slot] = False
        self._free_slot(slot)
        req.t_cancelled = self.sched.clock.now
        req.cancel_reason = "migrated"
        self.stats.migrated_out += 1
        self.events.append(("migrate_out", self.sched.clock.now, rid))
        now = self.sched.clock.now
        self._end_request_span(req, "migrated", now)
        if self.obs.enabled:
            self.obs.metrics.counter("engine.migrated_out").inc()
            self._tr.instant("migrate_out", self.pid, now,
                             args={"rid": rid, "n_tokens": len(req.tokens)})
        return ticket

    def import_request(self, ticket: MigrationTicket) -> Optional[int]:
        """Re-admit a migrated request with its cache prefix restored —
        no re-prefill. Returns the new local rid, or None when the pool
        cannot admit it right now (no free slot / not enough blocks):
        the caller keeps the ticket and retries after capacity frees, or
        falls back to resubmitting prompt + emitted tokens."""
        if self.speculative:
            raise ValueError("cannot import into a speculative engine "
                             "(draft twin state is not snapshotted)")
        if ticket.checksum is not None:
            # Verify BEFORE touching the pool: a corrupt ticket must be
            # rejected without allocating anything (reject-and-requeue is
            # the owner's job; resuming from garbage would silently
            # diverge the greedy stream).
            expect = ticket_checksum(ticket)
            if expect != ticket.checksum:
                raise TicketIntegrityError(
                    f"migration ticket failed integrity check: sealed "
                    f"{ticket.checksum[:12]}…, recomputed {expect[:12]}…"
                )
        budget = int(ticket.prompt.size) + int(ticket.max_new_tokens)
        if budget > self.pool.max_len:
            raise ValueError("ticket exceeds this engine's max_len")
        rid = self._next_rid
        slot = self.pool.restore_slot(ticket.snapshot, owner=rid, n_tokens=budget)
        if slot is None:
            return None
        self._next_rid += 1
        req = Request(
            rid, ticket.prompt, int(ticket.max_new_tokens),
            float(ticket.arrival), deadline=ticket.deadline,
        )
        req.tokens = list(ticket.tokens)
        req.prefilled = req.prompt_len
        req.t_admit = self.sched.clock.now
        req.t_first_token = self.sched.clock.now
        self._requests[rid] = req
        self._pending[slot] = np.int32(ticket.pending)
        self._decoding[slot] = True
        self.stats.migrated_in += 1
        self.events.append(("migrate_in", self.sched.clock.now, rid))
        now = self.sched.clock.now
        if self._tr.enabled:
            self._span_ids[rid] = self._tr.begin_span(
                "request", self.pid, now,
                args={"rid": rid, "arrival": float(ticket.arrival),
                      "prompt_len": int(ticket.prompt.size),
                      "max_new_tokens": int(ticket.max_new_tokens),
                      "migrated_in": True,
                      "tokens_so_far": len(ticket.tokens)},
            )
        if self.obs.enabled:
            self.obs.metrics.counter("engine.migrated_in").inc()
            self._tr.instant("migrate_in", self.pid, now,
                             args={"rid": rid,
                                   "n_tokens": len(ticket.tokens)})
        return rid

    # -- introspection (frontend/replica layers) -----------------------------
    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def live_rids(self) -> List[int]:
        """Requests neither finished nor cancelled (queued, mid-prefill,
        or decoding)."""
        return [
            rid for rid, r in self._requests.items()
            if r.t_done is None and not r.cancelled
        ]

    def decoding_rids(self) -> List[int]:
        """Requests mid-decode — the ones that carry migratable cache
        state (``export_request``)."""
        return [
            self.pool.owner[int(s)] for s in np.nonzero(self._decoding)[0]
        ]

    @property
    def has_work(self) -> bool:
        """True while a ``step()`` would do something other than idle
        forever (active slots, queued arrivals, or mid-prefill work)."""
        return bool(
            self.pool.n_active > 0 or self.sched.waiting or self.sched.running
        )

    # -- actions -------------------------------------------------------------
    def _slot_of(self, rid: int) -> int:
        return self.pool.owner.index(rid)

    @staticmethod
    def _budget(req: Request) -> int:
        """Cache rows a request can touch over its whole lifetime —
        reserved in full at admission so decode never stalls on blocks."""
        return req.prompt_len + req.max_new_tokens

    def _can_admit(self, req: Request) -> bool:
        if not self.prefix_sharing:
            return self.pool.can_admit(self._budget(req))
        # Sharing mode: no whole-budget commitment — admit when the
        # PREFILL (minus whatever the trie already holds) fits the live
        # free list, leaving at least one block of headroom. Decode-time
        # growth is covered by preempt-and-requeue, not by reservation.
        pool = self.pool
        if pool.n_free == 0 or not pool.manager.can_commit(self._budget(req)):
            return False
        mgr = pool.manager
        matched = (0 if pool._any_contiguous
                   else len(pool.prefix.match(req.prefill_target())))
        need = mgr.blocks_for(req.prefill_len) - matched
        return mgr.n_free_blocks >= max(need, 1)

    def _fresh_slot_caches(self):
        """Batch-1 caches for a first prefill chunk: blank contiguous
        leaves, the pool's live arenas for paged leaves (pure pytree
        re-composition — no device work)."""
        if not self.pool.paged:
            return self._blank1
        return jax.tree.map(
            lambda s, pooled, blank: pooled if is_paged_spec(s) else blank,
            self.pool.specs, self.pool.caches, self._blank1,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )

    def _do_prefill(self, req: Request) -> None:
        sched, pool = self.sched, self.pool
        t0 = sched.clock.now
        target = req.prefill_target()   # prompt, + emitted[:-1] on replay
        first = req.rid not in pool.owner
        if first:
            # First chunk. (Detected by slot ownership, not prefilled==0:
            # a trie adoption below pre-advances ``prefilled``.)
            sched.on_admit(req)
            slot = pool.allocate(owner=req.rid, n_tokens=self._budget(req))
            assert slot is not None, "scheduler admitted without slot/blocks"
            if self.prefix_sharing:
                matched = pool.adopt_prefix(slot, target)
                if matched:
                    if matched == len(target):
                        # Full-block full match: re-feed the last token so
                        # its write forks the shared tail block — the
                        # emitted continuation needs logits at that row.
                        matched -= 1
                        pool.positions[slot] = matched
                    req.prefilled = matched
                    self.stats.prefix_hits += 1
                    self.stats.prefix_rows_shared += matched
        else:
            slot = self._slot_of(req.rid)

        start, n_tok = sched.chunk_for(req)
        # Cap the pad bucket at the slot capacity past `start`: an oversized
        # chunk would crash (update wider than the cache) or, worse, let
        # XLA clamp the write start and silently overwrite valid rows.
        # submit() guarantees n_tok <= max_len - start.
        bucket = min(next_bucket(n_tok, self.prefill_bucket), pool.max_len - start)
        chunk = np.zeros((1, bucket), np.int32)
        chunk[0, :n_tok] = target[start : start + n_tok]
        # Lazily grow the slot's block table to cover the chunk's real
        # rows (bucket overhang past them falls into the NULL sink), and
        # fork any shared block the scatter would touch (only the full-
        # match re-feed row can be shared: adopted blocks sit below the
        # write start). Either can hit arena pressure under sharing.
        self._ensure_preempting(
            slot, lambda: pool.ensure_rows(slot, start + n_tok)
        )
        if self.prefix_sharing:
            self._ensure_preempting(
                slot, lambda: pool.ensure_writable(slot, start, start + n_tok)
            )
        # Capture the slot view AFTER the ensures: a copy-on-write fork
        # rewrites pool.caches, and an earlier capture would hand the
        # prefill a stale arena missing the forked block's rows.
        slot_caches = (self._fresh_slot_caches() if first
                       else pool.read_slot(slot))
        logits, slot_caches = self._prefill(
            self.params,
            jnp.asarray(chunk),
            slot_caches,
            jnp.asarray([n_tok], jnp.int32),
            jnp.int32(start),
            pool.tables_device(slot),
        )
        pool.write_slot(slot, slot_caches, position=start + n_tok)
        if self.speculative:
            # The draft cache must hold the same prefix (same bucketed
            # chunk, so the draft reuses the target's compile shapes).
            self.draft.prefill_chunk(
                slot, jnp.asarray(chunk), n_tok, start, owner=req.rid
            )
            sched.on_draft_prefill(n_tok)
        done = start + n_tok >= req.prefill_len
        sched.on_prefill_chunk(req, n_tok, done)
        self.stats.prefill_calls += 1
        self.stats.prefill_tokens += n_tok
        if done:
            if self.prefix_sharing:
                pool.register_prefix(slot, req.prompt)
            if req.tokens:
                # Replay of a preempted request: every emitted token is
                # already in the stream — re-enter decode exactly where
                # the eviction hit, feeding the last emitted token. No
                # emit here, so the stream stays byte-identical.
                self._pending[slot] = np.int32(req.tokens[-1])
                self._decoding[slot] = True
            else:
                tok = int(jnp.argmax(logits[0, -1]))
                self._emit(req, tok)
                if self._finished(req):     # max_new_tokens == 1
                    self._free_slot(slot)
                else:
                    self._pending[slot] = tok
                    self._decoding[slot] = True
        self.events.append(("prefill", self.sched.clock.now, req.rid))
        if self.obs.enabled:
            self._m_prefill_tokens.inc(n_tok)
            self._tr.complete(
                "prefill", self.pid, t0, sched.clock.now,
                args={"rid": req.rid, "start": start,
                      "n_tokens": n_tok, "done": done},
            )

    def _free_slot(self, slot: int) -> None:
        self.pool.free(slot)
        if self.speculative:
            self.draft.pool.free(slot)

    # -- preemption (prefix sharing, DESIGN.md §16) --------------------------
    def _recompute_cost(self, req: Request, slot: int) -> float:
        """Price of evicting ``slot`` now: prefill over the replay
        sequence MINUS whatever prefix would still be trie-resident
        after the victim's own references drop (it re-adopts that part
        for free on requeue)."""
        replay = req.prompt_len + max(len(req.tokens) - 1, 0)
        resident = self.pool.match_resident(
            req.prefill_target(), exclude_slot=slot
        )
        return self.sched.clock.cost.recompute(replay - resident)

    def _preempt_slot(self, slot: int) -> None:
        """Evict ``slot``'s request and requeue it: blocks freed NOW,
        emitted tokens kept, next admission replays from the longest
        still-resident prefix (byte-identical continuation — pinned in
        tests/test_prefix.py)."""
        req = self._requests[self.pool.owner[slot]]
        self._decoding[slot] = False
        self._pending[slot] = 0
        self._free_slot(slot)
        self.sched.requeue(req)
        self.stats.preempted_requests += 1
        now = self.sched.clock.now
        self.events.append(("preempt", now, req.rid))
        if self.obs.enabled:
            self.obs.metrics.counter("engine.preempted").inc()
            self._tr.instant("preempt", self.pid, now,
                             args={"rid": req.rid,
                                   "n_tokens": len(req.tokens)})

    def _preempt_for(self, needy_slot: int) -> None:
        """FORCED eviction: ``needy_slot``'s in-flight write hit an empty
        free list and must proceed (its action is half-priced already).
        Evict the cheapest-to-recompute OTHER lane, preferring decoding
        lanes (a mid-prefill lane has no emitted stream to protect).
        Livelock-free: every forced preemption follows the preemptor
        completing a write + emit, so global progress is monotone."""
        best, best_rc = None, None
        for s in np.nonzero(self.pool.active)[0]:
            s = int(s)
            if s == needy_slot:
                continue
            rc = self._recompute_cost(
                self._requests[self.pool.owner[s]], s
            )
            # Decoding lanes first: preempting the mid-prefill lane the
            # scheduler is committed to would wedge its chunk loop.
            rank = (0 if self._decoding[s] else 1, rc)
            if best_rc is None or rank < best_rc:
                best, best_rc = s, rank
        if best is None:
            raise RuntimeError(
                f"arena exhausted with no preemptable lane (slot "
                f"{needy_slot} alone holds the arena) — raise arena_blocks"
            )
        self._preempt_slot(best)

    def _ensure_preempting(self, slot: int, fn) -> None:
        """Run a block-allocating pool op, evicting lanes until it fits
        (sharing mode; pass-through elsewhere — legacy commitment makes
        exhaustion impossible)."""
        while True:
            try:
                return fn()
            except ArenaExhausted:
                self._preempt_for(slot)

    def _maybe_preempt_for_admission(self) -> None:
        """PRICED eviction at admission: when the queue head is blocked
        on blocks (not on slots), evict the lane whose recompute is
        cheapest — but only if recompute undercuts holding it to
        completion (the paper's wait-vs-recompute trade, priced by the
        event-clock cost model), and only from requests strictly YOUNGER
        than the head. The age guard makes admission eviction a strict
        priority order, so two queued requests can never evict each
        other in a ping-pong (the oldest live request is never evicted
        for admission — it only ever finishes). At most one eviction per
        step keeps the policy incremental and replayable."""
        sched = self.sched
        if sched.running:
            return                      # finish the in-flight prefill first
        req = sched._eligible()
        if req is None or self.pool.n_free == 0 or self._can_admit(req):
            return
        cost = sched.clock.cost
        head_key = (req.arrival, req.rid)
        best, best_rc = None, None
        for s in np.nonzero(self.pool.active)[0]:
            s = int(s)
            victim = self._requests[self.pool.owner[s]]
            if (victim.arrival, victim.rid) <= head_key:
                continue                # never evict an older request
            rc = self._recompute_cost(victim, s)
            hold = cost.hold(victim.max_new_tokens - len(victim.tokens))
            if rc < hold and (best_rc is None or rc < best_rc):
                best, best_rc = s, rc
        if best is not None:
            self._preempt_slot(best)

    def _do_decode(self) -> None:
        pool = self.pool
        t0 = self.sched.clock.now
        # Each decoding lane writes one row at its position: grow its
        # block table (and fork any shared block under sharing) BEFORE
        # snapshotting the lane mask — under arena pressure these ensures
        # may preempt OTHER decoding lanes, which must then drop out of
        # this tick. Legacy mode never fails here (whole-budget commit).
        for slot in np.nonzero(self._decoding)[0]:
            slot = int(slot)
            if not self._decoding[slot]:
                continue                # preempted by an earlier ensure
            pos = int(pool.positions[slot])
            self._ensure_preempting(
                slot, lambda s=slot, p=pos: pool.ensure_rows(s, p + 1)
            )
            if self.prefix_sharing and self._decoding[slot]:
                self._ensure_preempting(
                    slot,
                    lambda s=slot, p=pos: pool.ensure_writable(s, p, p + 1),
                )
        mask = self._decoding.copy()
        tokens = jnp.asarray(self._pending[:, None])
        positions = jnp.asarray(np.clip(pool.positions, 0, pool.max_len - 1))
        logits, pool.caches = self._decode(
            self.params, tokens, pool.caches, positions, jnp.asarray(mask),
            pool.tables_device(),
        )
        self.sched.on_decode_tick()
        self.stats.decode_ticks += 1
        next_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        for slot in np.nonzero(mask)[0]:
            slot = int(slot)
            pool.positions[slot] += 1
            req = self._requests[pool.owner[slot]]
            self._emit(req, int(next_tok[slot]))
            if self._finished(req):
                self._decoding[slot] = False
                self._free_slot(slot)
            else:
                self._pending[slot] = next_tok[slot]
        self.events.append(("decode", self.sched.clock.now, -1))
        if self.obs.enabled:
            self._m_decode_ticks.inc()
            self._tr.complete(
                "decode", self.pid, t0, self.sched.clock.now,
                args={"lanes": int(mask.sum())},
            )

    def _do_spec_round(self) -> None:
        """One draft-then-verify round over the whole pool (replaces a
        decode tick when a draft model is attached).

        Per-lane draft budgets enter the fixed-shape verify call as DATA
        (``n_input``: 0 = free/mid-prefill lane, 1 = plain decode — a
        lane one token from its budget — 1 + gamma_b = speculating), so
        one compile per window width covers every occupancy pattern.
        Rollback is the position rewind described in DESIGN.md §12.2:
        the verify call itself committed only what the acceptance rule
        allows, block tables keep their (within-budget) blocks, and the
        draft resyncs by replaying the committed tokens from its
        snapshot."""
        pool, sched, draft = self.pool, self.sched, self.draft
        t0 = sched.clock.now
        n_slots = pool.n_slots
        decoding = self._decoding.copy()
        slots = np.nonzero(decoding)[0]
        plan = self.spec.choose_gamma(sched.clock.cost)
        gamma = plan.gamma
        if gamma == 0 or slots.size == 0:
            # Plain decode tick — but the draft cache must still consume
            # the tokens the target consumes, or it falls behind the
            # committed stream and later rounds would draft from a stale
            # prefix. One masked draft tick (proposal discarded) keeps
            # the lockstep; lanes that finished were freed in both pools.
            old_pending = self._pending.copy()
            self._do_decode()
            live = decoding & self._decoding
            if live.any():
                draft.decode_tick(old_pending, live)
                sched.on_draft_decode()
                self.stats.draft_ticks += 1
            return
        # Per-lane draft budget: never draft past a request's remaining
        # token budget (the last emitted token needs no successor), which
        # also keeps every verify write inside the committed block budget.
        remaining = np.zeros(n_slots, np.int64)
        for slot in slots:
            req = self._requests[pool.owner[slot]]
            remaining[slot] = req.max_new_tokens - len(req.tokens)
        gamma_b = np.minimum(gamma, np.maximum(remaining - 1, 0))
        S = gamma + 1
        inputs = np.zeros((n_slots, S), np.int32)
        inputs[:, 0] = self._pending
        n_input = np.zeros(n_slots, np.int32)
        n_input[slots] = 1 + gamma_b[slots]

        # -- draft phase: gamma masked sequential ticks ----------------------
        draft.snapshot()
        tokens = self._pending.copy()
        draft_ticks = 0
        for j in range(gamma):
            mask_j = decoding & (gamma_b > j)
            if not mask_j.any():
                break
            proposed = draft.decode_tick(tokens, mask_j)
            tokens = np.where(mask_j, proposed, tokens)
            inputs[mask_j, j + 1] = proposed[mask_j]
            draft_ticks += 1

        # -- verify phase: one fused target call over the pool ---------------
        starts = pool.positions.copy()
        for slot in slots:
            pool.ensure_rows(int(slot), int(starts[slot]) + int(n_input[slot]))
        positions = jnp.asarray(np.clip(starts, 0, pool.max_len - 1))
        greedy, pool.caches = self._verify(
            self.params, jnp.asarray(inputs), pool.caches,
            jnp.asarray(n_input), positions, pool.tables_device(),
        )
        greedy = np.asarray(greedy, np.int32)

        # -- acceptance: exact argmax chain, then emit + rewind --------------
        n_commit = np.zeros(n_slots, np.int32)
        emitted_live: List[int] = []   # per-lane commits, still-decoding lanes
        emitted_all: List[int] = []
        for slot in slots:
            slot = int(slot)
            ni = int(n_input[slot])
            a = 0
            while a < ni - 1 and greedy[slot, a] == inputs[slot, a + 1]:
                a += 1
            self.spec.observe(a, ni - 1)
            self.stats.spec_accepted += a
            req = self._requests[pool.owner[slot]]
            for i in range(a + 1):
                self._emit(req, int(greedy[slot, i]))
            pool.positions[slot] = int(starts[slot]) + a + 1
            n_commit[slot] = a + 1
            emitted_all.append(a + 1)
            if self._finished(req):
                self._decoding[slot] = False
                self._free_slot(slot)
                n_commit[slot] = 0      # freed draft lane: leave it alone
            else:
                self._pending[slot] = greedy[slot, a]
                emitted_live.append(a + 1)

        # -- draft resync: rollback to the committed stream ------------------
        extra_ticks, replayed = draft.resync(inputs, n_commit)
        draft_ticks += extra_ticks
        # Debt credit = the WEAKEST live lane's progress: a low-acceptance
        # lane must still see decode_per_prefill rounds' worth of tokens
        # between prefill chunks (finished lanes need no guarantee; an
        # all-finished round credits its full commit).
        emitted = min(emitted_live) if emitted_live else max(emitted_all)
        sched.on_spec_round(draft_ticks, S, emitted, replay=replayed)
        self.stats.spec_rounds += 1
        self.stats.draft_ticks += draft_ticks
        self.events.append(("spec", sched.clock.now, -1))
        if self.obs.enabled:
            self._tr.complete(
                "spec_round", self.pid, t0, sched.clock.now,
                args={"gamma": int(gamma), "lanes": int(slots.size),
                      "committed": int(sum(emitted_all))},
            )

    def _emit(self, req: Request, tok: int) -> None:
        if not req.tokens:
            req.t_first_token = self.sched.clock.now
        req.tokens.append(tok)
        self.stats.generated_tokens += 1
        self._m_tokens.inc()

    def _finished(self, req: Request) -> bool:
        if len(req.tokens) >= req.max_new_tokens:
            if req.t_done is None:
                req.t_done = self.sched.clock.now
                self._end_request_span(req, "done", req.t_done)
            return True
        return False

    def defrag(self) -> Dict[int, int]:
        """Compact the pool's live slots and remap the engine's per-slot
        decode state to match — safe mid-flight (bare ``pool.defrag()``
        would silently desync ``_pending``/``_decoding``). With a draft
        attached, the draft pool compacts with the identical permutation
        (its occupancy mirrors the target's by construction), keeping
        the two pools in slot-index lockstep across the move."""
        moves = self.pool.defrag()
        if self.speculative:
            draft_moves = self.draft.pool.defrag()
            assert draft_moves == moves, (
                f"draft pool desync under defrag: {draft_moves} != {moves}"
            )
        if moves:
            inv = {new: old for old, new in moves.items()}
            pending, decoding = self._pending, self._decoding
            self._pending = np.zeros_like(pending)
            self._decoding = np.zeros_like(decoding)
            for s in np.nonzero(self.pool.active)[0]:
                src = inv.get(int(s), int(s))
                self._pending[s] = pending[src]
                self._decoding[s] = decoding[src]
        return moves

    # -- driver --------------------------------------------------------------
    def step(self) -> str:
        """Run one scheduler action; returns its kind. Deadlines are
        policed here, before the action is chosen — an expired request's
        slot (and blocks) are free by the time admission is priced."""
        self._expire_deadlines()
        if self.prefix_sharing:
            self._maybe_preempt_for_admission()
        kind, req = self.sched.next_action(
            self.pool.n_active, self.pool.n_free, self._can_admit
        )
        if kind == "prefill":
            self._do_prefill(req)
        elif kind == "decode":
            if self.speculative:
                self._do_spec_round()
            else:
                self._do_decode()
        elif kind == "idle":
            t0 = self.sched.clock.now
            self.sched.on_idle()
            self.events.append(("idle", self.sched.clock.now, -1))
            if self._tr.enabled:
                self._tr.complete("idle", self.pid, t0, self.sched.clock.now)
        if self.obs.enabled and kind != "done":
            self._g_slots.set(self.pool.n_active)
            values = {"slots": int(self.pool.n_active)}
            if self.pool.paged:
                used = self.pool.manager.n_used_blocks
                self._g_blocks.set(used)
                values["blocks"] = int(used)
            self._tr.counter(
                "occupancy", self.pid, self.sched.clock.now, values
            )
        return kind

    def run(self) -> Dict[int, Request]:
        """Drive until every submitted request completes."""
        t0 = time.perf_counter()
        while self.step() != "done":
            pass
        self.stats.wall_seconds += time.perf_counter() - t0
        self.stats.virtual_seconds = self.sched.clock.now
        return dict(self._requests)


# ---------------------------------------------------------------------------
# References: per-request offline decode + static batching baseline
# ---------------------------------------------------------------------------

@model_scoped_cache
def _offline_decode(model):
    return jax.jit(model.decode_step)


def generate_offline(
    model, params, prompt, max_new_tokens: int, max_len: int
) -> List[int]:
    """Single-request greedy generation with batch-1 caches — the token
    stream the continuous-batching engine must reproduce exactly."""
    prompt = np.asarray(prompt, np.int32).reshape(1, -1)
    P = prompt.shape[1]
    caches = model.blank_caches(1, max_len)
    logits, caches = model.prefill_with_cache(
        params, jnp.asarray(prompt), caches,
        length=jnp.asarray([P], jnp.int32), start_index=jnp.int32(0),
    )
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    decode = _offline_decode(model)
    for t in range(P, P + max_new_tokens - 1):
        logits, caches = decode(
            params, jnp.asarray([[tok]], jnp.int32), caches, jnp.int32(t)
        )
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


class _StaticScheduler(Scheduler):
    """Static batching: admissions barrier until the pool fully drains."""

    def __init__(self, n_slots: int, *, clock: Optional[EventClock] = None):
        super().__init__(n_slots, clock=clock)
        self._barrier_open = True

    def next_action(self, n_active: int, n_free: int, can_admit=None):
        if n_active == 0:
            self._barrier_open = True
        if self.running:
            return "prefill", self.running[0]
        req = self._eligible()
        if (req is not None and n_free > 0 and self._barrier_open
                and (can_admit is None or can_admit(req))):
            return "prefill", req
        if n_active > 0:
            self._barrier_open = False
            return "decode", None
        if self._next_arrival() is not None:
            return "idle", None
        return "done", None


def run_static(
    model,
    params,
    requests: List[Tuple[np.ndarray, int, float]],   # (prompt, max_new, arrival)
    *,
    n_slots: int,
    max_len: int,
    cost: Optional[CostModel] = None,
    prefill_bucket: int = 16,
) -> Tuple[Dict[int, Request], EngineStats]:
    """Same kernels/pool, static-batch admission (the baseline)."""
    sched = _StaticScheduler(n_slots, clock=EventClock(cost))
    eng = ServeEngine(
        model, params, n_slots=n_slots, max_len=max_len,
        scheduler=sched, prefill_bucket=prefill_bucket,
    )
    for prompt, m, arr in requests:
        eng.submit(prompt, m, arrival=arr)
    return eng.run(), eng.stats
