"""Async serving frontend: hedged dispatch over N replicas with chaos
failover, bounded retry, and in-flight KV migration.

This is the serving analogue of the training loop's elastic failover:
the frontend owns a fleet of ``Replica`` engines and a ``HedgedRouter``,
and every request is dispatched per the router's order-statistic pricing
— ``n_h`` concurrent copies, keep the first to finish, cancel the rest.
Cancellation here is REAL: a hedged loser's engine slot and paged arena
blocks are freed the moment the winner lands (``ServeEngine.cancel``),
which is what makes hedging affordable under memory pressure, and the
loser is fed to the tracker as CENSORED telemetry (all we learn is
"slower than the winner") — the same fastest-k censoring discipline the
paper's training side uses.

Failure semantics (docs/serving.md "Failure semantics"):

* **Deadlines** — each dispatch attempt carries an absolute deadline
  (``deadline`` budget from local dispatch time). The engine polices it
  every step; an expired copy frees its slot/blocks and surfaces as a
  censored observation at the deadline level. When every copy of a
  request expires, the request requeues (bounded by ``retry_budget``)
  and re-enters hedged dispatch — typically landing on faster replicas,
  since the expiry telemetry just repriced the slow ones.
* **Retry-and-requeue** — a retry does NOT restart generation: greedy
  decode is deterministic, so every copy's partial output is a prefix of
  the same stream; the longest harvested prefix is appended to the
  prompt and only the remaining tokens are regenerated. Final streams
  are byte-identical to a fault-free run.
* **Fleet degradation** — a dead replica is marked out of the fleet and
  the router re-prices from the shrunken fleet: quorum clamps to the
  live count, fan-outs re-run over whoever is left. The frontend never
  stalls while at least one replica lives.
* **Migration** — ``drain(r)`` hands every decoding request off replica
  ``r`` to the healthiest peer with capacity via
  ``ServeEngine.export_request`` / ``import_request``: the slot's owned
  KV blocks and recurrent lanes move, no re-prefill, and the greedy
  continuation is byte-identical to never having moved.

Chaos enters as a declarative ``FaultEvent`` schedule (shared with the
training runtime, ``repro.runtime.faults``) keyed on plane-wide engine
steps: ``fail`` / ``slow`` / ``rejoin`` plus the serving-only ``drain``
(graceful decommission: migrate everything off, then leave the fleet).
The frontend reacts only to observables — completions, response times,
liveness marks — never to the schedule itself.

Public API contract: MODEL-AGNOSTIC and deterministic — same workload +
same schedule -> same token streams, same virtual latencies. All policy
(hedging, retry, migration targets) lives here; replicas own time and
liveness; engines own slots and caches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import NULL_OBS, Observability
from repro.runtime.faults import FaultEvent, schedule_by_step

from .replica import Replica
from .router import HedgedRouter, HedgePlan

__all__ = ["FrontendRequest", "Frontend"]


@dataclasses.dataclass
class FrontendRequest:
    """One logical request as the frontend sees it — possibly served by
    several engine-local copies (hedges, retries, migrations) over its
    lifetime. ``tokens`` is the committed stream prefix stitched across
    attempts; ``partial`` buffers the best prefix harvested from the
    current attempt's dead copies until requeue."""

    gid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: float
    tokens: List[int] = dataclasses.field(default_factory=list)
    partial: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0
    copies: Dict[int, int] = dataclasses.field(default_factory=dict)
    t0: Dict[int, float] = dataclasses.field(default_factory=dict)
    plan: Optional[HedgePlan] = None
    t_done: Optional[float] = None
    winner: Optional[int] = None
    dropped: bool = False

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency(self) -> float:
        return (self.t_done - self.arrival) if self.done else np.inf


class Frontend:
    def __init__(
        self,
        replicas: Sequence[Replica],
        delay_model,
        *,
        quorum: int = 1,
        cost_per_replica: float = 0.0,
        beta: float = 1.0,
        deadline: Optional[float] = None,
        retry_budget: int = 3,
        events: Sequence[FaultEvent] = (),
        n_max: Optional[int] = None,
        ewma_alpha: float = 0.1,
        warmup: int = 8,
        obs: Optional[Observability] = None,
    ):
        """``deadline``: per-ATTEMPT virtual-second budget from local
        dispatch time (None = no deadlines). ``events``: chaos schedule
        keyed on plane-wide engine steps (``self.ticks``). ``obs``: the
        observability bundle — shared with the router; replicas carry
        their own (pass the same one when building them to get the full
        fleet on one timeline)."""
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        n_slots = self.replicas[0].engine.pool.n_slots
        self.obs = obs or NULL_OBS
        self._tr = self.obs.tracer
        self.pid = self._tr.register_process("frontend")
        self.router = HedgedRouter(
            delay_model, n_replicas=len(self.replicas),
            quorum=quorum, cost_per_replica=cost_per_replica,
            slots_per_replica=n_slots, n_max=n_max,
            ewma_alpha=ewma_alpha, warmup=warmup, obs=self.obs,
        )
        self.beta = float(beta)
        self.deadline = deadline
        self.retry_budget = int(retry_budget)
        self.schedule = schedule_by_step(events)
        self.ticks = 0                      # plane-wide engine steps
        self.queue: List[FrontendRequest] = []
        self.inflight: Dict[int, FrontendRequest] = {}
        self.results: Dict[int, FrontendRequest] = {}
        self.dropped: List[int] = []
        self.migrations = 0
        self._next_gid = 0
        # -- observability state ---------------------------------------------
        self._gid_spans: Dict[int, int] = {}   # gid -> open lifecycle span
        self._ts = 0.0                         # monotone frontend timestamp
        m = self.obs.metrics
        self._m_wins = m.counter("hedge.wins")
        self._m_losers = m.counter("hedge.losers_cancelled")
        self._m_expiries = m.counter("hedge.deadline_expiries")
        self._m_retries = m.counter("frontend.retries")
        self._m_dropped = m.counter("frontend.dropped")
        self._m_migrations = m.counter("frontend.migrations")
        self._h_latency = m.histogram("frontend.latency")

    # -- submission ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, arrival: float = 0.0) -> int:
        gid = self._next_gid
        self._next_gid += 1
        fr = FrontendRequest(
            gid, np.asarray(prompt, np.int32).reshape(-1),
            int(max_new_tokens), float(arrival),
        )
        self.queue.append(fr)
        if self._tr.enabled:
            # Logical lifecycle span: [arrival, t_done]. Every retirement
            # path stamps a ts >= arrival, so the span never inverts.
            self._gid_spans[gid] = self._tr.begin_span(
                "request", self.pid, fr.arrival,
                args={"gid": gid, "prompt_len": int(fr.prompt.size),
                      "max_new_tokens": int(max_new_tokens)},
            )
        return gid

    # -- time ----------------------------------------------------------------
    def _frontier(self) -> float:
        return max((rep.now for rep in self.replicas if rep.alive), default=0.0)

    def _stamp(self) -> float:
        """Monotone frontend-lane timestamp: the fleet frontier can go
        BACKWARD when the fastest replica fails, but a trace track may
        not — clamp to the furthest time this lane has already stamped."""
        self._ts = max(self._ts, self._frontier())
        return self._ts

    def _end_gid_span(self, fr: FrontendRequest, outcome: str, ts: float) -> None:
        sid = self._gid_spans.pop(fr.gid, None)
        if sid:
            self._tr.end_span(
                sid, max(ts, fr.arrival),
                args={"outcome": outcome, "n_tokens": len(fr.tokens),
                      "retries": fr.retries},
            )

    # -- fault surface -------------------------------------------------------
    def _apply(self, ev: FaultEvent) -> None:
        rep = self.replicas[ev.worker]
        if self._tr.enabled:
            self._tr.instant(
                "fault", self.pid, self._stamp(),
                args={"kind": ev.kind, "replica": ev.worker,
                      "tick": self.ticks},
            )
        if ev.kind == "fail":
            self._on_fail(ev.worker)
        elif ev.kind == "slow":
            if rep.alive:
                rep.set_slow(ev.factor)
        elif ev.kind == "rejoin":
            if rep.alive:
                rep.set_slow(1.0)
            else:
                rep.rejoin(self._frontier())
                self.router.mark_joined(ev.worker)
        elif ev.kind == "drain":
            if rep.alive:
                self.drain(ev.worker)
                rep.alive = False
                self.router.mark_failed(ev.worker)
        else:
            raise ValueError(f"unknown fault kind {ev.kind!r}")

    def _on_fail(self, r: int) -> None:
        rep = self.replicas[r]
        if not rep.alive:
            return
        self.router.mark_failed(r)
        by_rid = {req.rid: req for req in rep.fail()}
        for fr in list(self.inflight.values()):
            rid = fr.copies.pop(r, None)
            if rid is None:
                continue
            fr.t0.pop(r, None)
            self.router.release(r)
            local = by_rid.get(rid)
            if local is not None and len(local.tokens) > len(fr.partial):
                fr.partial = list(local.tokens)
            if not fr.copies:
                # The hedge didn't cover this failure: requeue from the
                # longest prefix any dead copy got to.
                self._requeue(fr)

    # -- migration -----------------------------------------------------------
    def drain(self, r: int) -> int:
        """Migrate every in-flight copy off replica ``r``: decoding
        copies move their KV state (block handoff, no re-prefill);
        queued / mid-prefill copies just requeue. Returns the number of
        KV migrations performed."""
        rep = self.replicas[r]
        before = self.migrations
        decoding = set(rep.engine.decoding_rids())
        for fr in list(self.inflight.values()):
            rid = fr.copies.get(r)
            if rid is None:
                continue
            if not (rid in decoding and self._migrate(fr, r, rid)):
                self._abandon_copy(fr, r, rid)
        return self.migrations - before

    def _migrate(self, fr: FrontendRequest, src: int, rid: int) -> bool:
        """KV block handoff: export from ``src``, import into the
        fastest-estimated alive peer that can admit it. Returns True
        once the copy is fully handled — moved, or (every import
        refused) torn down with its tokens seeding the requeue prefix.
        False only when there is no peer to even try, leaving the copy
        for the caller to abandon."""
        rep = self.replicas[src]
        slow = self.router._slowdowns()
        dests = sorted(
            (d for d in self.replicas if d.alive and d.id != src),
            key=lambda d: (slow[d.id], d.id),
        )
        if not dests:
            return False
        ticket = rep.engine.export_request(rid)
        elapsed = rep.now - fr.t0[src]
        for dest in dests:
            adj = ticket
            if ticket.deadline is not None:
                # Absolute deadlines are clock-local: carry the REMAINING
                # budget over to the destination's clock.
                remaining = max(ticket.deadline - rep.now, 0.0)
                adj = dataclasses.replace(
                    ticket, deadline=dest.now + remaining
                )
            new_rid = dest.engine.import_request(adj)
            if new_rid is None:
                continue
            del fr.copies[src]
            del fr.t0[src]
            fr.copies[dest.id] = new_rid
            fr.t0[dest.id] = dest.now - elapsed   # preserve elapsed so far
            self.router.release(src)
            self.router.occupy(dest.id)
            self.migrations += 1
            self._m_migrations.inc()
            if self._tr.enabled:
                self._tr.instant(
                    "migrate", self.pid, self._stamp(),
                    args={"gid": fr.gid, "src": src, "dest": dest.id},
                )
            return True
        # No destination could admit: the ticket dies, but its tokens
        # seed the requeue prefix (ticket.tokens = the full local stream).
        if len(ticket.tokens) > len(fr.partial):
            fr.partial = list(ticket.tokens)
        del fr.copies[src]
        del fr.t0[src]
        self.router.release(src)
        if not fr.copies:
            self._requeue(fr)
        return True

    def _abandon_copy(self, fr: FrontendRequest, r: int, rid: int) -> None:
        eng = self.replicas[r].engine
        local = eng.request(rid)
        eng.cancel(rid)
        if len(local.tokens) > len(fr.partial):
            fr.partial = list(local.tokens)
        fr.copies.pop(r, None)
        fr.t0.pop(r, None)
        self.router.release(r)
        if not fr.copies:
            self._requeue(fr)

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self) -> None:
        self.queue.sort(key=lambda fr: (fr.arrival, fr.gid))
        while self.queue:
            plan = self.router.choose_hedge(self.beta)
            if plan is None:
                return
            fr = self.queue.pop(0)
            self.router.begin(plan)
            fr.plan = plan
            fr.copies, fr.t0 = {}, {}
            prompt = fr.prompt
            if fr.tokens:
                prompt = np.concatenate(
                    [fr.prompt, np.asarray(fr.tokens, np.int32)]
                )
            remaining = fr.max_new_tokens - len(fr.tokens)
            for r in plan.replicas:
                rep = self.replicas[r]
                local_arr = max(rep.now, fr.arrival)
                dl = None if self.deadline is None else local_arr + self.deadline
                rid = rep.engine.submit(
                    prompt, remaining, arrival=fr.arrival, deadline=dl
                )
                fr.copies[r] = rid
                fr.t0[r] = local_arr
            self.inflight[fr.gid] = fr
            if self._tr.enabled:
                self._tr.instant(
                    "dispatch", self.pid, self._stamp(),
                    args={"gid": fr.gid, "n_h": plan.n_h,
                          "replicas": list(plan.replicas),
                          "retry": fr.retries},
                )

    def _requeue(self, fr: FrontendRequest) -> None:
        fr.tokens = fr.tokens + fr.partial
        fr.partial = []
        fr.plan, fr.copies, fr.t0 = None, {}, {}
        self.inflight.pop(fr.gid, None)
        if len(fr.tokens) >= fr.max_new_tokens:
            # The dead copies had already finished the stream.
            fr.t_done = self._frontier()
            self.results[fr.gid] = fr
            self._end_gid_span(fr, "done", fr.t_done)
            self._h_latency.observe(fr.latency)
        elif fr.retries >= self.retry_budget:
            fr.dropped = True
            self.dropped.append(fr.gid)
            self.results[fr.gid] = fr
            self._m_dropped.inc()
            self._end_gid_span(fr, "dropped", self._stamp())
        else:
            fr.retries += 1
            self.queue.append(fr)
            self._m_retries.inc()
            if self._tr.enabled:
                self._tr.instant(
                    "requeue", self.pid, self._stamp(),
                    args={"gid": fr.gid, "retry": fr.retries,
                          "prefix_tokens": len(fr.tokens)},
                )

    # -- harvest -------------------------------------------------------------
    def _harvest(self, rep: Replica) -> None:
        r = rep.id
        for fr in list(self.inflight.values()):
            rid = fr.copies.get(r)
            if rid is None:
                continue
            req = rep.engine.request(rid)
            if req.t_done is not None:
                self._resolve_winner(fr, r, req)
            elif req.cancelled and req.cancel_reason == "deadline":
                self._copy_expired(fr, r)

    def _resolve_winner(self, fr: FrontendRequest, winner: int, req) -> None:
        rep = self.replicas[winner]
        elapsed = rep.now - fr.t0[winner]
        participants = list(fr.copies)
        for r, rid in list(fr.copies.items()):
            if r != winner:
                # Loser cancellation is what frees slots AND blocks.
                self.replicas[r].engine.cancel(rid)
            self.router.release(r)
        dense = np.zeros(self.router.n_replicas)
        dense[winner] = elapsed
        # Winner observed; losers censored at the winner's elapsed time.
        self.router.record(
            dense, participants, observed=[winner], censor_level=elapsed
        )
        fr.tokens = fr.tokens + list(req.tokens)
        fr.t_done = rep.now
        fr.winner = winner
        fr.copies, fr.t0 = {}, {}
        self.inflight.pop(fr.gid, None)
        self.results[fr.gid] = fr
        self._m_wins.inc()
        self._m_losers.inc(len(participants) - 1)
        self._h_latency.observe(fr.latency)
        self._end_gid_span(fr, "done", fr.t_done)

    def _copy_expired(self, fr: FrontendRequest, r: int) -> None:
        rep = self.replicas[r]
        req = rep.engine.request(fr.copies[r])
        if len(req.tokens) > len(fr.partial):
            fr.partial = list(req.tokens)
        del fr.copies[r]
        fr.t0.pop(r, None)
        self.router.release(r)
        # All the expiry teaches us: this replica was slower than the
        # deadline budget on this request.
        self.router.record(
            np.zeros(self.router.n_replicas), [r],
            observed=[], censor_level=self.deadline,
        )
        self._m_expiries.inc()
        if self._tr.enabled:
            self._tr.instant(
                "deadline_expiry", self.pid, self._stamp(),
                args={"gid": fr.gid, "replica": r},
            )
        if not fr.copies:
            self._requeue(fr)

    # -- driver --------------------------------------------------------------
    def _step_target(self) -> Optional[Replica]:
        cands = [rep for rep in self.replicas if rep.alive and rep.has_work]
        if not cands:
            return None
        return min(cands, key=lambda rep: (rep.now, rep.id))

    def run(self) -> Dict[int, FrontendRequest]:
        """Drive the fleet until every request completes or drops.
        Deterministic: one engine action per iteration, always on the
        alive replica furthest behind in virtual time (ties to lowest
        id); chaos events fire between actions at their scheduled
        step."""
        while self.queue or self.inflight:
            for ev in self.schedule.pop(self.ticks, []):
                self._apply(ev)
            self._dispatch()
            rep = self._step_target()
            if rep is None:
                future = [s for s in self.schedule if s > self.ticks]
                if future:
                    # Whole fleet down/idle: jump to the next chaos event
                    # (e.g. a rejoin) instead of spinning.
                    self.ticks = min(future)
                    continue
                if self.queue or self.inflight:
                    raise RuntimeError(
                        "frontend stranded: requests pending but no live "
                        "replica has capacity and no future fault events"
                    )
                break
            rep.step()
            self.ticks += 1
            self._harvest(rep)
        return dict(self.results)

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        lats = [fr.latency for fr in self.results.values() if fr.done]
        eng = [rep.engine.stats for rep in self.replicas]
        return {
            "completed": sum(fr.done for fr in self.results.values()),
            "dropped": len(self.dropped),
            "retries": sum(fr.retries for fr in self.results.values()),
            "migrations": self.migrations,
            "cancelled_copies": sum(s.cancelled_requests for s in eng),
            "generated_tokens": sum(s.generated_tokens for s in eng),
            "p50_latency": float(np.percentile(lats, 50)) if lats else np.nan,
            "p99_latency": float(np.percentile(lats, 99)) if lats else np.nan,
        }
