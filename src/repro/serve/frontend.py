"""Async serving frontend: hedged dispatch over N replicas with chaos
failover, bounded retry, and in-flight KV migration — over an explicit,
faultable message transport.

This is the serving analogue of the training loop's elastic failover:
the frontend owns a fleet of ``Replica`` engines and a ``HedgedRouter``,
and every request is dispatched per the router's order-statistic pricing
— ``n_h`` concurrent copies, keep the first to finish, cancel the rest.
Cancellation here is REAL: a hedged loser's engine slot and paged arena
blocks are freed when the cancel lands (``ServeEngine.cancel``), which
is what makes hedging affordable under memory pressure, and the loser is
fed to the tracker as CENSORED telemetry (all we learn is "slower than
the winner") — the same fastest-k censoring discipline the paper's
training side uses.

Since PR 9 the frontend talks to replicas ONLY through
``serve.transport``: submits, cancels, stream chunks, migration tickets
and their replies are wire messages that a fault plan can drop,
duplicate, reorder, delay, or partition away, and the invariants below
survive because the protocol is idempotent at-least-once — copies are
addressed by ``(gid, attempt)`` (never replica-local rids), stream
chunks are position-addressed, the transport acks/dedups/retransmits
with backoff priced from the router's censored telemetry, and migration
tickets carry an end-to-end integrity checksum (reject-and-requeue on
corruption). The ONE deliberate exception to messages-only is the
co-located control plane: teardown of a node the chaos plane just
killed or drained (harvesting partials, exporting tickets) touches that
node's engine/port directly — that code runs ON the node in a real
deployment, and there is no network between a process and itself.

Failure semantics (docs/serving.md "Failure semantics"):

* **Deadlines** — each dispatch attempt carries a deadline BUDGET; the
  replica stamps the absolute deadline on its own clock at admission.
  The engine polices it every step; an expired copy frees its
  slot/blocks and surfaces (via an ``Expired`` message) as a censored
  observation at the deadline level. When every copy of a request
  expires, the request requeues (bounded by ``retry_budget``).
* **Retry-and-requeue** — a retry does NOT restart generation: greedy
  decode is deterministic, so every copy's partial output is a prefix of
  the same stream; the longest RECEIVED prefix is appended to the prompt
  and only the remaining tokens are regenerated. Final streams are
  byte-identical to a fault-free run.
* **Fleet degradation** — a dead replica is marked out of the fleet,
  its transport endpoint is forgotten (in-flight messages die with the
  process, dedup history wipes — a rejoin is a fresh process), and the
  router re-prices from the shrunken fleet.
* **Migration** — ``drain(r)`` exports every decoding request on ``r``
  into sealed ``MigrationTicket``s and ships each to the
  fastest-estimated peer as a ``Ticket`` message; the destination
  verifies integrity and replies ok / busy / corrupt. Busy walks the
  peer list; corrupt is reject-and-requeue from the last trusted prefix
  — a mutated ticket is NEVER resumed. A draining node stops taking new
  work but its outbound messages keep (re)transmitting until acked:
  graceful decommission flushes the pipe, hard failure cuts it.

Chaos enters on two axes: the node-level ``FaultEvent`` schedule shared
with the training runtime (fail / slow / rejoin / drain, keyed on
plane-wide ticks) and the message-level ``TransportFaults`` plan
(per-transmission drop/dup/delay/reorder/corrupt directives plus one-way
partitions). The frontend reacts only to observables — messages,
response times, liveness marks — never to either schedule.

Public API contract: MODEL-AGNOSTIC and deterministic — same workload +
same schedules -> same token streams, same virtual latencies, same wire
history. All policy (hedging, retry, migration targets) lives here;
replicas own time and liveness; engines own slots and caches; the
transport owns delivery.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.obs import NULL_OBS, Observability
from repro.runtime.faults import FaultEvent, schedule_by_step

from .replica import Replica, ReplicaPort
from .router import HedgedRouter, HedgePlan
from .transport import (
    FE,
    Cancel,
    Submit,
    Ticket,
    Transport,
    TransportFaults,
    replica_endpoint,
)

__all__ = ["FrontendRequest", "Frontend"]


@dataclasses.dataclass
class _AttemptBuf:
    """Reassembly buffer for one copy's position-addressed chunk stream.
    Duplicated chunks rewrite the same cells with the same values;
    reordered chunks fill different cells; the stream is complete when
    positions ``0..total-1`` are all present."""

    toks: Dict[int, int] = dataclasses.field(default_factory=dict)
    total: Optional[int] = None
    elapsed: Optional[float] = None

    @property
    def complete(self) -> bool:
        return self.total is not None and all(
            i in self.toks for i in range(self.total)
        )

    def stream(self) -> List[int]:
        return [self.toks[i] for i in range(self.total)]

    def prefix(self) -> List[int]:
        """Longest contiguous received prefix — the safe salvage when
        the sender died mid-stream (later cells past a hole cannot be
        trusted as committed)."""
        out, i = [], 0
        while i in self.toks:
            out.append(self.toks[i])
            i += 1
        return out


@dataclasses.dataclass
class _PendingTicket:
    """A migration in flight: the frontend holds the sealed (intact)
    ticket while a wire copy rides to ``dest``; ``tried`` prevents
    re-offering to a peer that already refused."""

    attempt: int
    ticket: object                      # engine.MigrationTicket (sealed)
    remaining: Optional[float]          # deadline budget left (src clock)
    elapsed: float                      # service time already accrued
    dest: Optional[int] = None
    tried: Set[int] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class FrontendRequest:
    """One logical request as the frontend sees it — possibly served by
    several copies (hedges, retries, migrations) over its lifetime, each
    addressed by a globally unique ``(gid, attempt)`` key. ``tokens`` is
    the committed stream prefix stitched across attempts; ``partial``
    buffers the best received prefix from the current attempt's dead
    copies until requeue."""

    gid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: float
    tokens: List[int] = dataclasses.field(default_factory=list)
    partial: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0
    copies: Dict[int, int] = dataclasses.field(default_factory=dict)
    recv: Dict[int, _AttemptBuf] = dataclasses.field(default_factory=dict)
    n_attempts: int = 0
    pending_ticket: Optional[_PendingTicket] = None
    plan: Optional[HedgePlan] = None
    t_done: Optional[float] = None
    winner: Optional[int] = None
    dropped: bool = False

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency(self) -> float:
        return (self.t_done - self.arrival) if self.done else np.inf

    @property
    def live_copies(self) -> int:
        return len(self.copies) + (1 if self.pending_ticket else 0)


class Frontend:
    def __init__(
        self,
        replicas: Sequence[Replica],
        delay_model,
        *,
        quorum: int = 1,
        cost_per_replica: float = 0.0,
        beta: float = 1.0,
        deadline: Optional[float] = None,
        retry_budget: int = 3,
        events: Sequence[FaultEvent] = (),
        transport_faults: Optional[TransportFaults] = None,
        reliable: bool = True,
        dedup: bool = True,
        base_rto_ticks: int = 16,
        max_ticks: Optional[int] = None,
        n_max: Optional[int] = None,
        ewma_alpha: float = 0.1,
        warmup: int = 8,
        obs: Optional[Observability] = None,
    ):
        """``deadline``: per-ATTEMPT virtual-second budget, stamped
        absolute by the receiving replica at admission (None = no
        deadlines). ``events``: node-level chaos schedule keyed on
        plane-wide ticks. ``transport_faults``: message-level fault plan
        (``serve.transport``). ``reliable``/``dedup``: the at-least-once
        layer's knobs — ONLY disable them to demonstrate what they buy
        (the chaos harness does exactly that). ``max_ticks``: hard cap
        on plane ticks; exceeding it raises — the chaos harness's
        liveness oracle. ``obs``: the observability bundle — shared with
        the router and transport; replicas carry their own (pass the
        same one when building them to get the full fleet on one
        timeline)."""
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = list(replicas)
        n_slots = self.replicas[0].engine.pool.n_slots
        self.obs = obs or NULL_OBS
        self._tr = self.obs.tracer
        self.pid = self._tr.register_process("frontend")
        self.router = HedgedRouter(
            delay_model, n_replicas=len(self.replicas),
            quorum=quorum, cost_per_replica=cost_per_replica,
            slots_per_replica=n_slots, n_max=n_max,
            ewma_alpha=ewma_alpha, warmup=warmup, obs=self.obs,
        )
        self.transport = Transport(
            len(self.replicas), transport_faults,
            reliable=reliable, dedup=dedup, base_rto_ticks=base_rto_ticks,
            rto_scale=self._rto_scale, obs=self.obs,
        )
        self.ports = [ReplicaPort(rep, self.transport) for rep in self.replicas]
        self.beta = float(beta)
        self.deadline = deadline
        self.retry_budget = int(retry_budget)
        self.max_ticks = max_ticks
        self.schedule = schedule_by_step(events)
        self.ticks = 0                      # plane-wide engine steps
        self.queue: List[FrontendRequest] = []
        self.inflight: Dict[int, FrontendRequest] = {}
        self.results: Dict[int, FrontendRequest] = {}
        self.dropped: List[int] = []
        self.migrations = 0
        self.ticket_rejects = 0             # corrupt tickets refused
        self._next_gid = 0
        # -- observability state ---------------------------------------------
        self._gid_spans: Dict[int, int] = {}   # gid -> open lifecycle span
        self._ts = 0.0                         # monotone frontend timestamp
        m = self.obs.metrics
        self._m_wins = m.counter("hedge.wins")
        self._m_losers = m.counter("hedge.losers_cancelled")
        self._m_expiries = m.counter("hedge.deadline_expiries")
        self._m_retries = m.counter("frontend.retries")
        self._m_dropped = m.counter("frontend.dropped")
        self._m_migrations = m.counter("frontend.migrations")
        self._m_ticket_rejects = m.counter("frontend.ticket_rejects")
        self._h_latency = m.histogram("frontend.latency")

    def _rto_scale(self, ep: str) -> float:
        """Retransmission pricing: a destination the censored telemetry
        says is k-times slow gets a k-times retransmit budget before the
        sender burns a duplicate transmission."""
        if ep == FE:
            return 1.0
        return float(self.router.slowdowns()[int(ep[1:])])

    # -- submission ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, arrival: float = 0.0) -> int:
        gid = self._next_gid
        self._next_gid += 1
        fr = FrontendRequest(
            gid, np.asarray(prompt, np.int32).reshape(-1),
            int(max_new_tokens), float(arrival),
        )
        self.queue.append(fr)
        if self._tr.enabled:
            # Logical lifecycle span: [arrival, t_done]. Every retirement
            # path stamps a ts >= arrival, so the span never inverts.
            self._gid_spans[gid] = self._tr.begin_span(
                "request", self.pid, fr.arrival,
                args={"gid": gid, "prompt_len": int(fr.prompt.size),
                      "max_new_tokens": int(max_new_tokens)},
            )
        return gid

    # -- time ----------------------------------------------------------------
    def _frontier(self) -> float:
        return max((rep.now for rep in self.replicas if rep.alive), default=0.0)

    def _stamp(self) -> float:
        """Monotone frontend-lane timestamp: the fleet frontier can go
        BACKWARD when the fastest replica fails, but a trace track may
        not — clamp to the furthest time this lane has already stamped."""
        self._ts = max(self._ts, self._frontier())
        return self._ts

    def _end_gid_span(self, fr: FrontendRequest, outcome: str, ts: float) -> None:
        sid = self._gid_spans.pop(fr.gid, None)
        if sid:
            self._tr.end_span(
                sid, max(ts, fr.arrival),
                args={"outcome": outcome, "n_tokens": len(fr.tokens),
                      "retries": fr.retries},
            )

    # -- fault surface -------------------------------------------------------
    def _apply(self, ev: FaultEvent) -> None:
        rep = self.replicas[ev.worker]
        if self._tr.enabled:
            self._tr.instant(
                "fault", self.pid, self._stamp(),
                args={"kind": ev.kind, "replica": ev.worker,
                      "tick": self.ticks},
            )
        if ev.kind == "fail":
            self._on_fail(ev.worker)
        elif ev.kind == "slow":
            if rep.alive:
                rep.set_slow(ev.factor)
        elif ev.kind == "rejoin":
            if rep.alive:
                rep.set_slow(1.0)
            else:
                rep.rejoin(self._frontier())
                self.ports[ev.worker].reset()
                self.transport.revive_endpoint(replica_endpoint(ev.worker))
                self.router.mark_joined(ev.worker)
        elif ev.kind == "drain":
            if rep.alive:
                self.drain(ev.worker)
                rep.alive = False
                self.router.mark_failed(ev.worker)
        else:
            raise ValueError(f"unknown fault kind {ev.kind!r}")

    def _on_fail(self, r: int) -> None:
        """Hard failure: the process dies — its engine state, its
        protocol state, and every message queued to or from it. Partial
        streams are salvaged from what the frontend RECEIVED, not from
        the corpse's memory."""
        rep = self.replicas[r]
        if not rep.alive:
            return
        self.router.mark_failed(r)
        rep.fail()
        self.ports[r].reset()
        self.transport.forget_endpoint(replica_endpoint(r))
        for fr in list(self.inflight.values()):
            att = fr.copies.pop(r, None)
            if att is not None:
                self.router.release(r)
                prefix = fr.recv[att].prefix()
                if len(prefix) > len(fr.partial):
                    fr.partial = prefix
            pt = fr.pending_ticket
            if pt is not None and pt.dest == r:
                # The in-flight ticket's destination died before (or
                # after — we cannot know) importing: the frontend still
                # holds the intact ticket, so offer it to the next peer.
                pt.dest = None
                self._offer_ticket(fr, pt)
            if att is not None and fr.live_copies == 0:
                self._requeue(fr)

    # -- migration -----------------------------------------------------------
    def drain(self, r: int) -> int:
        """Gracefully decommission replica ``r``: export every decoding
        copy into a sealed ticket and ship it to a peer; abandon (and
        requeue) queued / mid-prefill copies. Export and teardown are
        co-located control plane (this code runs on the node); the
        ticket TRANSFER is a wire message the fault plan can attack.
        Returns the number of tickets put in flight — replies resolve
        asynchronously, so ``self.migrations`` counts landings, not
        departures."""
        rep, port = self.replicas[r], self.ports[r]
        decoding = set(rep.engine.decoding_rids())
        sent = 0
        for fr in list(self.inflight.values()):
            att = fr.copies.get(r)
            if att is None:
                continue
            rid = port.rid_of(fr.gid, att)
            if rid is not None and rid in decoding:
                self._export_and_offer(fr, r, att, rid)
                sent += 1
            else:
                self._abandon_copy(fr, r, att)
        return sent

    def _export_and_offer(
        self, fr: FrontendRequest, src: int, att: int, rid: int
    ) -> None:
        rep, port = self.replicas[src], self.ports[src]
        elapsed = port.elapsed_of(fr.gid, att)
        ticket = rep.engine.export_request(rid)
        port.forget(fr.gid, att)
        remaining = (
            None if ticket.deadline is None
            else max(ticket.deadline - rep.now, 0.0)
        )
        del fr.copies[src]
        self.router.release(src)
        # The sealed ticket is authoritative for the stream prefix it
        # carries. Chunks from ``src`` still in flight will be dropped
        # as stale once the copy is deregistered (the ``_active`` guard)
        # — without this merge, a chunk racing the export would leave a
        # permanent hole in the attempt buffer and strand the request.
        buf = fr.recv[att]
        for i, t in enumerate(ticket.tokens):
            buf.toks[i] = int(t)
        pt = _PendingTicket(
            attempt=att, ticket=ticket, remaining=remaining,
            elapsed=elapsed, tried={src},
        )
        fr.pending_ticket = pt
        self._offer_ticket(fr, pt)

    def _offer_ticket(self, fr: FrontendRequest, pt: _PendingTicket) -> None:
        """Ship the held ticket to the fastest-estimated peer not yet
        tried; when every peer has refused (or none is alive), the
        ticket dies and its tokens seed the requeue prefix. A peer
        already hosting a hedged copy of this request is excluded —
        ``fr.copies`` is keyed by replica, so landing there would
        silently orphan the existing copy's accounting (the chaos
        harness's no-leaks oracle caught exactly that)."""
        slow = self.router.slowdowns()
        dests = sorted(
            (d for d in self.replicas
             if d.alive and d.id not in pt.tried and d.id not in fr.copies),
            key=lambda d: (slow[d.id], d.id),
        )
        if not dests:
            fr.pending_ticket = None
            if len(pt.ticket.tokens) > len(fr.partial):
                fr.partial = list(pt.ticket.tokens)
            if fr.live_copies == 0:
                self._requeue(fr)
            return
        dest = dests[0]
        pt.dest = dest.id
        pt.tried.add(dest.id)
        self.transport.send(
            FE, replica_endpoint(dest.id),
            Ticket(fr.gid, pt.attempt, pt.ticket, pt.remaining, pt.elapsed),
            self.ticks,
        )

    def _abandon_copy(self, fr: FrontendRequest, r: int, att: int) -> None:
        """Tear down a copy on a node being decommissioned (co-located
        control plane). The engine's partial stream is trustworthy here
        — the node is alive and we are standing on it."""
        port = self.ports[r]
        rid = port.rid_of(fr.gid, att)
        if rid is not None:
            eng = self.replicas[r].engine
            local = eng.request(rid)
            eng.cancel(rid)
            if len(local.tokens) > len(fr.partial):
                fr.partial = list(local.tokens)
            port.forget(fr.gid, att)
        del fr.copies[r]
        self.router.release(r)
        if fr.live_copies == 0:
            self._requeue(fr)

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self) -> None:
        self.queue.sort(key=lambda fr: (fr.arrival, fr.gid))
        while self.queue:
            plan = self.router.choose_hedge(self.beta)
            if plan is None:
                return
            fr = self.queue.pop(0)
            self.router.begin(plan)
            fr.plan = plan
            fr.copies, fr.recv = {}, {}
            prompt = fr.prompt
            if fr.tokens:
                prompt = np.concatenate(
                    [fr.prompt, np.asarray(fr.tokens, np.int32)]
                )
            remaining = fr.max_new_tokens - len(fr.tokens)
            for r in plan.replicas:
                att = fr.n_attempts
                fr.n_attempts += 1
                fr.copies[r] = att
                fr.recv[att] = _AttemptBuf()
                self.transport.send(
                    FE, replica_endpoint(r),
                    Submit(fr.gid, att, prompt, remaining,
                           fr.arrival, self.deadline),
                    self.ticks,
                )
            self.inflight[fr.gid] = fr
            if self._tr.enabled:
                self._tr.instant(
                    "dispatch", self.pid, self._stamp(),
                    args={"gid": fr.gid, "n_h": plan.n_h,
                          "replicas": list(plan.replicas),
                          "retry": fr.retries},
                )

    def _requeue(self, fr: FrontendRequest) -> None:
        fr.tokens = fr.tokens + fr.partial
        fr.partial = []
        fr.plan, fr.copies, fr.recv = None, {}, {}
        fr.pending_ticket = None
        self.inflight.pop(fr.gid, None)
        if len(fr.tokens) >= fr.max_new_tokens:
            # The dead copies had already finished the stream.
            fr.t_done = self._frontier()
            self.results[fr.gid] = fr
            self._end_gid_span(fr, "done", fr.t_done)
            self._h_latency.observe(fr.latency)
        elif fr.retries >= self.retry_budget:
            fr.dropped = True
            self.dropped.append(fr.gid)
            self.results[fr.gid] = fr
            self._m_dropped.inc()
            self._end_gid_span(fr, "dropped", self._stamp())
        else:
            fr.retries += 1
            self.queue.append(fr)
            self._m_retries.inc()
            if self._tr.enabled:
                self._tr.instant(
                    "requeue", self.pid, self._stamp(),
                    args={"gid": fr.gid, "retry": fr.retries,
                          "prefix_tokens": len(fr.tokens)},
                )

    # -- inbound protocol ----------------------------------------------------
    def _process_inbox(self) -> None:
        for msg in self.transport.receive(FE, self.ticks):
            r = int(msg.src[1:])
            if msg.kind == "chunk":
                self._on_chunk(r, msg.payload)
            elif msg.kind == "expired":
                self._on_expired(r, msg.payload)
            elif msg.kind == "ticketreply":
                self._on_ticket_reply(r, msg.payload)
            else:
                raise ValueError(f"frontend got unexpected {msg.kind!r}")

    def _active(self, r: int, gid: int, attempt: int) -> Optional[FrontendRequest]:
        """The request iff ``(gid, attempt)`` is the CURRENT copy on
        ``r`` — everything else (resolved gids, superseded attempts,
        reassigned replicas) is stale wire traffic to ignore."""
        fr = self.inflight.get(gid)
        if fr is None or fr.copies.get(r) != attempt:
            return None
        return fr

    def _on_chunk(self, r: int, p) -> None:
        fr = self._active(r, p.gid, p.attempt)
        if fr is None:
            return
        buf = fr.recv[p.attempt]
        for i, tok in enumerate(p.tokens):
            buf.toks[p.start + i] = int(tok)
        if p.done:
            buf.total = int(p.total)
            buf.elapsed = float(p.elapsed)
        if buf.complete:
            self._resolve_winner(fr, r, p.attempt)

    def _resolve_winner(self, fr: FrontendRequest, winner: int, att: int) -> None:
        buf = fr.recv[att]
        elapsed = buf.elapsed
        participants = list(fr.copies)
        for r, a in list(fr.copies.items()):
            if r != winner:
                # Loser cancellation is what frees slots AND blocks —
                # it rides the (reliable) wire, so it lands a beat
                # later than the old direct call; the run loop keeps
                # the plane alive until every cancel is acked.
                self.transport.send(
                    FE, replica_endpoint(r), Cancel(fr.gid, a), self.ticks
                )
            self.router.release(r)
        dense = np.zeros(self.router.n_replicas)
        dense[winner] = elapsed
        # Winner observed; losers censored at the winner's elapsed time.
        self.router.record(
            dense, participants, observed=[winner], censor_level=elapsed
        )
        fr.tokens = fr.tokens + buf.stream()
        fr.t_done = max(self.replicas[winner].now, fr.arrival)
        fr.winner = winner
        fr.copies, fr.recv = {}, {}
        self.inflight.pop(fr.gid, None)
        self.results[fr.gid] = fr
        self._m_wins.inc()
        self._m_losers.inc(len(participants) - 1)
        self._h_latency.observe(fr.latency)
        self._end_gid_span(fr, "done", fr.t_done)

    def _on_expired(self, r: int, p) -> None:
        fr = self._active(r, p.gid, p.attempt)
        if fr is None:
            return
        if len(p.tokens) > len(fr.partial):
            fr.partial = list(p.tokens)
        del fr.copies[r]
        self.router.release(r)
        # All the expiry teaches us: this replica was slower than the
        # deadline budget on this request.
        self.router.record(
            np.zeros(self.router.n_replicas), [r],
            observed=[], censor_level=self.deadline,
        )
        self._m_expiries.inc()
        if self._tr.enabled:
            self._tr.instant(
                "deadline_expiry", self.pid, self._stamp(),
                args={"gid": fr.gid, "replica": r},
            )
        if fr.live_copies == 0:
            self._requeue(fr)

    def _on_ticket_reply(self, r: int, p) -> None:
        fr = self.inflight.get(p.gid) or self.results.get(p.gid)
        pt = fr.pending_ticket if fr is not None else None
        if pt is None or pt.dest != r or pt.attempt != p.attempt:
            return
        if fr.done or fr.dropped:
            # The hedge resolved while the ticket was in flight: a
            # successful zombie import must be torn down, a refusal
            # needs nothing.
            fr.pending_ticket = None
            if p.status == "ok":
                self.transport.send(
                    FE, replica_endpoint(r), Cancel(p.gid, p.attempt),
                    self.ticks,
                )
            return
        if p.status == "ok":
            fr.pending_ticket = None
            fr.copies[r] = pt.attempt
            self.router.occupy(r)
            self.migrations += 1
            self._m_migrations.inc()
            if self._tr.enabled:
                self._tr.instant(
                    "migrate", self.pid, self._stamp(),
                    args={"gid": fr.gid, "dest": r},
                )
        elif p.status == "corrupt":
            # Reject-and-requeue: the wire copy was mutated in flight
            # and the destination's integrity check caught it. NEVER
            # resume from a corrupt ticket — fall back to the last
            # trusted prefix (the intact ticket the frontend held).
            fr.pending_ticket = None
            self.ticket_rejects += 1
            self._m_ticket_rejects.inc()
            if self._tr.enabled:
                self._tr.instant(
                    "ticket_reject", self.pid, self._stamp(),
                    args={"gid": fr.gid, "dest": r},
                )
            if len(pt.ticket.tokens) > len(fr.partial):
                fr.partial = list(pt.ticket.tokens)
            if fr.live_copies == 0:         # the ticket WAS the last copy
                self._requeue(fr)
        else:                               # busy: walk the peer list
            self._offer_ticket(fr, pt)

    # -- driver --------------------------------------------------------------
    def _step_target(self) -> Optional[Replica]:
        cands = [rep for rep in self.replicas if rep.alive and rep.has_work]
        if not cands:
            return None
        return min(cands, key=lambda rep: (rep.now, rep.id))

    def _deliver_replica_inboxes(self) -> None:
        for rep, port in zip(self.replicas, self.ports):
            ep = replica_endpoint(rep.id)
            for msg in self.transport.receive(ep, self.ticks):
                if not rep.alive:
                    # A decommissioned (drained) node refuses new work
                    # but still answers tickets with busy — the sender
                    # must not wait forever on a corpse that acked.
                    if msg.kind == "ticket":
                        port._reply(msg.payload, "busy", self.ticks)
                    continue
                port.on_message(msg, self.ticks)

    def run(self) -> Dict[int, FrontendRequest]:
        """Drive the fleet until every request completes or drops AND
        the transport drains (un-acked cancels would otherwise leak
        slots). Deterministic: chaos events, inbox delivery, dispatch,
        then one engine action on the alive replica furthest behind in
        virtual time (ties to lowest id). When no replica has work the
        plane jumps to the next scheduled event — a chaos entry or a
        transport delivery/retransmission — instead of spinning."""
        while self.queue or self.inflight or self.transport.busy():
            if self.max_ticks is not None and self.ticks > self.max_ticks:
                raise RuntimeError(
                    f"frontend exceeded max_ticks={self.max_ticks} with "
                    f"{len(self.queue)} queued / {len(self.inflight)} "
                    "in-flight requests — the plane is stalled"
                )
            for ev in self.schedule.pop(self.ticks, []):
                self._apply(ev)
            self._process_inbox()
            self.transport.pump(self.ticks)
            self._dispatch()
            self._deliver_replica_inboxes()
            rep = self._step_target()
            if rep is None:
                future = [s for s in self.schedule if s > self.ticks]
                t_net = self.transport.next_event_tick()
                if t_net is not None:
                    future.append(max(t_net, self.ticks + 1))
                if future:
                    # Whole fleet idle: jump to the next chaos event or
                    # transport event instead of spinning.
                    self.ticks = min(future)
                    continue
                if self.queue or self.inflight:
                    raise RuntimeError(
                        "frontend stranded: requests pending but no live "
                        "replica has capacity and no future fault events"
                    )
                break
            rep.step()
            self.ports[rep.id].flush(self.ticks)
            self.ticks += 1
        return dict(self.results)

    # -- reporting -----------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        lats = [fr.latency for fr in self.results.values() if fr.done]
        eng = [rep.engine.stats for rep in self.replicas]
        out = {
            "completed": sum(fr.done for fr in self.results.values()),
            "dropped": len(self.dropped),
            "retries": sum(fr.retries for fr in self.results.values()),
            "migrations": self.migrations,
            "ticket_rejects": self.ticket_rejects,
            "cancelled_copies": sum(s.cancelled_requests for s in eng),
            "preemptions": sum(s.preempted_requests for s in eng),
            "prefix_hits": sum(s.prefix_hits for s in eng),
            "generated_tokens": sum(s.generated_tokens for s in eng),
            "p50_latency": float(np.percentile(lats, 50)) if lats else np.nan,
            "p99_latency": float(np.percentile(lats, 99)) if lats else np.nan,
        }
        out.update(
            {f"transport_{k}": v for k, v in self.transport.stats().items()}
        )
        return out
