"""Slot-based KV/SSM cache pool, contiguous or paged.

The pool owns one device-resident cache pytree shaped for ``n_slots``
sequences of up to ``max_len`` tokens, built from ``model.cache_specs``
— so it works unchanged for every registered arch family (attention KV
rows, MLA latent rows, Mamba2/xLSTM recurrent states). Slot occupancy is
host-side bookkeeping; all device mutation goes through the spec-driven
slot helpers in ``repro.models.layers`` (``act_batch`` marks where the
slot axis lives in each leaf, which is NOT always axis 0 — stacked-layer
segments put "layers" first).

With ``block_size`` set, every cache leaf that carries a sequence axis
becomes a global BLOCK ARENA shared by all slots, and a ``BlockManager``
maps each slot's rows to arena blocks through a block table — the
serving twin of the paper's load adaptation: decode memory tracks LIVE
tokens instead of ``n_slots * max_len`` reserved stripes. Recurrent
conv/SSM/xLSTM state leaves (no sequence axis) keep their contiguous
per-slot layout behind the same API in either mode.

Public API contract: everything here is SPEC-DRIVEN. The pool never
inspects a model beyond ``cache_specs`` — each leaf's ``ParamSpec.axes``
says where the slot axis lives ("act_batch"), whether the leaf pages
("kv_blocks"), and what a reset writes (``init``). Adding an arch
family requires no pool changes, only correct specs. The only
model-specific knowledge in this file is the NULL-sink/alignment
convention shared with ``repro.models.attention``.

Invariants (tested in tests/test_serve.py):
  * a slot is in exactly one of {free, active};
  * ``positions[s]`` is the next cache write index of slot ``s``;
  * freeing resets bookkeeping immediately and lazily reuses device rows
    (the next prefill overwrites the whole slot); paged mode additionally
    returns the slot's blocks to the free pool INSTANTLY;
  * a block is owned by at most one slot; arena row 0 is the NULL sink
    (never allocated, absorbs masked-lane writes);
  * ``defrag()`` compacts active slots to the lowest indices, gathering
    only contiguous leaves — paged leaves never move (block tables are
    host arrays), so for pure-attention families it is a device no-op.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import NULL_BLOCK, round_kv_len
from repro.models.layers import (
    DTYPES,
    ParamSpec,
    batch_axis_of,
    is_paged_spec,
    slot_read,
    slot_reset,
    slot_take,
    slot_write,
)

__all__ = ["BlockManager", "SlotPool", "SlotSnapshot", "model_scoped_cache"]


@dataclasses.dataclass(frozen=True)
class SlotSnapshot:
    """One slot's cache state, detached from any pool — the unit of
    in-flight request migration between replicas.

    ``data`` mirrors the pool's spec tree: contiguous leaves (recurrent
    lanes, or KV rows of an unpaged pool) are batch-1 slices; paged
    leaves are the slot's OWNED ARENA BLOCKS gathered block-major along
    the ``kv_blocks`` axis (shape ``n_blocks`` on that axis — only the
    rows the slot actually wrote travel, not the whole arena). Restoring
    into another pool of the same geometry scatters those blocks into
    freshly allocated destination blocks: a block-table handoff, not a
    recompute."""

    data: Any                 # pytree matching the pool's spec tree
    position: int             # next cache write index of the slot
    n_blocks: int             # owned arena blocks captured (0 = unpaged)
    block_size: Optional[int]
    rows: int                 # per-slot row capacity (geometry check)


def model_scoped_cache(fn):
    """Memoize ``fn(model, *args)`` ON the model instance.

    A module-level ``lru_cache`` keyed on the model would pin the model
    (and every jitted closure tracing through it) alive for the life of
    the process; storing the memo in the model's own ``__dict__`` ties
    the cache — and its jit executables — to the model's lifetime, so
    dropping the last model reference frees everything (regression test:
    ``test_dropped_model_pool_ops_collectable``)."""
    slot_name = f"_memo_{fn.__name__}"

    @functools.wraps(fn)
    def wrapper(model, *args):
        cache = model.__dict__.setdefault(slot_name, {})
        if args not in cache:
            cache[args] = fn(model, *args)
        return cache[args]

    wrapper.cache_slot = slot_name
    return wrapper


@model_scoped_cache
def _pool_ops(model, n_slots: int, max_len: int,
              block_size: Optional[int], arena_blocks: int):
    """Jitted slot ops shared across every pool of the same geometry on
    the same model — per-instance jax.jit wrappers would re-trace for
    each new pool."""
    specs = model.cache_specs(
        n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
    )
    return (
        specs,
        jax.jit(lambda c, s: slot_read(c, specs, s)),
        jax.jit(lambda c, s, v: slot_write(c, specs, s, v)),
        jax.jit(lambda c, s: slot_reset(c, specs, s)),
        jax.jit(lambda c, p: slot_take(c, specs, p)),
    )


class BlockManager:
    """Host-side block allocator: one global arena of ``num_blocks``
    usable blocks (arena row 0 is the NULL sink) and one block table row
    per slot. Purely bookkeeping — device scatter/gather reads
    ``tables`` as data, so allocation never recompiles anything.

    Two-level discipline (what makes it both memory-proportional and
    deadlock-free without an eviction path):

      * **commit** — admission charges a slot's whole token budget
        against the arena (``sum(committed) <= num_blocks`` always), so
        a slot can ALWAYS grow to its budget: decode never stalls on
        blocks mid-flight;
      * **append** — blocks are physically allocated lazily, one block
        at a time, as rows are actually written. The used high-water
        therefore tracks LIVE tokens, not reserved budgets — the number
        an allocator would really need co-resident.
    """

    def __init__(self, n_slots: int, n_rows: int, block_size: int,
                 num_blocks: int):
        if n_rows % block_size:
            raise ValueError(
                f"block_size={block_size} must divide the (aligned) cache "
                f"rows {n_rows} so paged views match contiguous shapes"
            )
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.table_width = n_rows // block_size
        #: (n_slots, T) int32 arena indices; NULL_BLOCK marks unallocated.
        self.tables = np.full((n_slots, self.table_width), NULL_BLOCK, np.int32)
        # LIFO free list over ids 1..num_blocks (0 is the sink).
        self._free: List[int] = list(range(num_blocks, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._budget: List[int] = [0] * n_slots   # committed blocks per slot
        self.used_high_water = 0

    # -- accounting ----------------------------------------------------------
    @property
    def n_free_blocks(self) -> int:
        return len(self._free)

    @property
    def n_used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def n_committed_blocks(self) -> int:
        return sum(self._budget)

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(int(n_tokens), 0) / self.block_size)

    def can_commit(self, n_tokens: int) -> bool:
        """Admission test: the request's whole budget must fit beside
        every already-committed budget (worst-case accounting — this is
        what guarantees decode-time appends can never exhaust the
        arena), and inside one slot's table."""
        need = self.blocks_for(n_tokens)
        return (need <= self.table_width
                and self.n_committed_blocks + need <= self.num_blocks)

    # -- commit / append / free ----------------------------------------------
    def commit(self, slot: int, n_tokens: int) -> None:
        """Charge ``slot``'s lifetime token budget against the arena (no
        blocks move yet). Raises when over-committed — callers gate
        admission on :meth:`can_commit`."""
        need = self.blocks_for(n_tokens)
        if need > self.table_width:
            raise ValueError(
                f"{n_tokens} tokens need {need} blocks > table width "
                f"{self.table_width} (slot capacity)"
            )
        if self.n_committed_blocks - self._budget[slot] + need > self.num_blocks:
            raise ValueError(
                f"arena over-committed: budget {need} blocks on top of "
                f"{self.n_committed_blocks - self._budget[slot]} committed "
                f"(capacity {self.num_blocks})"
            )
        self._budget[slot] = max(self._budget[slot], need)

    def append(self, slot: int, n_rows: int) -> None:
        """Grow ``slot``'s table to physically cover ``n_rows`` rows
        (append-only; no-op when covered). Never exceeds the slot's
        committed budget — which also makes exhaustion impossible."""
        want = self.blocks_for(n_rows)
        owned = self._owned[slot]
        if want > self._budget[slot]:
            raise ValueError(
                f"slot {slot}: {n_rows} rows need {want} blocks > "
                f"committed budget {self._budget[slot]}"
            )
        while len(owned) < want:
            bid = self._free.pop()
            self.tables[slot, len(owned)] = bid
            owned.append(bid)
        self.used_high_water = max(self.used_high_water, self.n_used_blocks)

    def free(self, slot: int) -> None:
        """Return every block of ``slot`` to the pool instantly, release
        its budget commitment, and point its table at the NULL sink
        (stale rows are never read again: reads mask by length, and
        reallocation overwrites)."""
        owned = self._owned[slot]
        self._free.extend(reversed(owned))
        owned.clear()
        self._budget[slot] = 0
        self.tables[slot, :] = NULL_BLOCK

    def permute(self, order: np.ndarray) -> None:
        """Remap slot indices (pool defrag) — pure host bookkeeping."""
        self.tables = self.tables[order]
        self._owned = [self._owned[int(o)] for o in order]
        self._budget = [self._budget[int(o)] for o in order]

    def check(self) -> None:
        """Assert allocator invariants (test hook)."""
        seen: set = set()
        for slot, owned in enumerate(self._owned):
            assert len(owned) <= self._budget[slot], (
                f"slot {slot} owns {len(owned)} blocks over its budget"
            )
            assert list(self.tables[slot, : len(owned)]) == owned, (
                f"slot {slot} table/owned mismatch"
            )
            assert all(t == NULL_BLOCK for t in self.tables[slot, len(owned):]), (
                f"slot {slot} has table entries past its owned blocks"
            )
            for b in owned:
                assert NULL_BLOCK < b <= self.num_blocks, f"bad block id {b}"
                assert b not in seen, f"block {b} owned twice"
                seen.add(b)
        assert self.n_committed_blocks <= self.num_blocks, "over-committed"
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids in free list"
        assert free.isdisjoint(seen), "block both free and owned"
        assert free | seen == set(range(1, self.num_blocks + 1)), "leaked blocks"


class SlotPool:
    def __init__(
        self,
        model,
        n_slots: int,
        max_len: int,
        *,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
    ):
        """``block_size`` switches sequence-axis cache leaves to a paged
        arena of ``arena_blocks`` blocks (default: full capacity,
        ``n_slots * rows / block_size`` — undersize it to serve under an
        explicit memory budget with admit-by-budget queuing)."""
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_len = max_len
        self.rows = round_kv_len(max_len)   # aligned per-slot row capacity
        self.block_size = block_size
        self.paged = block_size is not None
        if self.paged:
            if arena_blocks is None:
                arena_blocks = n_slots * math.ceil(self.rows / block_size)
            self.manager: Optional[BlockManager] = BlockManager(
                n_slots, self.rows, block_size, arena_blocks
            )
        else:
            arena_blocks = 0
            self.manager = None
        self.specs, self._read, self._write, self._reset, self._take = _pool_ops(
            model, n_slots, max_len, block_size, arena_blocks
        )
        self.caches = model.blank_caches(
            n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
        )
        self._spec_leaves = jax.tree.leaves(
            self.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
        )
        self._any_contiguous = any(
            not is_paged_spec(s) for s in self._spec_leaves
        )
        # Host-side occupancy. Free slots are handed out lowest-index
        # first so the engine's active lanes stay dense without defrag.
        self.positions = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.owner: List[Optional[int]] = [None] * n_slots

    # -- occupancy -----------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    def active_mask(self) -> np.ndarray:
        return self.active.copy()

    def can_admit(self, n_tokens: int) -> bool:
        """Admission test: a free slot AND (paged) room to commit the
        request's whole token budget — commitment at admission is what
        lets decode grow blocks lazily without ever stalling on arena
        pressure mid-flight."""
        if self.n_free == 0:
            return False
        return not self.paged or self.manager.can_commit(n_tokens)

    def allocate(
        self, owner: Optional[int] = None, n_tokens: Optional[int] = None
    ) -> Optional[int]:
        """Claim the lowest free slot (or None when full / over-committed).
        Paged pools commit ``n_tokens`` rows of budget at admission;
        blocks are appended lazily as rows are written (:meth:`ensure_rows`)."""
        free = np.nonzero(~self.active)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        if self.paged:
            budget = self.rows if n_tokens is None else int(n_tokens)
            if not self.manager.can_commit(budget):
                return None
            self.manager.commit(slot, budget)
        self.active[slot] = True
        self.owner[slot] = owner
        self.positions[slot] = 0
        return slot

    def ensure_rows(self, slot: int, n_rows: int) -> None:
        """Lazily append blocks so ``slot`` physically covers ``n_rows``
        cache rows (no-op for contiguous pools and covered slots)."""
        if self.paged:
            self.manager.append(slot, n_rows)

    def free(self, slot: int) -> None:
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = False
        self.owner[slot] = None
        self.positions[slot] = 0
        if self.paged:
            self.manager.free(slot)

    # -- paged bookkeeping ---------------------------------------------------
    def tables_device(self, slot: Optional[int] = None) -> Optional[jax.Array]:
        """Block tables as device data — all slots (n_slots, T) for the
        decode tick, or one (1, T) row for a slot's prefill."""
        if not self.paged:
            return None
        t = self.manager.tables if slot is None else self.manager.tables[slot:slot + 1]
        return jnp.asarray(t)

    # -- memory accounting (benchmarks) --------------------------------------
    def kv_bytes_per_block(self) -> int:
        """Bytes one arena block occupies across every paged leaf
        (stacked-layer leaves count each layer's row)."""
        total = 0
        for s in self._spec_leaves:
            if is_paged_spec(s):
                n_arena = s.shape[s.axes.index("kv_blocks")]
                total += s.size // n_arena * np.dtype(DTYPES[s.dtype]).itemsize
        return total

    def kv_bytes_contiguous(self) -> int:
        """What the sequence-axis leaves would occupy as contiguous
        ``n_slots * rows`` stripes (the pre-paging layout) — the baseline
        every high-water measurement compares against."""
        if self.paged:
            per_block = self.kv_bytes_per_block()
            return per_block * (self.rows // self.block_size) * self.n_slots
        total = 0
        for s in self._spec_leaves:
            if "act_kv_seq" in s.axes:
                total += s.size * np.dtype(DTYPES[s.dtype]).itemsize
        return total

    def kv_bytes_high_water(self) -> int:
        """High-water mark of arena bytes actually reserved (+ the NULL
        sink block) — decode KV memory proportional to live tokens."""
        if not self.paged:
            return self.kv_bytes_contiguous()
        return (self.manager.used_high_water + 1) * self.kv_bytes_per_block()

    # -- device-side slot ops ------------------------------------------------
    def read_slot(self, slot: int):
        """Batch-1 cache pytree for one slot (chunked-prefill
        continuation); paged arena leaves pass through whole."""
        return self._read(self.caches, jnp.int32(slot))

    def write_slot(self, slot: int, slot_caches, position: int) -> None:
        """Install a batch-1 cache (a prefill result) into ``slot`` and
        record its next write position."""
        self.caches = self._write(self.caches, jnp.int32(slot), slot_caches)
        self.positions[slot] = position

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's contiguous device rows to the spec init
        values (zeros for KV rows, ones for the sLSTM normalizer, ...).
        Paged leaves are untouched — stale blocks are recycled lazily."""
        self.caches = self._reset(self.caches, jnp.int32(slot))
        self.positions[slot] = 0

    # -- migration (KV block handoff) ----------------------------------------
    def snapshot_slot(self, slot: int) -> SlotSnapshot:
        """Capture one active slot as a :class:`SlotSnapshot`: contiguous
        leaves slice out batch-1, paged leaves gather exactly the slot's
        owned blocks from the arena. The slot itself is untouched (the
        caller frees it after a successful handoff)."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        if self.paged:
            owned = list(self.manager._owned[slot])
            ids = jnp.asarray(owned, jnp.int32)
        else:
            owned, ids = [], None

        def snap(c, s):
            if is_paged_spec(s):
                return jnp.take(c, ids, axis=s.axes.index("kv_blocks"))
            return jax.lax.dynamic_slice_in_dim(
                c, slot, 1, axis=batch_axis_of(s)
            )

        data = jax.tree.map(
            snap, self.caches, self.specs,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        return SlotSnapshot(
            data=data,
            position=int(self.positions[slot]),
            n_blocks=len(owned),
            block_size=self.block_size,
            rows=self.rows,
        )

    def restore_slot(
        self, snap: SlotSnapshot, owner: Optional[int] = None,
        n_tokens: Optional[int] = None,
    ) -> Optional[int]:
        """Re-admit a migrated slot: allocate a slot (committing the
        request's remaining lifetime budget ``n_tokens``, paged pools),
        append destination blocks to cover the snapshot's rows, and
        scatter the snapshot's block contents into them; contiguous
        leaves write back with the usual batch-1 slice. Returns the slot
        index, or None when this pool cannot admit the request right now
        (no free slot / arena over-committed) — the caller requeues."""
        if snap.block_size != self.block_size or snap.rows != self.rows:
            raise ValueError(
                f"snapshot geometry (block_size={snap.block_size}, "
                f"rows={snap.rows}) does not match pool "
                f"(block_size={self.block_size}, rows={self.rows})"
            )
        budget = snap.position if n_tokens is None else int(n_tokens)
        if budget < snap.position:
            raise ValueError(
                f"budget {budget} tokens below snapshot position "
                f"{snap.position}"
            )
        if self.paged and self.manager.blocks_for(budget) < snap.n_blocks:
            raise ValueError(
                f"budget {budget} tokens ({self.manager.blocks_for(budget)} "
                f"blocks) cannot hold the snapshot's {snap.n_blocks} blocks"
            )
        slot = self.allocate(owner=owner, n_tokens=budget)
        if slot is None:
            return None
        if self.paged and snap.n_blocks:
            self.manager.append(slot, snap.n_blocks * self.block_size)
            dest_ids = jnp.asarray(
                self.manager._owned[slot][: snap.n_blocks], jnp.int32
            )
        else:
            dest_ids = None

        def rest(c, s, v):
            if is_paged_spec(s):
                if snap.n_blocks == 0:
                    return c
                ax = s.axes.index("kv_blocks")
                m = jnp.moveaxis(c, ax, 0)
                m = m.at[dest_ids].set(jnp.moveaxis(v, ax, 0).astype(m.dtype))
                return jnp.moveaxis(m, 0, ax)
            return jax.lax.dynamic_update_slice_in_dim(
                c, v.astype(c.dtype), slot, axis=batch_axis_of(s)
            )

        self.caches = jax.tree.map(
            rest, self.caches, self.specs, snap.data,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        self.positions[slot] = snap.position
        return slot

    def defrag(self) -> Dict[int, int]:
        """Compact active slots to the lowest indices (one gather over
        the CONTIGUOUS leaves; paged leaves only permute their host-side
        block tables, so attention-family pools defrag for free).
        Returns the {old_slot: new_slot} moves applied to live slots.
        NOTE: an engine holding per-slot state on top of this pool must
        remap it with the returned moves — use ``ServeEngine.defrag()``,
        not this, on a live engine."""
        order = np.concatenate(
            [np.nonzero(self.active)[0], np.nonzero(~self.active)[0]]
        ).astype(np.int32)
        moves = {int(old): new for new, old in enumerate(order) if int(old) != new}
        if not moves:
            return {}
        if self._any_contiguous:
            self.caches = self._take(self.caches, jnp.asarray(order))
        if self.paged:
            self.manager.permute(order)
        self.positions = self.positions[order]
        self.active = self.active[order]
        self.owner = [self.owner[int(old)] for old in order]
        return {old: new for old, new in moves.items() if self.active[new]}
