"""Slot-based KV/SSM cache pool.

The pool owns one device-resident cache pytree shaped for ``n_slots``
sequences of up to ``max_len`` tokens, built from ``model.cache_specs``
— so it works unchanged for every registered arch family (attention KV
rows, MLA latent rows, Mamba2/xLSTM recurrent states). Slot occupancy is
host-side bookkeeping; all device mutation goes through the spec-driven
slot helpers in ``repro.models.layers`` (``act_batch`` marks where the
slot axis lives in each leaf, which is NOT always axis 0 — stacked-layer
segments put "layers" first).

Invariants (tested in tests/test_serve.py):
  * a slot is in exactly one of {free, active};
  * ``positions[s]`` is the next cache write index of slot ``s``;
  * freeing resets bookkeeping immediately and lazily reuses device rows
    (the next prefill overwrites the whole slot);
  * ``defrag()`` compacts active slots to the lowest indices with one
    gather, preserving per-slot contents and positions.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import slot_read, slot_reset, slot_take, slot_write

__all__ = ["SlotPool"]


@functools.lru_cache(maxsize=None)
def _pool_ops(model, n_slots: int, max_len: int):
    """Jitted slot ops shared across every pool of the same geometry —
    per-instance jax.jit wrappers would re-trace for each new pool."""
    specs = model.cache_specs(n_slots, max_len)
    return (
        specs,
        jax.jit(lambda c, s: slot_read(c, specs, s)),
        jax.jit(lambda c, s, v: slot_write(c, specs, s, v)),
        jax.jit(lambda c, s: slot_reset(c, specs, s)),
        jax.jit(lambda c, p: slot_take(c, specs, p)),
    )


class SlotPool:
    def __init__(self, model, n_slots: int, max_len: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_len = max_len
        self.specs, self._read, self._write, self._reset, self._take = _pool_ops(
            model, n_slots, max_len
        )
        self.caches = model.blank_caches(n_slots, max_len)
        # Host-side occupancy. Free slots are handed out lowest-index
        # first so the engine's active lanes stay dense without defrag.
        self.positions = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.owner: List[Optional[int]] = [None] * n_slots

    # -- occupancy -----------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    def active_mask(self) -> np.ndarray:
        return self.active.copy()

    def allocate(self, owner: Optional[int] = None) -> Optional[int]:
        """Claim the lowest free slot (or None when full)."""
        free = np.nonzero(~self.active)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        self.active[slot] = True
        self.owner[slot] = owner
        self.positions[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = False
        self.owner[slot] = None
        self.positions[slot] = 0

    # -- device-side slot ops ------------------------------------------------
    def read_slot(self, slot: int):
        """Batch-1 cache pytree for one slot (chunked-prefill continuation)."""
        return self._read(self.caches, jnp.int32(slot))

    def write_slot(self, slot: int, slot_caches, position: int) -> None:
        """Install a batch-1 cache (a prefill result) into ``slot`` and
        record its next write position."""
        self.caches = self._write(self.caches, jnp.int32(slot), slot_caches)
        self.positions[slot] = position

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's device rows to the spec init values
        (zeros for KV rows, ones for the sLSTM normalizer, ...)."""
        self.caches = self._reset(self.caches, jnp.int32(slot))
        self.positions[slot] = 0

    def defrag(self) -> Dict[int, int]:
        """Compact active slots to the lowest indices (one gather over
        every leaf). Returns the {old_slot: new_slot} moves applied to
        live slots. NOTE: an engine holding per-slot state on top of
        this pool must remap it with the returned moves — use
        ``ServeEngine.defrag()``, not this, on a live engine."""
        order = np.concatenate(
            [np.nonzero(self.active)[0], np.nonzero(~self.active)[0]]
        ).astype(np.int32)
        moves = {int(old): new for new, old in enumerate(order) if int(old) != new}
        if not moves:
            return {}
        self.caches = self._take(self.caches, jnp.asarray(order))
        self.positions = self.positions[order]
        self.active = self.active[order]
        self.owner = [self.owner[int(old)] for old in order]
        return {old: new for old, new in moves.items() if self.active[new]}
