"""Slot-based KV/SSM cache pool, contiguous or paged.

The pool owns one device-resident cache pytree shaped for ``n_slots``
sequences of up to ``max_len`` tokens, built from ``model.cache_specs``
— so it works unchanged for every registered arch family (attention KV
rows, MLA latent rows, Mamba2/xLSTM recurrent states). Slot occupancy is
host-side bookkeeping; all device mutation goes through the spec-driven
slot helpers in ``repro.models.layers`` (``act_batch`` marks where the
slot axis lives in each leaf, which is NOT always axis 0 — stacked-layer
segments put "layers" first).

With ``block_size`` set, every cache leaf that carries a sequence axis
becomes a global BLOCK ARENA shared by all slots, and a ``BlockManager``
maps each slot's rows to arena blocks through a block table — the
serving twin of the paper's load adaptation: decode memory tracks LIVE
tokens instead of ``n_slots * max_len`` reserved stripes. Recurrent
conv/SSM/xLSTM state leaves (no sequence axis) keep their contiguous
per-slot layout behind the same API in either mode.

Public API contract: everything here is SPEC-DRIVEN. The pool never
inspects a model beyond ``cache_specs`` — each leaf's ``ParamSpec.axes``
says where the slot axis lives ("act_batch"), whether the leaf pages
("kv_blocks"), and what a reset writes (``init``). Adding an arch
family requires no pool changes, only correct specs. The only
model-specific knowledge in this file is the NULL-sink/alignment
convention shared with ``repro.models.attention``.

Invariants (tested in tests/test_serve.py):
  * a slot is in exactly one of {free, active};
  * ``positions[s]`` is the next cache write index of slot ``s``;
  * freeing resets bookkeeping immediately and lazily reuses device rows
    (the next prefill overwrites the whole slot); paged mode additionally
    returns the slot's blocks to the free pool INSTANTLY;
  * a block is owned by at most one slot; arena row 0 is the NULL sink
    (never allocated, absorbs masked-lane writes);
  * ``defrag()`` compacts active slots to the lowest indices, gathering
    only contiguous leaves — paged leaves never move (block tables are
    host arrays), so for pure-attention families it is a device no-op.

Copy-on-write prefix sharing (``prefix_sharing=True``, DESIGN.md §16):
every block carries a REFCOUNT = the number of slot tables referencing
it. A :class:`PrefixIndex` trie maps full-block prompt prefixes to
resident blocks so a new request ADOPTS a matching chain instead of
recomputing it (refcount++ per block, vLLM/TGI block-table idiom), and
any write into a block with refcount > 1 must FORK it first — a fresh
block, a device copy (``slot_block_copy``), and a table swap, so the
writer scatters into a private clone while readers keep the original.
Sharing replaces the commit-at-admission guarantee: ``append``/``fork``
can now raise :class:`ArenaExhausted`, and the ENGINE answers arena
pressure by preempt-and-requeue instead of queuing at admission.
Invariants (tested in tests/test_prefix.py, randomized):
  * refcount[b] == number of live table references to b, for every b;
  * a block written through a slot's table has refcount 1 (no block is
    doubly owned by writers — shared blocks are read-only until forked);
  * a freed block returns to the free list exactly once, when its LAST
    reference drops (free ∪ referenced == {1..num_blocks}, disjoint);
  * ``used_high_water`` tracks the max of UNIQUE live blocks — shared
    blocks count once, which is the whole memory win.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import NULL_BLOCK, round_kv_len
from repro.models.layers import (
    DTYPES,
    ParamSpec,
    batch_axis_of,
    is_paged_spec,
    slot_block_copy,
    slot_read,
    slot_reset,
    slot_take,
    slot_write,
)

__all__ = [
    "ArenaExhausted", "BlockManager", "PrefixIndex", "SlotPool",
    "SlotSnapshot", "model_scoped_cache",
]


class ArenaExhausted(RuntimeError):
    """A sharing-mode allocation (lazy append or copy-on-write fork)
    found the free list empty. Never raised in commit-at-admission mode
    — there the admission-time budget check makes exhaustion impossible.
    Under prefix sharing the engine catches this and preempts the
    cheapest lane (recompute-vs-hold priced by the CostModel) instead of
    stalling."""


@dataclasses.dataclass(frozen=True)
class SlotSnapshot:
    """One slot's cache state, detached from any pool — the unit of
    in-flight request migration between replicas.

    ``data`` mirrors the pool's spec tree: contiguous leaves (recurrent
    lanes, or KV rows of an unpaged pool) are batch-1 slices; paged
    leaves are the slot's OWNED ARENA BLOCKS gathered block-major along
    the ``kv_blocks`` axis (shape ``n_blocks`` on that axis — only the
    rows the slot actually wrote travel, not the whole arena). Restoring
    into another pool of the same geometry scatters those blocks into
    freshly allocated destination blocks: a block-table handoff, not a
    recompute."""

    data: Any                 # pytree matching the pool's spec tree
    position: int             # next cache write index of the slot
    n_blocks: int             # owned arena blocks captured (0 = unpaged)
    block_size: Optional[int]
    rows: int                 # per-slot row capacity (geometry check)


def model_scoped_cache(fn):
    """Memoize ``fn(model, *args)`` ON the model instance.

    A module-level ``lru_cache`` keyed on the model would pin the model
    (and every jitted closure tracing through it) alive for the life of
    the process; storing the memo in the model's own ``__dict__`` ties
    the cache — and its jit executables — to the model's lifetime, so
    dropping the last model reference frees everything (regression test:
    ``test_dropped_model_pool_ops_collectable``)."""
    slot_name = f"_memo_{fn.__name__}"

    @functools.wraps(fn)
    def wrapper(model, *args):
        cache = model.__dict__.setdefault(slot_name, {})
        if args not in cache:
            cache[args] = fn(model, *args)
        return cache[args]

    wrapper.cache_slot = slot_name
    return wrapper


@model_scoped_cache
def _pool_ops(model, n_slots: int, max_len: int,
              block_size: Optional[int], arena_blocks: int):
    """Jitted slot ops shared across every pool of the same geometry on
    the same model — per-instance jax.jit wrappers would re-trace for
    each new pool."""
    specs = model.cache_specs(
        n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
    )
    return (
        specs,
        jax.jit(lambda c, s: slot_read(c, specs, s)),
        jax.jit(lambda c, s, v: slot_write(c, specs, s, v)),
        jax.jit(lambda c, s: slot_reset(c, specs, s)),
        jax.jit(lambda c, p: slot_take(c, specs, p)),
        jax.jit(lambda c, s, d: slot_block_copy(c, specs, s, d)),
    )


class _TrieNode:
    __slots__ = ("key", "bid", "parent", "children")

    def __init__(self, key, bid, parent):
        self.key = key          # tuple of block_size tokens (root: None)
        self.bid = bid          # arena block holding these rows (root: None)
        self.parent = parent
        self.children: Dict[tuple, "_TrieNode"] = {}


class PrefixIndex:
    """Radix-style trie over FULL prompt blocks: each node is one
    ``block_size``-token chunk, its path from the root is the full token
    prefix, and its payload is the resident arena block holding exactly
    those rows. Consulted at admission: the longest root chain matching
    a new prompt is adopted into the request's block table (refcount++)
    instead of being recomputed.

    Only full PROMPT blocks are registered (generated tokens never are —
    they are private to their stream), and a node dies the moment its
    block's last reference drops (``forget``, driven by the pool's
    ``free``). Because adopters always take whole root chains, a live
    descendant implies live ancestors, so eviction only ever removes
    reachable leaves — the trie never dangles."""

    def __init__(self, block_size: int):
        self.block_size = block_size
        self.root = _TrieNode(None, None, None)
        self._by_bid: Dict[int, _TrieNode] = {}

    def __len__(self) -> int:
        return len(self._by_bid)

    def _chunks(self, tokens) -> List[tuple]:
        toks = [int(t) for t in tokens]
        bs = self.block_size
        return [tuple(toks[i: i + bs])
                for i in range(0, len(toks) - len(toks) % bs, bs)]

    def match(self, tokens) -> List[int]:
        """Block ids of the longest resident full-block prefix of
        ``tokens`` (root-down chain; possibly empty)."""
        node, bids = self.root, []
        for key in self._chunks(tokens):
            node = node.children.get(key)
            if node is None:
                break
            bids.append(node.bid)
        return bids

    def register(self, tokens, bids: Sequence[int]) -> int:
        """Record that ``bids[k]`` holds the k-th full block of
        ``tokens``. Chunks already present keep their incumbent block
        (two identical prompts racing through prefill both finish; the
        first registration wins and the loser's blocks stay private).
        Returns how many NEW nodes were created."""
        node, created = self.root, 0
        for key, bid in zip(self._chunks(tokens), bids):
            child = node.children.get(key)
            if child is None:
                child = _TrieNode(key, int(bid), node)
                node.children[key] = child
                self._by_bid[int(bid)] = child
                created += 1
            node = child
        return created

    def forget(self, bid: int) -> None:
        """Evict the node holding ``bid`` (called when the block's last
        reference drops and it returns to the free list)."""
        node = self._by_bid.pop(int(bid), None)
        if node is None:
            return
        if node.parent is not None and node.parent.children.get(node.key) is node:
            del node.parent.children[node.key]


class BlockManager:
    """Host-side block allocator: one global arena of ``num_blocks``
    usable blocks (arena row 0 is the NULL sink) and one block table row
    per slot. Purely bookkeeping — device scatter/gather reads
    ``tables`` as data, so allocation never recompiles anything.

    Two-level discipline (what makes it both memory-proportional and
    deadlock-free without an eviction path):

      * **commit** — admission charges a slot's whole token budget
        against the arena (``sum(committed) <= num_blocks`` always), so
        a slot can ALWAYS grow to its budget: decode never stalls on
        blocks mid-flight;
      * **append** — blocks are physically allocated lazily, one block
        at a time, as rows are actually written. The used high-water
        therefore tracks LIVE tokens, not reserved budgets — the number
        an allocator would really need co-resident.

    With ``sharing=True`` the arena-level half of the commit guarantee
    is traded away for copy-on-write prefix sharing: ``adopt`` maps a
    slot's table onto already-resident blocks (refcount++), ``fork``
    clones a shared block into the writer's table before a write, and
    ``append``/``fork`` raise :class:`ArenaExhausted` instead of being
    deadlock-free by construction — the engine's preempt-and-requeue
    path is the eviction valve. ``refcount`` is maintained in BOTH modes
    (legacy blocks simply never exceed 1), so the conservation oracle
    ``sum(refcounts) == live table references`` holds fleet-wide.
    """

    def __init__(self, n_slots: int, n_rows: int, block_size: int,
                 num_blocks: int, *, sharing: bool = False):
        if n_rows % block_size:
            raise ValueError(
                f"block_size={block_size} must divide the (aligned) cache "
                f"rows {n_rows} so paged views match contiguous shapes"
            )
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.sharing = bool(sharing)
        self.table_width = n_rows // block_size
        #: (n_slots, T) int32 arena indices; NULL_BLOCK marks unallocated.
        self.tables = np.full((n_slots, self.table_width), NULL_BLOCK, np.int32)
        # LIFO free list over ids 1..num_blocks (0 is the sink).
        self._free: List[int] = list(range(num_blocks, 0, -1))
        #: per-slot referenced block ids in table order. Under sharing a
        #: block adopted by several slots appears in each slot's list —
        #: "referenced", not exclusively owned.
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._budget: List[int] = [0] * n_slots   # committed blocks per slot
        #: refcount[bid] = number of live table references to bid.
        self.refcount = np.zeros(num_blocks + 1, np.int32)
        self.used_high_water = 0

    # -- accounting ----------------------------------------------------------
    @property
    def n_free_blocks(self) -> int:
        return len(self._free)

    @property
    def n_used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def n_committed_blocks(self) -> int:
        return sum(self._budget)

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(max(int(n_tokens), 0) / self.block_size)

    def can_commit(self, n_tokens: int) -> bool:
        """Admission test: the request's whole budget must fit beside
        every already-committed budget (worst-case accounting — this is
        what guarantees decode-time appends can never exhaust the
        arena), and inside one slot's table. Under sharing the arena-sum
        half is dropped — admission is priced by the engine against live
        free blocks, with preemption as the pressure valve."""
        need = self.blocks_for(n_tokens)
        if need > self.table_width:
            return False
        return self.sharing or self.n_committed_blocks + need <= self.num_blocks

    # -- commit / append / free ----------------------------------------------
    def commit(self, slot: int, n_tokens: int) -> None:
        """Charge ``slot``'s lifetime token budget against the arena (no
        blocks move yet). Raises when over-committed — callers gate
        admission on :meth:`can_commit`. Sharing mode keeps the budget
        as a per-slot table-width cap only (the arena-sum guarantee is
        what sharing trades for multiplied occupancy)."""
        need = self.blocks_for(n_tokens)
        if need > self.table_width:
            raise ValueError(
                f"{n_tokens} tokens need {need} blocks > table width "
                f"{self.table_width} (slot capacity)"
            )
        if (not self.sharing and self.n_committed_blocks - self._budget[slot]
                + need > self.num_blocks):
            raise ValueError(
                f"arena over-committed: budget {need} blocks on top of "
                f"{self.n_committed_blocks - self._budget[slot]} committed "
                f"(capacity {self.num_blocks})"
            )
        self._budget[slot] = max(self._budget[slot], need)

    def append(self, slot: int, n_rows: int) -> None:
        """Grow ``slot``'s table to physically cover ``n_rows`` rows
        (append-only; no-op when covered). Never exceeds the slot's
        committed budget. In commit-at-admission mode exhaustion is
        impossible by construction; under sharing an empty free list
        raises :class:`ArenaExhausted` for the engine's preemption
        path."""
        want = self.blocks_for(n_rows)
        owned = self._owned[slot]
        if want > self._budget[slot]:
            raise ValueError(
                f"slot {slot}: {n_rows} rows need {want} blocks > "
                f"committed budget {self._budget[slot]}"
            )
        # try/finally: exhaustion mid-append keeps partial progress (the
        # engine preempts and retries), so high-water must cover it too.
        try:
            while len(owned) < want:
                if not self._free:
                    raise ArenaExhausted(
                        f"slot {slot} needs {want - len(owned)} more block(s) "
                        f"but the arena free list is empty"
                    )
                bid = self._free.pop()
                self.tables[slot, len(owned)] = bid
                owned.append(bid)
                self.refcount[bid] = 1
        finally:
            self.used_high_water = max(self.used_high_water, self.n_used_blocks)

    # -- sharing: adopt / fork / writability ---------------------------------
    def adopt(self, slot: int, bids: Sequence[int]) -> None:
        """Map an empty slot's table prefix onto already-resident blocks
        (a trie match at admission): refcount++ per block, no device
        work. The adopted chain must fit the slot's committed budget —
        the prompt prefix always does."""
        if not self.sharing:
            raise ValueError("adopt requires a sharing-mode manager")
        owned = self._owned[slot]
        if owned:
            raise ValueError(f"slot {slot} must adopt before any append")
        if len(bids) > self._budget[slot]:
            raise ValueError(
                f"adopting {len(bids)} blocks exceeds slot {slot}'s "
                f"budget {self._budget[slot]}"
            )
        for bid in bids:
            bid = int(bid)
            if not (NULL_BLOCK < bid <= self.num_blocks) or self.refcount[bid] < 1:
                raise ValueError(f"cannot adopt non-resident block {bid}")
            self.tables[slot, len(owned)] = bid
            owned.append(bid)
            self.refcount[bid] += 1

    def is_shared(self, bid: int) -> bool:
        return self.refcount[int(bid)] > 1

    def fork(self, slot: int, block_index: int) -> Tuple[int, int]:
        """Copy-on-write: give ``slot`` a private clone of the shared
        block at ``block_index`` of its table. Pops a fresh block (raises
        :class:`ArenaExhausted` when none is free), swaps the table
        entry, and moves one reference count over. Returns
        ``(src_bid, dst_bid)`` so the pool can device-copy the rows —
        the host swap MUST be paired with that copy before any write."""
        if not self.sharing:
            raise ValueError("fork requires a sharing-mode manager")
        owned = self._owned[slot]
        if not (0 <= block_index < len(owned)):
            raise ValueError(f"slot {slot} has no block at {block_index}")
        src = owned[block_index]
        if self.refcount[src] < 2:
            raise ValueError(f"block {src} is not shared — nothing to fork")
        if not self._free:
            raise ArenaExhausted(
                f"fork of shared block {src} needs a free block"
            )
        dst = self._free.pop()
        self.refcount[src] -= 1
        self.refcount[dst] = 1
        self.tables[slot, block_index] = dst
        owned[block_index] = dst
        self.used_high_water = max(self.used_high_water, self.n_used_blocks)
        return src, dst

    def free(self, slot: int) -> List[int]:
        """Drop every reference ``slot`` holds, release its budget, and
        point its table at the NULL sink. A block returns to the free
        list exactly when its LAST reference drops; the released ids are
        returned so the pool can evict them from the prefix index.
        (Stale rows are never read again: reads mask by length, and
        reallocation overwrites.)"""
        owned = self._owned[slot]
        released: List[int] = []
        for bid in reversed(owned):
            self.refcount[bid] -= 1
            if self.refcount[bid] == 0:
                self._free.append(bid)
                released.append(bid)
        owned.clear()
        self._budget[slot] = 0
        self.tables[slot, :] = NULL_BLOCK
        return released

    def permute(self, order: np.ndarray) -> None:
        """Remap slot indices (pool defrag) — pure host bookkeeping."""
        self.tables = self.tables[order]
        self._owned = [self._owned[int(o)] for o in order]
        self._budget = [self._budget[int(o)] for o in order]

    def audit(self) -> List[str]:
        """Every allocator-invariant violation as a message list (empty
        = healthy). Non-throwing twin of :meth:`check` so the chaos
        harness can use it as an oracle (block conservation under
        sharing) without turning bookkeeping bugs into crashes."""
        errs: List[str] = []
        refs: Dict[int, int] = {}
        for slot, owned in enumerate(self._owned):
            if len(owned) > self._budget[slot]:
                errs.append(f"slot {slot} holds {len(owned)} blocks over "
                            f"its budget {self._budget[slot]}")
            if list(self.tables[slot, : len(owned)]) != owned:
                errs.append(f"slot {slot} table/owned mismatch")
            if any(t != NULL_BLOCK for t in self.tables[slot, len(owned):]):
                errs.append(f"slot {slot} has table entries past its "
                            "referenced blocks")
            for b in owned:
                if not (NULL_BLOCK < b <= self.num_blocks):
                    errs.append(f"bad block id {b}")
                    continue
                refs[b] = refs.get(b, 0) + 1
        for b, n in refs.items():
            if int(self.refcount[b]) != n:
                errs.append(f"block {b}: refcount {int(self.refcount[b])} "
                            f"!= {n} live table references")
            if not self.sharing and n > 1:
                errs.append(f"block {b} owned twice")
        free = set(self._free)
        if len(free) != len(self._free):
            errs.append("duplicate ids in free list")
        if not free.isdisjoint(refs):
            errs.append("block both free and referenced")
        if free | set(refs) != set(range(1, self.num_blocks + 1)):
            errs.append("leaked blocks: free + referenced != capacity")
        for b in self._free:
            if int(self.refcount[b]) != 0:
                errs.append(f"free block {b} carries refcount "
                            f"{int(self.refcount[b])}")
        if not self.sharing and self.n_committed_blocks > self.num_blocks:
            errs.append("over-committed")
        if self.n_used_blocks != len(refs):
            errs.append(f"used {self.n_used_blocks} != {len(refs)} unique "
                        "live blocks")
        if self.used_high_water < self.n_used_blocks:
            errs.append("high-water below current unique live blocks")
        return errs

    def check(self) -> None:
        """Assert allocator invariants (test hook)."""
        errs = self.audit()
        assert not errs, "; ".join(errs)


class SlotPool:
    def __init__(
        self,
        model,
        n_slots: int,
        max_len: int,
        *,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
        prefix_sharing: bool = False,
    ):
        """``block_size`` switches sequence-axis cache leaves to a paged
        arena of ``arena_blocks`` blocks (default: full capacity,
        ``n_slots * rows / block_size`` — undersize it to serve under an
        explicit memory budget with admit-by-budget queuing).

        ``prefix_sharing`` (paged only) turns on copy-on-write block
        sharing: a :class:`PrefixIndex` trie over resident full prompt
        blocks lets new requests adopt matching chains at admission, and
        :meth:`ensure_writable` forks shared blocks before any write.
        Allocation can then raise :class:`ArenaExhausted` — callers must
        run a preemption policy (the engine does)."""
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if prefix_sharing and block_size is None:
            raise ValueError("prefix_sharing requires a paged pool "
                             "(block_size set)")
        self.n_slots = n_slots
        self.max_len = max_len
        self.rows = round_kv_len(max_len)   # aligned per-slot row capacity
        self.block_size = block_size
        self.paged = block_size is not None
        self.prefix_sharing = bool(prefix_sharing)
        if self.paged:
            if arena_blocks is None:
                arena_blocks = n_slots * math.ceil(self.rows / block_size)
            self.manager: Optional[BlockManager] = BlockManager(
                n_slots, self.rows, block_size, arena_blocks,
                sharing=self.prefix_sharing,
            )
        else:
            arena_blocks = 0
            self.manager = None
        self.prefix: Optional[PrefixIndex] = (
            PrefixIndex(block_size) if self.prefix_sharing else None
        )
        (self.specs, self._read, self._write, self._reset, self._take,
         self._copy) = _pool_ops(
            model, n_slots, max_len, block_size, arena_blocks
        )
        self.caches = model.blank_caches(
            n_slots, max_len, block_size=block_size, num_blocks=arena_blocks
        )
        self._spec_leaves = jax.tree.leaves(
            self.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
        )
        self._any_contiguous = any(
            not is_paged_spec(s) for s in self._spec_leaves
        )
        # Host-side occupancy. Free slots are handed out lowest-index
        # first so the engine's active lanes stay dense without defrag.
        self.positions = np.zeros(n_slots, np.int32)
        self.active = np.zeros(n_slots, bool)
        self.owner: List[Optional[int]] = [None] * n_slots

    # -- occupancy -----------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_free(self) -> int:
        return self.n_slots - self.n_active

    def active_mask(self) -> np.ndarray:
        return self.active.copy()

    def can_admit(self, n_tokens: int) -> bool:
        """Admission test: a free slot AND (paged) room to commit the
        request's whole token budget — commitment at admission is what
        lets decode grow blocks lazily without ever stalling on arena
        pressure mid-flight."""
        if self.n_free == 0:
            return False
        return not self.paged or self.manager.can_commit(n_tokens)

    def allocate(
        self, owner: Optional[int] = None, n_tokens: Optional[int] = None
    ) -> Optional[int]:
        """Claim the lowest free slot (or None when full / over-committed).
        Paged pools commit ``n_tokens`` rows of budget at admission;
        blocks are appended lazily as rows are written (:meth:`ensure_rows`)."""
        free = np.nonzero(~self.active)[0]
        if free.size == 0:
            return None
        slot = int(free[0])
        if self.paged:
            budget = self.rows if n_tokens is None else int(n_tokens)
            if not self.manager.can_commit(budget):
                return None
            self.manager.commit(slot, budget)
        self.active[slot] = True
        self.owner[slot] = owner
        self.positions[slot] = 0
        return slot

    def ensure_rows(self, slot: int, n_rows: int) -> None:
        """Lazily append blocks so ``slot`` physically covers ``n_rows``
        cache rows (no-op for contiguous pools and covered slots)."""
        if self.paged:
            self.manager.append(slot, n_rows)

    def free(self, slot: int) -> None:
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        self.active[slot] = False
        self.owner[slot] = None
        self.positions[slot] = 0
        if self.paged:
            released = self.manager.free(slot)
            if self.prefix is not None:
                for bid in released:
                    self.prefix.forget(bid)

    # -- prefix sharing (copy-on-write) --------------------------------------
    def adopt_prefix(self, slot: int, prompt) -> int:
        """Map ``slot``'s table onto the longest resident full-block
        prefix of ``prompt`` (refcount++, zero device work). Returns the
        number of cache ROWS adopted — the engine skips prefill compute
        for exactly those rows.

        Returns 0 for pools with ANY contiguous leaf: recurrent state
        (xLSTM/Mamba2 lanes) is a running function of every token, so a
        mid-stream block chain cannot stand in for the skipped compute —
        those families keep preemption but not sharing."""
        if self.prefix is None or self._any_contiguous:
            return 0
        bids = self.prefix.match(prompt)
        if not bids:
            return 0
        self.manager.adopt(slot, bids)
        rows = len(bids) * self.block_size
        self.positions[slot] = rows
        return rows

    def register_prefix(self, slot: int, prompt) -> int:
        """Publish ``slot``'s full PROMPT blocks into the trie once its
        prefill completed (generated tokens stay private). No-op for
        non-sharing pools and recurrent hybrids. Returns new trie nodes."""
        if self.prefix is None or self._any_contiguous:
            return 0
        n_full = len(prompt) // self.block_size
        owned = self.manager._owned[slot][:n_full]
        return self.prefix.register(prompt, owned)

    def match_resident(self, prompt, exclude_slot: Optional[int] = None) -> int:
        """Rows of ``prompt`` that would still be trie-resident if
        ``exclude_slot`` dropped its references — what a preempted
        request could re-adopt on replay, used by the engine to price
        recompute-from-longest-prefix. The chain is cut at the first
        block that would die with the excluded slot."""
        if self.prefix is None or self._any_contiguous:
            return 0
        excl: List[int] = ([] if exclude_slot is None
                           else self.manager._owned[exclude_slot])
        rows = 0
        for bid in self.prefix.match(prompt):
            survives = int(self.manager.refcount[bid])
            survives -= excl.count(bid)
            if survives < 1:
                break
            rows += self.block_size
        return rows

    def ensure_writable(self, slot: int, row_start: int, row_end: int) -> None:
        """Copy-on-write gate: fork every SHARED block backing rows
        ``[row_start, row_end)`` of ``slot`` into private clones (host
        table swap + device block copy) so the upcoming scatter cannot
        be observed by other sharers. Cheap host no-op when nothing in
        range is shared. May raise :class:`ArenaExhausted`."""
        if self.prefix is None or row_end <= row_start:
            return
        mgr = self.manager
        owned = mgr._owned[slot]
        lo = row_start // self.block_size
        hi = min((row_end - 1) // self.block_size, len(owned) - 1)
        for idx in range(lo, hi + 1):
            if mgr.refcount[owned[idx]] > 1:
                src, dst = mgr.fork(slot, idx)
                self.caches = self._copy(
                    self.caches, jnp.int32(src), jnp.int32(dst)
                )

    # -- paged bookkeeping ---------------------------------------------------
    def tables_device(self, slot: Optional[int] = None) -> Optional[jax.Array]:
        """Block tables as device data — all slots (n_slots, T) for the
        decode tick, or one (1, T) row for a slot's prefill."""
        if not self.paged:
            return None
        t = self.manager.tables if slot is None else self.manager.tables[slot:slot + 1]
        return jnp.asarray(t)

    # -- memory accounting (benchmarks) --------------------------------------
    def kv_bytes_per_block(self) -> int:
        """Bytes one arena block occupies across every paged leaf
        (stacked-layer leaves count each layer's row)."""
        total = 0
        for s in self._spec_leaves:
            if is_paged_spec(s):
                n_arena = s.shape[s.axes.index("kv_blocks")]
                total += s.size // n_arena * np.dtype(DTYPES[s.dtype]).itemsize
        return total

    def kv_bytes_contiguous(self) -> int:
        """What the sequence-axis leaves would occupy as contiguous
        ``n_slots * rows`` stripes (the pre-paging layout) — the baseline
        every high-water measurement compares against."""
        if self.paged:
            per_block = self.kv_bytes_per_block()
            return per_block * (self.rows // self.block_size) * self.n_slots
        total = 0
        for s in self._spec_leaves:
            if "act_kv_seq" in s.axes:
                total += s.size * np.dtype(DTYPES[s.dtype]).itemsize
        return total

    def kv_bytes_high_water(self) -> int:
        """High-water mark of arena bytes actually reserved (+ the NULL
        sink block) — decode KV memory proportional to live tokens."""
        if not self.paged:
            return self.kv_bytes_contiguous()
        return (self.manager.used_high_water + 1) * self.kv_bytes_per_block()

    # -- device-side slot ops ------------------------------------------------
    def read_slot(self, slot: int):
        """Batch-1 cache pytree for one slot (chunked-prefill
        continuation); paged arena leaves pass through whole."""
        return self._read(self.caches, jnp.int32(slot))

    def write_slot(self, slot: int, slot_caches, position: int) -> None:
        """Install a batch-1 cache (a prefill result) into ``slot`` and
        record its next write position."""
        self.caches = self._write(self.caches, jnp.int32(slot), slot_caches)
        self.positions[slot] = position

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's contiguous device rows to the spec init
        values (zeros for KV rows, ones for the sLSTM normalizer, ...).
        Paged leaves are untouched — stale blocks are recycled lazily."""
        self.caches = self._reset(self.caches, jnp.int32(slot))
        self.positions[slot] = 0

    # -- migration (KV block handoff) ----------------------------------------
    def snapshot_slot(self, slot: int) -> SlotSnapshot:
        """Capture one active slot as a :class:`SlotSnapshot`: contiguous
        leaves slice out batch-1, paged leaves gather exactly the slot's
        owned blocks from the arena. The slot itself is untouched (the
        caller frees it after a successful handoff)."""
        if not self.active[slot]:
            raise ValueError(f"slot {slot} is not active")
        if self.paged:
            owned = list(self.manager._owned[slot])
            ids = jnp.asarray(owned, jnp.int32)
        else:
            owned, ids = [], None

        def snap(c, s):
            if is_paged_spec(s):
                return jnp.take(c, ids, axis=s.axes.index("kv_blocks"))
            return jax.lax.dynamic_slice_in_dim(
                c, slot, 1, axis=batch_axis_of(s)
            )

        data = jax.tree.map(
            snap, self.caches, self.specs,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        return SlotSnapshot(
            data=data,
            position=int(self.positions[slot]),
            n_blocks=len(owned),
            block_size=self.block_size,
            rows=self.rows,
        )

    def restore_slot(
        self, snap: SlotSnapshot, owner: Optional[int] = None,
        n_tokens: Optional[int] = None,
    ) -> Optional[int]:
        """Re-admit a migrated slot: allocate a slot (committing the
        request's remaining lifetime budget ``n_tokens``, paged pools),
        append destination blocks to cover the snapshot's rows, and
        scatter the snapshot's block contents into them; contiguous
        leaves write back with the usual batch-1 slice. Returns the slot
        index, or None when this pool cannot admit the request right now
        (no free slot / arena over-committed) — the caller requeues."""
        if snap.block_size != self.block_size or snap.rows != self.rows:
            raise ValueError(
                f"snapshot geometry (block_size={snap.block_size}, "
                f"rows={snap.rows}) does not match pool "
                f"(block_size={self.block_size}, rows={self.rows})"
            )
        budget = snap.position if n_tokens is None else int(n_tokens)
        if budget < snap.position:
            raise ValueError(
                f"budget {budget} tokens below snapshot position "
                f"{snap.position}"
            )
        if self.paged and self.manager.blocks_for(budget) < snap.n_blocks:
            raise ValueError(
                f"budget {budget} tokens ({self.manager.blocks_for(budget)} "
                f"blocks) cannot hold the snapshot's {snap.n_blocks} blocks"
            )
        slot = self.allocate(owner=owner, n_tokens=budget)
        if slot is None:
            return None
        if self.paged and snap.n_blocks:
            try:
                self.manager.append(slot, snap.n_blocks * self.block_size)
            except ArenaExhausted:
                # Sharing-mode arena too full to land the migration right
                # now — report "busy" (None) like a full pool; the caller
                # requeues and local preemption will open space.
                self.free(slot)
                return None
            dest_ids = jnp.asarray(
                self.manager._owned[slot][: snap.n_blocks], jnp.int32
            )
        else:
            dest_ids = None

        def rest(c, s, v):
            if is_paged_spec(s):
                if snap.n_blocks == 0:
                    return c
                ax = s.axes.index("kv_blocks")
                m = jnp.moveaxis(c, ax, 0)
                m = m.at[dest_ids].set(jnp.moveaxis(v, ax, 0).astype(m.dtype))
                return jnp.moveaxis(m, 0, ax)
            return jax.lax.dynamic_update_slice_in_dim(
                c, v.astype(c.dtype), slot, axis=batch_axis_of(s)
            )

        self.caches = jax.tree.map(
            rest, self.caches, self.specs, snap.data,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        self.positions[slot] = snap.position
        return slot

    def defrag(self) -> Dict[int, int]:
        """Compact active slots to the lowest indices (one gather over
        the CONTIGUOUS leaves; paged leaves only permute their host-side
        block tables, so attention-family pools defrag for free).
        Returns the {old_slot: new_slot} moves applied to live slots.
        NOTE: an engine holding per-slot state on top of this pool must
        remap it with the returned moves — use ``ServeEngine.defrag()``,
        not this, on a live engine."""
        order = np.concatenate(
            [np.nonzero(self.active)[0], np.nonzero(~self.active)[0]]
        ).astype(np.int32)
        moves = {int(old): new for new, old in enumerate(order) if int(old) != new}
        if not moves:
            return {}
        if self._any_contiguous:
            self.caches = self._take(self.caches, jnp.asarray(order))
        if self.paged:
            self.manager.permute(order)
        self.positions = self.positions[order]
        self.active = self.active[order]
        self.owner = [self.owner[int(old)] for old in order]
        return {old: new for old, new in moves.items() if self.active[new]}
