"""One fleet member: a ServeEngine plus the fault surface chaos drives.

A replica is an independent ``ServeEngine`` (own scheduler, own virtual
clock, own slot pool / paged arena) wearing the same fault model the
training runtime's straggler simulator applies to workers: it can FAIL
(drop out of the fleet, losing every in-flight request), run SLOW (every
engine action's virtual cost scales by a factor — a degraded node, not a
dead one), and REJOIN (come back empty and healthy at the fleet's
current time frontier). The frontend injects these from the shared
``repro.runtime.faults.FaultEvent`` schedule and reacts only to what it
can observe — completions stop arriving, response times inflate — never
to the schedule itself (same oracle-free discipline as the training
loop's elastic failover).

Public API contract: a replica owns TIME and LIVENESS, nothing about
requests — submission, hedging, retry, and migration policy live in
``serve.frontend``. ``fail()`` tears down local state and returns the
cancelled requests so the frontend can harvest their partial streams
(greedy decode is deterministic, so every copy's partial output is a
prefix of the same stream and the longest one seeds the retry).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.obs import Observability

from .engine import ServeEngine, TicketIntegrityError
from .scheduler import CostModel, EventClock, Request, Scheduler
from .transport import FE, Chunk, Expired, TicketReply, WireMessage, replica_endpoint

__all__ = ["FaultyClock", "Replica", "ReplicaPort"]


class FaultyClock(EventClock):
    """EventClock whose compute actions cost ``slow`` times the model's
    price (1.0 = nominal). Only COMPUTE advances scale — ``advance_to``
    (idle jump to an arrival / rejoin frontier) moves wall position, not
    work, so it stays unscaled."""

    def __init__(self, cost: Optional[CostModel] = None):
        super().__init__(cost)
        self.slow = 1.0

    def advance_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.prefill(n_tokens) * self.slow

    def advance_decode(self) -> None:
        self.now += self.cost.decode() * self.slow

    def advance_draft_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.draft_prefill(n_tokens) * self.slow

    def advance_spec_round(
        self, draft_ticks: int, verify_tokens: int, replay: bool = False
    ) -> None:
        self.now += self.cost.spec_round(draft_ticks, verify_tokens, replay) * self.slow


class Replica:
    """An engine + id + liveness. Builds its own ``FaultyClock`` and
    ``Scheduler`` so fleet members never share mutable state."""

    def __init__(
        self,
        replica_id: int,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        cost: Optional[CostModel] = None,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
        prefix_sharing: bool = False,
        prefill_chunk: Optional[int] = None,
        decode_per_prefill: int = 4,
        prefill_bucket: int = 16,
        obs: Optional[Observability] = None,
    ):
        self.id = int(replica_id)
        self.clock = FaultyClock(cost)
        sched = Scheduler(
            n_slots,
            prefill_chunk=prefill_chunk,
            decode_per_prefill=decode_per_prefill,
            clock=self.clock,
        )
        self.engine = ServeEngine(
            model, params,
            n_slots=n_slots, max_len=max_len, scheduler=sched,
            prefill_bucket=prefill_bucket,
            block_size=block_size, arena_blocks=arena_blocks,
            prefix_sharing=prefix_sharing,
            obs=obs, obs_name=f"replica {self.id}",
        )
        self.alive = True

    def _fault_instant(self, kind: str, **args) -> None:
        """Mark a fault-surface transition on this replica's trace lane."""
        eng = self.engine
        if eng.obs.enabled:
            eng._tr.instant(
                "fault", eng.pid, self.clock.now,
                args={"kind": kind, "replica": self.id, **args},
            )
            eng.obs.metrics.counter(f"replica.fault.{kind}").inc()

    @property
    def now(self) -> float:
        return self.clock.now

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    def step(self) -> str:
        if not self.alive:
            raise RuntimeError(f"replica {self.id} is down")
        return self.engine.step()

    # -- fault surface -------------------------------------------------------
    def set_slow(self, factor: float) -> None:
        """Degrade (or restore, factor=1.0) this replica's speed."""
        if factor <= 0:
            raise ValueError("slow factor must be > 0")
        self.clock.slow = float(factor)
        self._fault_instant("slow", factor=float(factor))

    def fail(self) -> List[Request]:
        """Hard failure: every in-flight request dies with the node.
        Local slots and blocks are torn down (the engine survives to be
        rejoined later — a process restart with warm weights). Returns
        the cancelled requests, partial token streams intact, so the
        caller can requeue from the longest prefix."""
        self.alive = False
        self._fault_instant("fail")
        eng = self.engine
        out = []
        for rid in eng.live_rids():
            req = eng.request(rid)
            eng.cancel(rid, reason="cancelled")
            out.append(req)
        return out

    def rejoin(self, now: float) -> None:
        """Come back empty, healthy, and AT THE FLEET'S TIME FRONTIER —
        a rejoining node does not get to serve from the past."""
        self.alive = True
        self.clock.slow = 1.0
        self.clock.advance_to(now)
        self._fault_instant("rejoin")


class ReplicaPort:
    """The replica-side endpoint of the frontend↔replica message
    protocol (``serve.transport``): translates wire messages into engine
    calls and engine progress back into wire messages. The frontend
    NEVER sees replica-local rids — every copy is addressed by its
    ``(gid, attempt)`` key, which is also the receiver's idempotency
    key.

    Inbound: ``Submit`` admits a copy (stamping its absolute deadline on
    THIS replica's clock from the carried budget), ``Cancel`` tears one
    down (a tombstone in ``cancelled`` also blocks a late-retransmitted
    Submit from admitting a zombie after its cancel already landed), and
    ``Ticket`` imports a migration ticket — integrity verification
    happens inside ``import_request``; a :class:`TicketIntegrityError`
    becomes a ``corrupt`` reply (reject-and-requeue), pool backpressure
    a ``busy`` reply.

    Outbound (``flush`` after every engine step): new tokens ship as
    position-addressed ``Chunk`` messages — idempotent and order-free by
    construction, so duplicated/reordered delivery rewrites the same
    cells — with the terminal chunk carrying the stream length and the
    replica-local service time; a deadline expiry ships the full partial
    prefix as ``Expired``.

    ``admission_log`` is harness-facing monitoring, NOT control: it
    records every engine admission keyed by copy, append-only across
    ``reset()``, so the chaos harness can check the exactly-once-effects
    oracle (with transport dedup disabled, a duplicated Submit really
    does admit twice — that is the violation the oracle exists to
    catch)."""

    def __init__(self, replica: Replica, transport):
        self.rep = replica
        self.transport = transport
        self.ep = replica_endpoint(replica.id)
        self.rid_by_key: Dict[Tuple[int, int], int] = {}
        self.cursor: Dict[Tuple[int, int], int] = {}
        self.t_start: Dict[Tuple[int, int], float] = {}
        self.closed: Set[Tuple[int, int]] = set()
        self.cancelled: Set[Tuple[int, int]] = set()
        self.admission_log: List[Tuple[int, int]] = []

    # -- inbound -------------------------------------------------------------
    def on_message(self, msg: WireMessage, tick: int) -> None:
        if msg.kind == "submit":
            self._on_submit(msg.payload, tick)
        elif msg.kind == "cancel":
            self._on_cancel(msg.payload)
        elif msg.kind == "ticket":
            self._on_ticket(msg.payload, tick)
        else:
            raise ValueError(f"replica port got unexpected {msg.kind!r}")

    def _on_submit(self, p, tick: int) -> None:
        key = (p.gid, p.attempt)
        if key in self.cancelled:
            return      # cancel overtook a (re)transmitted submit
        if self.transport.dedup and key in self.rid_by_key:
            return      # idempotent receiver (transport dedup's backstop)
        self.admission_log.append(key)
        now = self.rep.clock.now
        t0 = max(now, float(p.arrival))
        dl = None if p.deadline_budget is None else t0 + p.deadline_budget
        rid = self.rep.engine.submit(
            p.prompt, p.max_new_tokens, arrival=p.arrival, deadline=dl
        )
        self.rid_by_key[key] = rid
        self.cursor[key] = 0
        self.t_start[key] = t0
        self.closed.discard(key)

    def _on_cancel(self, p) -> None:
        key = (p.gid, p.attempt)
        self.cancelled.add(key)
        rid = self.rid_by_key.get(key)
        if rid is not None:
            self.rep.engine.cancel(rid)     # no-op if already terminal
            self.closed.add(key)

    def _on_ticket(self, p, tick: int) -> None:
        key = (p.gid, p.attempt)
        if key in self.cancelled:
            self._reply(p, "busy", tick)
            return
        if self.transport.dedup and key in self.rid_by_key:
            self._reply(p, "ok", tick)      # duplicate ticket: re-ack
            return
        now = self.rep.clock.now
        adj = p.ticket
        if p.remaining_deadline is not None:
            # Absolute deadlines are clock-local: restamp from the
            # carried remaining budget (excluded from the integrity
            # seal for exactly this reason).
            adj = dataclasses.replace(adj, deadline=now + p.remaining_deadline)
        try:
            rid = self.rep.engine.import_request(adj)
        except TicketIntegrityError:
            self._reply(p, "corrupt", tick)
            return
        if rid is None:
            self._reply(p, "busy", tick)
            return
        self.admission_log.append(key)
        self.rid_by_key[key] = rid
        self.cursor[key] = len(p.ticket.tokens)
        self.t_start[key] = now - float(p.elapsed)
        self.closed.discard(key)
        self._reply(p, "ok", tick)

    def _reply(self, p, status: str, tick: int) -> None:
        self.transport.send(
            self.ep, FE, TicketReply(p.gid, p.attempt, status), tick
        )

    # -- outbound ------------------------------------------------------------
    def flush(self, tick: int) -> None:
        """Ship engine progress since the last flush: one Chunk per copy
        with new tokens (terminal chunk carries total + elapsed), one
        Expired per deadline-cancelled copy. Local teardown paths
        (explicit cancel, migration export) close silently — their
        initiator already knows."""
        eng = self.rep.engine
        for key, rid in list(self.rid_by_key.items()):
            if key in self.closed:
                continue
            req = eng.request(rid)
            if req.cancelled:
                if req.cancel_reason == "deadline":
                    self.transport.send(
                        self.ep, FE,
                        Expired(key[0], key[1], tuple(req.tokens)), tick,
                    )
                self.closed.add(key)
                continue
            cur, n = self.cursor[key], len(req.tokens)
            done = req.t_done is not None
            if n > cur:
                elapsed = (
                    self.rep.clock.now - self.t_start[key] if done else None
                )
                self.transport.send(
                    self.ep, FE,
                    Chunk(key[0], key[1], cur, tuple(req.tokens[cur:n]),
                          done=done, total=(n if done else None),
                          elapsed=elapsed),
                    tick,
                )
                self.cursor[key] = n
                if done:
                    self.closed.add(key)

    # -- introspection (co-located control plane: drain/fail paths) ----------
    def rid_of(self, gid: int, attempt: int) -> Optional[int]:
        return self.rid_by_key.get((gid, attempt))

    def elapsed_of(self, gid: int, attempt: int) -> float:
        return self.rep.clock.now - self.t_start[(gid, attempt)]

    def forget(self, gid: int, attempt: int) -> None:
        """Drop a copy's mapping after a co-located teardown (export)."""
        self.closed.add((gid, attempt))

    def reset(self) -> None:
        """Process death / rejoin: protocol state dies with the process.
        ``admission_log`` survives — it is the harness's god's-eye
        monitor, not process memory."""
        self.rid_by_key.clear()
        self.cursor.clear()
        self.t_start.clear()
        self.closed.clear()
        self.cancelled.clear()
