"""One fleet member: a ServeEngine plus the fault surface chaos drives.

A replica is an independent ``ServeEngine`` (own scheduler, own virtual
clock, own slot pool / paged arena) wearing the same fault model the
training runtime's straggler simulator applies to workers: it can FAIL
(drop out of the fleet, losing every in-flight request), run SLOW (every
engine action's virtual cost scales by a factor — a degraded node, not a
dead one), and REJOIN (come back empty and healthy at the fleet's
current time frontier). The frontend injects these from the shared
``repro.runtime.faults.FaultEvent`` schedule and reacts only to what it
can observe — completions stop arriving, response times inflate — never
to the schedule itself (same oracle-free discipline as the training
loop's elastic failover).

Public API contract: a replica owns TIME and LIVENESS, nothing about
requests — submission, hedging, retry, and migration policy live in
``serve.frontend``. ``fail()`` tears down local state and returns the
cancelled requests so the frontend can harvest their partial streams
(greedy decode is deterministic, so every copy's partial output is a
prefix of the same stream and the longest one seeds the retry).
"""

from __future__ import annotations

from typing import List, Optional

from repro.obs import Observability

from .engine import ServeEngine
from .scheduler import CostModel, EventClock, Request, Scheduler

__all__ = ["FaultyClock", "Replica"]


class FaultyClock(EventClock):
    """EventClock whose compute actions cost ``slow`` times the model's
    price (1.0 = nominal). Only COMPUTE advances scale — ``advance_to``
    (idle jump to an arrival / rejoin frontier) moves wall position, not
    work, so it stays unscaled."""

    def __init__(self, cost: Optional[CostModel] = None):
        super().__init__(cost)
        self.slow = 1.0

    def advance_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.prefill(n_tokens) * self.slow

    def advance_decode(self) -> None:
        self.now += self.cost.decode() * self.slow

    def advance_draft_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.draft_prefill(n_tokens) * self.slow

    def advance_spec_round(
        self, draft_ticks: int, verify_tokens: int, replay: bool = False
    ) -> None:
        self.now += self.cost.spec_round(draft_ticks, verify_tokens, replay) * self.slow


class Replica:
    """An engine + id + liveness. Builds its own ``FaultyClock`` and
    ``Scheduler`` so fleet members never share mutable state."""

    def __init__(
        self,
        replica_id: int,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        cost: Optional[CostModel] = None,
        block_size: Optional[int] = None,
        arena_blocks: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        decode_per_prefill: int = 4,
        prefill_bucket: int = 16,
        obs: Optional[Observability] = None,
    ):
        self.id = int(replica_id)
        self.clock = FaultyClock(cost)
        sched = Scheduler(
            n_slots,
            prefill_chunk=prefill_chunk,
            decode_per_prefill=decode_per_prefill,
            clock=self.clock,
        )
        self.engine = ServeEngine(
            model, params,
            n_slots=n_slots, max_len=max_len, scheduler=sched,
            prefill_bucket=prefill_bucket,
            block_size=block_size, arena_blocks=arena_blocks,
            obs=obs, obs_name=f"replica {self.id}",
        )
        self.alive = True

    def _fault_instant(self, kind: str, **args) -> None:
        """Mark a fault-surface transition on this replica's trace lane."""
        eng = self.engine
        if eng.obs.enabled:
            eng._tr.instant(
                "fault", eng.pid, self.clock.now,
                args={"kind": kind, "replica": self.id, **args},
            )
            eng.obs.metrics.counter(f"replica.fault.{kind}").inc()

    @property
    def now(self) -> float:
        return self.clock.now

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    def step(self) -> str:
        if not self.alive:
            raise RuntimeError(f"replica {self.id} is down")
        return self.engine.step()

    # -- fault surface -------------------------------------------------------
    def set_slow(self, factor: float) -> None:
        """Degrade (or restore, factor=1.0) this replica's speed."""
        if factor <= 0:
            raise ValueError("slow factor must be > 0")
        self.clock.slow = float(factor)
        self._fault_instant("slow", factor=float(factor))

    def fail(self) -> List[Request]:
        """Hard failure: every in-flight request dies with the node.
        Local slots and blocks are torn down (the engine survives to be
        rejoined later — a process restart with warm weights). Returns
        the cancelled requests, partial token streams intact, so the
        caller can requeue from the longest prefix."""
        self.alive = False
        self._fault_instant("fail")
        eng = self.engine
        out = []
        for rid in eng.live_rids():
            req = eng.request(rid)
            eng.cancel(rid, reason="cancelled")
            out.append(req)
        return out

    def rejoin(self, now: float) -> None:
        """Come back empty, healthy, and AT THE FLEET'S TIME FRONTIER —
        a rejoining node does not get to serve from the past."""
        self.alive = True
        self.clock.slow = 1.0
        self.clock.advance_to(now)
        self._fault_instant("rejoin")
