"""Multi-replica hedged dispatch priced by order statistics.

The paper prices every scheduling decision with the expected k-th order
statistic ``mu_{k:n}(beta)`` of random worker response times. Hedged
inference dispatch is the same decision at serving scale: send one
request to ``n_h`` replicas at once, keep the fastest ``k`` responses
(k=1 for plain generation, k>1 for quorum/verification schemes), cancel
the losers. Each extra replica buys latency through the order-statistic
tail ``H(n, k)`` but costs duplicated compute, so the router minimizes

    cost(n) = mu_{k:n}(beta) * slowdown(chosen n) + c_replica * n

by brute force over the feasible fan-outs — ``expected_kth`` makes the
latency term exact for both of the paper's delay models. Per-replica
speed estimates come from the same EWMA ``StragglerTracker`` the
training runtime uses for demotion; replicas the tracker marks slow stop
being chosen, which is the serving analogue of dropping a persistent
straggler from ``n``.

``ReplicaSet`` is the ground-truth simulator (hidden per-replica speed
factors over a ``repro.core.delay_models`` base model); the router only
ever sees observed response times.

Public API contract: MODEL-AGNOSTIC — the router prices opaque
request/response latencies and never sees tokens, caches, or arch
families. Its two dependencies are the paper-math layer
(``repro.core.order_stats.expected_kth`` over a
``repro.core.delay_models`` model) and the training-side EWMA
``StragglerTracker``; the same pricing seam is reused by
``serve.speculative.SpecController.choose_hedged`` for speculative
verify fan-outs.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.order_stats import expected_kth
from repro.runtime.telemetry import StragglerTracker

__all__ = ["HedgePlan", "DispatchOutcome", "ReplicaSet", "HedgedRouter"]


@dataclasses.dataclass(frozen=True)
class HedgePlan:
    n_h: int                      # hedge fan-out
    k: int                        # responses to wait for
    replicas: Tuple[int, ...]     # chosen replica ids (fastest-estimated first)
    expected_latency: float       # mu_{k:n} scaled by the subset's slowdown
    expected_cost: float          # latency + c_replica * n_h


@dataclasses.dataclass(frozen=True)
class DispatchOutcome:
    plan: HedgePlan
    completion_time: float        # k-th fastest response time
    completed: Tuple[int, ...]    # replicas whose responses were used
    cancelled: Tuple[int, ...]    # hedged losers, cancelled at completion


class ReplicaSet:
    """Ground truth for simulation: response time = base-model draw times
    a fixed per-replica speed factor (1.0 = nominal, 3.0 = straggler)."""

    def __init__(self, delay_model, speed_factors: Sequence[float], seed: int = 0):
        self.model = delay_model
        self.speed = np.asarray(speed_factors, np.float64)
        if np.any(self.speed <= 0):
            raise ValueError("speed factors must be > 0")
        self.rng = np.random.default_rng(seed)

    @property
    def n_replicas(self) -> int:
        return int(self.speed.size)

    def sample(self, replicas: Sequence[int], beta: float = 1.0) -> np.ndarray:
        base = self.model.sample(self.rng, len(replicas), beta)
        return base * self.speed[np.asarray(replicas, int)]


class HedgedRouter:
    def __init__(
        self,
        delay_model,
        n_replicas: int,
        *,
        quorum: int = 1,
        cost_per_replica: float = 0.0,
        slots_per_replica: int = 1,
        n_max: Optional[int] = None,
        ewma_alpha: float = 0.1,
        warmup: int = 8,
    ):
        if not (1 <= quorum <= n_replicas):
            raise ValueError("need 1 <= quorum <= n_replicas")
        self.model = delay_model
        self.n_replicas = n_replicas
        self.quorum = quorum
        self.cost_per_replica = cost_per_replica
        self.slots_per_replica = slots_per_replica
        self.n_max = n_max or n_replicas
        self.tracker = StragglerTracker(n_replicas, alpha=ewma_alpha, warmup=warmup)
        self.inflight = np.zeros(n_replicas, np.int64)

    # -- pricing -------------------------------------------------------------
    def _slowdowns(self) -> np.ndarray:
        """Per-replica slowdown estimates (1.0 until telemetry warms up)."""
        if int(self.tracker.rounds.max(initial=0)) < self.tracker.warmup:
            return np.ones(self.n_replicas)
        s = self.tracker.slowdown()
        return np.where(np.isfinite(s) & (s > 0), s, 1.0)

    def available(self) -> List[int]:
        return [
            r for r in range(self.n_replicas)
            if self.inflight[r] < self.slots_per_replica
        ]

    def hedge_cost(self, n: int, beta: float = 1.0, scale: float = 1.0) -> float:
        """Priced cost of fan-out ``n``: expected k-th order statistic of
        the response times plus the duplicated-compute charge."""
        k = min(self.quorum, n)
        return expected_kth(self.model, n, k, beta) * scale + self.cost_per_replica * n

    def choose_hedge(self, beta: float = 1.0) -> Optional[HedgePlan]:
        """Brute-force minimization of ``hedge_cost`` over feasible
        fan-outs, on the fastest-estimated available replicas."""
        slow = self._slowdowns()
        avail = sorted(self.available(), key=lambda r: (slow[r], r))
        if len(avail) < self.quorum:
            return None
        best: Optional[HedgePlan] = None
        for n in range(self.quorum, min(len(avail), self.n_max) + 1):
            subset = avail[:n]
            scale = float(np.mean(slow[subset]))
            k = min(self.quorum, n)
            lat = expected_kth(self.model, n, k, beta) * scale
            cost = lat + self.cost_per_replica * n
            if best is None or cost < best.expected_cost:
                best = HedgePlan(n, k, tuple(subset), lat, cost)
        return best

    # -- dispatch lifecycle --------------------------------------------------
    def dispatch(
        self,
        replica_set: ReplicaSet,
        beta: float = 1.0,
        *,
        auto_complete: bool = True,
    ) -> Optional[DispatchOutcome]:
        """Hedge one request. Occupies one slot on each chosen replica;
        with ``auto_complete=False`` the caller owns releasing them via
        ``complete(outcome)`` (concurrent in-flight hedges)."""
        plan = self.choose_hedge(beta)
        if plan is None:
            return None
        replicas = np.asarray(plan.replicas, int)
        times = replica_set.sample(replicas, beta)
        self.inflight[replicas] += 1
        order = np.argsort(times, kind="stable")
        completed = tuple(int(r) for r in replicas[order[: plan.k]])
        cancelled = tuple(int(r) for r in replicas[order[plan.k :]])
        outcome = DispatchOutcome(
            plan, float(times[order[plan.k - 1]]), completed, cancelled
        )
        # Telemetry sees only the responses that actually arrived —
        # cancelled losers are censored, never observed.
        obs = np.zeros(self.n_replicas)
        alive = np.zeros(self.n_replicas, bool)
        obs[list(completed)] = times[order[: plan.k]]
        alive[list(completed)] = True
        self.tracker.observe(obs, alive)
        if auto_complete:
            self.complete(outcome)
        return outcome

    def complete(self, outcome: DispatchOutcome) -> None:
        """Winner responded: release the winner's slot AND every hedged
        loser's (cancellation is what makes hedging affordable)."""
        for r in outcome.completed + outcome.cancelled:
            if self.inflight[r] <= 0:
                raise ValueError(f"replica {r} has no in-flight work")
            self.inflight[r] -= 1
