"""Multi-replica hedged dispatch priced by order statistics.

The paper prices every scheduling decision with the expected k-th order
statistic ``mu_{k:n}(beta)`` of random worker response times. Hedged
inference dispatch is the same decision at serving scale: send one
request to ``n_h`` replicas at once, keep the fastest ``k`` responses
(k=1 for plain generation, k>1 for quorum/verification schemes), cancel
the losers. Each extra replica buys latency through the order-statistic
tail ``H(n, k)`` but costs duplicated compute, so the router minimizes

    cost(n) = mu_{k:n}(beta) * slowdown(chosen n) + c_replica * n

by brute force over the feasible fan-outs — ``expected_kth`` makes the
latency term exact for both of the paper's delay models. Per-replica
speed estimates come from the same EWMA ``StragglerTracker`` the
training runtime uses for demotion; replicas the tracker marks slow stop
being chosen, which is the serving analogue of dropping a persistent
straggler from ``n``.

``ReplicaSet`` is the ground-truth simulator (hidden per-replica speed
factors over a ``repro.core.delay_models`` base model); the router only
ever sees observed response times.

Public API contract: MODEL-AGNOSTIC — the router prices opaque
request/response latencies and never sees tokens, caches, or arch
families. Its two dependencies are the paper-math layer
(``repro.core.order_stats.expected_kth`` over a
``repro.core.delay_models`` model) and the training-side EWMA
``StragglerTracker``; the same pricing seam is reused by
``serve.speculative.SpecController.choose_hedged`` for speculative
verify fan-outs.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.order_stats import expected_kth
from repro.obs import NULL_OBS, Observability
from repro.runtime.telemetry import StragglerTracker

__all__ = ["HedgePlan", "DispatchOutcome", "ReplicaSet", "HedgedRouter"]


@dataclasses.dataclass(frozen=True)
class HedgePlan:
    n_h: int                      # hedge fan-out
    k: int                        # responses to wait for
    replicas: Tuple[int, ...]     # chosen replica ids (fastest-estimated first)
    expected_latency: float       # mu_{k:n} scaled by the subset's slowdown
    expected_cost: float          # latency + c_replica * n_h


@dataclasses.dataclass(frozen=True)
class DispatchOutcome:
    plan: HedgePlan
    completion_time: float        # k-th fastest response time
    completed: Tuple[int, ...]    # replicas whose responses were used
    cancelled: Tuple[int, ...]    # hedged losers, cancelled at completion


class ReplicaSet:
    """Ground truth for simulation: response time = base-model draw times
    a fixed per-replica speed factor (1.0 = nominal, 3.0 = straggler)."""

    def __init__(self, delay_model, speed_factors: Sequence[float], seed: int = 0):
        self.model = delay_model
        self.speed = np.asarray(speed_factors, np.float64)
        if np.any(self.speed <= 0):
            raise ValueError("speed factors must be > 0")
        self.rng = np.random.default_rng(seed)

    @property
    def n_replicas(self) -> int:
        return int(self.speed.size)

    def sample(self, replicas: Sequence[int], beta: float = 1.0) -> np.ndarray:
        base = self.model.sample(self.rng, len(replicas), beta)
        return base * self.speed[np.asarray(replicas, int)]


class HedgedRouter:
    def __init__(
        self,
        delay_model,
        n_replicas: int,
        *,
        quorum: int = 1,
        cost_per_replica: float = 0.0,
        slots_per_replica: int = 1,
        n_max: Optional[int] = None,
        ewma_alpha: float = 0.1,
        warmup: int = 8,
        slow_cap: float = 1e6,
        obs: Optional[Observability] = None,
    ):
        if not (1 <= quorum <= n_replicas):
            raise ValueError("need 1 <= quorum <= n_replicas")
        self.model = delay_model
        self.n_replicas = n_replicas
        self.quorum = quorum
        self.cost_per_replica = cost_per_replica
        self.slots_per_replica = slots_per_replica
        self.n_max = n_max or n_replicas
        self.obs = obs or NULL_OBS
        self.tracker = StragglerTracker(
            n_replicas, alpha=ewma_alpha, warmup=warmup,
            metrics=self.obs.metrics if self.obs.enabled else None,
        )
        self._last_plan_key = None    # decision-log dedup (reprices only)
        self.inflight = np.zeros(n_replicas, np.int64)
        self.alive = np.ones(n_replicas, bool)
        #: finite stand-in for an unbounded censored estimate (a replica
        #: whose every interaction timed out): priced effectively last,
        #: but a later successful observation can still pull it back.
        self.slow_cap = slow_cap

    # -- fleet membership ----------------------------------------------------
    @property
    def n_alive(self) -> int:
        return int(self.alive.sum())

    def mark_failed(self, r: int) -> None:
        """Take a replica out of the fleet: it stops being a dispatch
        target and the quorum degrades to the shrunken fleet (pricing
        re-runs over whoever is left instead of stalling)."""
        self.alive[r] = False

    def mark_joined(self, r: int) -> None:
        """A replica (re)joins healthy. Its telemetry history is RESET:
        stale pre-failure estimates must not price it (a replica that
        was slow before dying may come back healthy — and one that was
        fast may come back cold). With zero rounds it is priced at the
        neutral prior 1.0 and, thanks to the tracker's per-worker
        first-observation seeding, its first real response time lands as
        its estimate directly — it is never read as infinitely fast while
        an EWMA crawls up from zero (the training-side PR 6 bug, mirrored
        here)."""
        self.alive[r] = True
        self.tracker.reset_worker(r)

    # -- pricing -------------------------------------------------------------
    def slowdowns(self) -> np.ndarray:
        """Public view of the per-replica slowdown estimates (see
        ``_slowdowns``) — the transport prices its retransmission
        timeouts from this, so retry backoff and hedged dispatch work
        from the SAME censored-telemetry picture of the fleet."""
        return self._slowdowns()

    def _slowdowns(self) -> np.ndarray:
        """Per-replica slowdown estimates.

        Fleet-wide cold start prices everyone at 1.0 until ``warmup``
        rounds of telemetry exist. Past that, each replica is priced from
        its OWN state: a finite censoring-corrected estimate where one
        exists; the neutral prior 1.0 for a replica with no history yet
        (fresh or just rejoined — per-worker seeding means its first
        observation will replace the prior wholesale); and ``slow_cap``
        for a replica whose history is all censoring (every interaction
        expired — only lower bounds known, so it prices last)."""
        if int(self.tracker.rounds.max(initial=0)) < self.tracker.warmup:
            return np.ones(self.n_replicas)
        s = self.tracker.slowdown()
        out = np.ones(self.n_replicas)
        seen = np.isfinite(s) & (s > 0)
        out[seen] = s[seen]
        unbounded = (self.tracker.rounds > 0) & (self.tracker.wins == 0)
        out[unbounded] = self.slow_cap
        return out

    def available(self) -> List[int]:
        return [
            r for r in range(self.n_replicas)
            if self.alive[r] and self.inflight[r] < self.slots_per_replica
        ]

    def hedge_cost(self, n: int, beta: float = 1.0, scale: float = 1.0) -> float:
        """Priced cost of fan-out ``n``: expected k-th order statistic of
        the response times plus the duplicated-compute charge."""
        k = min(self.quorum, n)
        return expected_kth(self.model, n, k, beta) * scale + self.cost_per_replica * n

    def choose_hedge(self, beta: float = 1.0) -> Optional[HedgePlan]:
        """Brute-force minimization of ``hedge_cost`` over feasible
        fan-outs, on the fastest-estimated available replicas.

        Degraded fleets re-price rather than stall: the required quorum
        is clamped to the number of ALIVE replicas, so losing replicas
        shrinks k (and the feasible fan-outs) instead of wedging the
        frontend. Busy-but-alive replicas still gate normally — a full
        fleet with too few free slots returns None and the caller
        retries after completions free capacity."""
        slow = self._slowdowns()
        avail = sorted(self.available(), key=lambda r: (slow[r], r))
        k_cap = min(self.quorum, max(self.n_alive, 1))
        if len(avail) < k_cap:
            return None
        best: Optional[HedgePlan] = None
        for n in range(k_cap, min(len(avail), self.n_max) + 1):
            subset = avail[:n]
            scale = float(np.mean(slow[subset]))
            k = min(self.quorum, n)
            lat = expected_kth(self.model, n, k, beta) * scale
            cost = lat + self.cost_per_replica * n
            if best is None or cost < best.expected_cost:
                best = HedgePlan(n, k, tuple(subset), lat, cost)
        if best is not None and self.obs.enabled:
            key = (best.n_h, best.k, best.replicas)
            if key != self._last_plan_key:
                # A reprice: the chosen fan-out / quorum / replica subset
                # changed since the last dispatch.
                self._last_plan_key = key
                self.obs.decisions.record(
                    "serve.hedge",
                    {"n_h": int(best.n_h), "k": int(best.k),
                     "replicas": list(best.replicas)},
                    {"slowdowns": [round(float(s), 6) for s in slow],
                     "n_alive": self.n_alive, "beta": float(beta),
                     "expected_latency": round(best.expected_latency, 9)},
                )
        return best

    # -- dispatch lifecycle --------------------------------------------------
    def begin(self, plan: HedgePlan) -> None:
        """Occupy one slot on each replica of a chosen plan. The caller
        owns releasing them — via ``complete(outcome)`` once the hedge
        resolves, or ``release(r)`` one at a time (e.g. a replica dies
        mid-request and its copy is torn down before any outcome
        exists)."""
        for r in plan.replicas:
            self.inflight[r] += 1

    def release(self, r: int) -> None:
        """Release a single replica's slot (early loser cancellation or
        replica death — cases where no ``DispatchOutcome`` applies)."""
        if self.inflight[r] <= 0:
            raise ValueError(f"replica {r} has no in-flight work")
        self.inflight[r] -= 1

    def occupy(self, r: int) -> None:
        """Occupy a single replica's slot outside a plan (a migrated
        request landing on a new replica)."""
        self.inflight[r] += 1

    def record(
        self,
        times: np.ndarray,
        participants: Sequence[int],
        observed: Optional[Sequence[int]] = None,
        censor_level: Optional[float] = None,
    ) -> None:
        """Feed one hedge's resolution to the tracker.

        ``times`` is dense over the fleet; only ``participants`` (the
        replicas this hedge actually touched) are eligible — censoring a
        loser must not count a round against replicas that never saw the
        request. With ``censor_level`` set, participants NOT in
        ``observed`` are recorded as censored at that level (the hedged
        losers: all we learn is "slower than the winner"/"slower than
        the deadline")."""
        part = np.zeros(self.n_replicas, bool)
        part[list(participants)] = True
        if censor_level is None:
            self.tracker.observe(np.asarray(times, np.float64), part)
        else:
            obs_mask = np.zeros(self.n_replicas, bool)
            if observed is not None:
                obs_mask[list(observed)] = True
            self.tracker.observe(
                np.asarray(times, np.float64), part,
                observed=obs_mask, censor_level=censor_level,
            )

    def dispatch(
        self,
        replica_set: ReplicaSet,
        beta: float = 1.0,
        *,
        auto_complete: bool = True,
    ) -> Optional[DispatchOutcome]:
        """Hedge one request. Occupies one slot on each chosen replica;
        with ``auto_complete=False`` the caller owns releasing them via
        ``complete(outcome)`` (concurrent in-flight hedges)."""
        plan = self.choose_hedge(beta)
        if plan is None:
            return None
        replicas = np.asarray(plan.replicas, int)
        times = replica_set.sample(replicas, beta)
        self.begin(plan)
        order = np.argsort(times, kind="stable")
        completed = tuple(int(r) for r in replicas[order[: plan.k]])
        cancelled = tuple(int(r) for r in replicas[order[plan.k :]])
        outcome = DispatchOutcome(
            plan, float(times[order[plan.k - 1]]), completed, cancelled
        )
        # Telemetry sees only the responses that actually arrived —
        # cancelled losers are censored, never observed.
        dense = np.zeros(self.n_replicas)
        dense[list(completed)] = times[order[: plan.k]]
        self.record(dense, completed)
        if auto_complete:
            self.complete(outcome)
        return outcome

    def complete(self, outcome: DispatchOutcome) -> None:
        """Winner responded: release the winner's slot AND every hedged
        loser's (cancellation is what makes hedging affordable)."""
        for r in outcome.completed + outcome.cancelled:
            if self.inflight[r] <= 0:
                raise ValueError(f"replica {r} has no in-flight work")
            self.inflight[r] -= 1
