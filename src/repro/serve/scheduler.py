"""Admission + prefill/decode interleaving with a deterministic event clock.

The engine's control loop is intentionally sequential and replayable:
every tick the scheduler picks ONE action — admit-and-prefill a waiting
request (possibly one chunk of it), run a decode tick over the whole
slot pool, or idle until the next arrival. Virtual time advances by a
linear cost model per action, so latency distributions are exact
functions of the workload (no wall-clock noise in tests or CI), while
the engine separately measures wall time for throughput.

The interleave policy bounds tail latency the same way the paper bounds
iteration time: a long prompt is chopped into ``prefill_chunk``-token
pieces, and between consecutive prefill actions at least
``decode_per_prefill`` decode ticks run whenever sequences are active —
so a 32k-token admission can't stall every in-flight request's
inter-token latency by more than one chunk's cost. Speculation rounds
pay that debt by the tokens they commit (``on_spec_round``), so
multi-token verifies never starve admissions.

Public API contract: pure host logic, MODEL-AGNOSTIC by construction —
nothing here touches arrays or specs. The engine reports what ran
(``on_prefill_chunk``/``on_decode_tick``/``on_spec_round``/...) and the
scheduler prices it with ``CostModel`` and picks the next action; any
engine honoring that callback protocol (including the static-batching
baseline and tests driving the scheduler directly) gets deterministic,
replayable virtual time.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.obs import NULL_OBS, Observability

__all__ = ["Request", "CostModel", "EventClock", "Scheduler", "next_bucket"]


def next_bucket(n: int, base: int = 16) -> int:
    """Smallest power-of-two multiple of ``base`` >= n (prefill shape
    bucketing: a handful of compiles cover every prompt length)."""
    b = base
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    arrival: float = 0.0
    #: absolute virtual-time deadline (None = no deadline). Set by the
    #: caller at submit, or stamped by the scheduler at admission when it
    #: was built with ``deadline_ticks``. An unfinished request past its
    #: deadline is cancelled: slot and blocks freed, the partial output
    #: kept, and the expiry surfaced as censored telemetry (all the
    #: router learns is "slower than the deadline").
    deadline: Optional[float] = None
    # -- filled by the engine ------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    t_cancelled: Optional[float] = None
    cancel_reason: Optional[str] = None   # "deadline" | "cancelled" | "migrated"
    prefilled: int = 0            # prompt tokens already in cache (chunked)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefill_len(self) -> int:
        """Tokens the next prefill phase must put in cache. A fresh
        request prefills its prompt; a PREEMPTED request replays prompt
        plus every already-emitted token except the last (that one is
        re-derived by the first decode tick from the replayed state, so
        the resumed stream stays byte-identical). Stable across the
        chunked phases of one prefill: ``tokens`` only grows during
        decode."""
        return self.prompt_len + max(len(self.tokens) - 1, 0)

    def prefill_target(self) -> np.ndarray:
        """The exact token sequence prefill feeds — see ``prefill_len``."""
        if not self.tokens:
            return self.prompt
        return np.concatenate([
            self.prompt, np.asarray(self.tokens[:-1], self.prompt.dtype)
        ])

    @property
    def cancelled(self) -> bool:
        return self.t_cancelled is not None

    @property
    def latency(self) -> float:
        return (self.t_done - self.arrival) if self.t_done is not None else np.inf


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual seconds per engine action. Defaults are shaped like a
    fixed-batch accelerator step: a per-launch constant plus a per-token
    term for prefill; decode ticks cost the same regardless of how many
    slots are live (the whole pool is one fixed-shape jit call).

    Speculation pricing (DESIGN.md §12): ``draft_ratio`` is the
    draft/target cost ratio (one draft action costs ``draft_ratio`` times
    the target's), and a verify call scoring a window of S tokens per
    lane costs one decode tick plus ``verify_per_token * S`` — it is one
    fused fixed-shape call whose weight traffic matches a decode tick,
    with a small per-token activation term. These two knobs are the
    economy the adaptive gamma controller prices rounds against."""

    prefill_base: float = 1e-3
    prefill_per_token: float = 1e-4
    decode_tick: float = 1e-3
    draft_ratio: float = 0.3
    verify_per_token: float = 1e-4

    def prefill(self, n_tokens: int) -> float:
        return self.prefill_base + self.prefill_per_token * n_tokens

    def decode(self) -> float:
        return self.decode_tick

    # -- speculation ---------------------------------------------------------
    def draft_decode(self) -> float:
        return self.draft_ratio * self.decode_tick

    def draft_prefill(self, n_tokens: int) -> float:
        return self.draft_ratio * self.prefill(n_tokens)

    def verify(self, n_tokens: int) -> float:
        """One batched verify call scoring ``n_tokens`` positions/lane."""
        return self.decode_tick + self.verify_per_token * n_tokens

    # -- preemption economics (DESIGN.md §16) --------------------------------
    def recompute(self, n_tokens: int) -> float:
        """Price of evicting a lane and replaying ``n_tokens`` of prefix
        later (prefill from the longest still-resident prefix) — the
        paper's "recompute" arm of the wait-vs-recompute trade."""
        return self.prefill(n_tokens) if n_tokens > 0 else 0.0

    def hold(self, remaining_tokens: int) -> float:
        """Price of keeping a lane's blocks pinned until it finishes on
        its own: the decode ticks it still needs — the "wait" arm."""
        return self.decode_tick * max(int(remaining_tokens), 0)

    def spec_round(
        self, draft_ticks: int, verify_tokens: int, replay: bool = False
    ) -> float:
        """One speculation round: sequential draft ticks (including any
        resync tick), one target verify, and — for drafts with recurrent
        state, which cannot rewind — a draft-scale replay scan over the
        same window (``replay=True``)."""
        c = draft_ticks * self.draft_decode() + self.verify(verify_tokens)
        if replay:
            c += self.draft_ratio * self.verify(verify_tokens)
        return c


class EventClock:
    def __init__(self, cost: Optional[CostModel] = None):
        self.cost = cost or CostModel()
        self.now = 0.0

    def advance_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.prefill(n_tokens)

    def advance_decode(self) -> None:
        self.now += self.cost.decode()

    def advance_draft_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.draft_prefill(n_tokens)

    def advance_spec_round(
        self, draft_ticks: int, verify_tokens: int, replay: bool = False
    ) -> None:
        self.now += self.cost.spec_round(draft_ticks, verify_tokens, replay)

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)


class Scheduler:
    """Chooses the next engine action. Pure host logic, fully deterministic."""

    def __init__(
        self,
        n_slots: int,
        *,
        prefill_chunk: Optional[int] = None,
        decode_per_prefill: int = 4,
        clock: Optional[EventClock] = None,
        deadline_ticks: Optional[int] = None,
        obs: Optional[Observability] = None,
    ):
        """``deadline_ticks``: default per-request deadline, in decode-tick
        units of the clock's cost model, stamped at ADMISSION (queueing
        time does not count against it). Requests submitted with an
        explicit absolute ``Request.deadline`` keep it."""
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.decode_per_prefill = max(int(decode_per_prefill), 0)
        self.clock = clock or EventClock()
        self.deadline_ticks = deadline_ticks
        self.waiting: List[Request] = []
        self.running: List[Request] = []   # admitted, mid-prefill (chunked)
        self._decode_debt = 0              # decode ticks owed before next prefill
        self.bind_obs(obs or NULL_OBS)

    def bind_obs(self, obs: Observability) -> None:
        """Attach (or swap) an observability bundle. The engine calls
        this for schedulers built without one, so default-constructed
        schedulers still report queue metrics when the engine is
        instrumented."""
        self.obs = obs
        self._h_wait = obs.metrics.histogram("sched.queue_wait")
        self._g_depth = obs.metrics.gauge("sched.waiting_depth")

    # -- queue ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))  # FIFO by arrival
        self._g_depth.set(len(self.waiting))

    def _eligible(self) -> Optional[Request]:
        for r in self.waiting:
            if r.arrival <= self.clock.now:
                return r
        return None

    def _next_arrival(self) -> Optional[float]:
        return min((r.arrival for r in self.waiting), default=None)

    # -- policy --------------------------------------------------------------
    def next_action(
        self, n_active: int, n_free: int, can_admit=None
    ) -> Tuple[str, Optional[Request]]:
        """-> ("prefill", request) | ("decode", None) | ("idle", None) |
        ("done", None).

        Mid-prefill requests always finish their remaining chunks before
        new admissions (they hold a slot). A fresh admission needs a free
        slot, a paid-down decode debt, and — when the engine supplies a
        ``can_admit(request)`` predicate (paged pools: "enough free
        blocks for the whole token budget") — a passing budget check;
        otherwise decode if anything is active (finishing requests
        returns blocks, which is what unblocks a queued admission);
        otherwise jump the clock to the next arrival. A request that
        fails the budget check with nothing active cannot occur: submit
        rejects requests larger than the whole arena, and an idle pool
        has every block free.
        """
        if self.running:
            req = self.running[0]
            if self._decode_debt > 0 and n_active > len(self.running):
                # sequences besides the mid-prefill ones are decoding:
                # interleave before the next chunk.
                self._decode_debt -= 1
                return "decode", None
            return "prefill", req
        req = self._eligible()
        if req is not None and n_free > 0 and (can_admit is None or can_admit(req)):
            if self._decode_debt > 0 and n_active > 0:
                self._decode_debt -= 1
                return "decode", None
            return "prefill", req
        if n_active > 0:
            return "decode", None
        nxt = self._next_arrival()
        if nxt is not None:
            return "idle", None
        return "done", None

    # -- engine callbacks ----------------------------------------------------
    def chunk_for(self, req: Request) -> Tuple[int, int]:
        """(start, n_tokens) of the next prefill chunk for ``req`` —
        measured against ``prefill_len`` so a preempted request's replay
        (prompt + emitted tokens) chunks exactly like a long prompt."""
        start = req.prefilled
        remaining = req.prefill_len - start
        if self.prefill_chunk is None:
            return start, remaining
        return start, min(self.prefill_chunk, remaining)

    def requeue(self, req: Request) -> None:
        """Put a PREEMPTED request back in the arrival queue: its slot
        and blocks were taken, its emitted tokens are kept, and its next
        admission replays from the longest still-resident prefix.
        ``arrival`` is deliberately unchanged — the victim's eventual
        latency honestly includes the eviction (no p99 laundering) and
        FIFO order re-admits it first, which with the preemptor's
        completed progress rules out livelock."""
        if req in self.running:
            self.running.remove(req)
        req.prefilled = 0
        req.t_admit = None
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))
        self._g_depth.set(len(self.waiting))

    def on_admit(self, req: Request) -> None:
        self.waiting.remove(req)
        self.running.append(req)
        req.t_admit = self.clock.now
        if req.deadline is None and self.deadline_ticks is not None:
            req.deadline = (
                self.clock.now + self.deadline_ticks * self.clock.cost.decode_tick
            )
        self._g_depth.set(len(self.waiting))
        # Queue wait: admission minus arrival, clamped at 0 (a hedge
        # copy can be admitted on a replica whose clock is behind the
        # logical arrival stamp).
        self._h_wait.observe(max(self.clock.now - req.arrival, 0.0))

    def drop(self, req: Request) -> None:
        """Forget a cancelled request wherever it sits in the queues
        (waiting or mid-prefill running; a decoding request is in
        neither — its slot is the engine's to free)."""
        if req in self.waiting:
            self.waiting.remove(req)
        if req in self.running:
            self.running.remove(req)
        self._g_depth.set(len(self.waiting))

    def on_prefill_chunk(self, req: Request, n_tokens: int, done: bool) -> None:
        req.prefilled += n_tokens
        self.clock.advance_prefill(n_tokens)
        if done:
            self.running.remove(req)
        self._decode_debt = self.decode_per_prefill

    def on_decode_tick(self) -> None:
        self.clock.advance_decode()

    def on_draft_prefill(self, n_tokens: int) -> None:
        """The draft model mirrors every admission prefill (its cache
        must hold the same prefix); priced at the draft cost ratio."""
        self.clock.advance_draft_prefill(n_tokens)

    def on_draft_decode(self) -> None:
        """One draft-lockstep tick during a non-speculating (gamma = 0)
        round: the draft consumes what the target consumed."""
        self.clock.now += self.clock.cost.draft_decode()

    def on_spec_round(
        self, draft_ticks: int, verify_tokens: int, emitted: int,
        replay: bool = False,
    ) -> None:
        """One speculation round in place of a decode tick.

        Debt accounting: the ``decode_per_prefill`` interleave owes the
        in-flight requests decode PROGRESS between prefill chunks, not
        literally ticks — a verify round is worth ``emitted`` ticks of
        that obligation, where the engine reports its WEAKEST live
        lane's committed tokens (so a zero-acceptance lane still sees
        the full interleave guarantee, while all-accepting rounds don't
        starve admissions by stretching the debt window into multi-token
        rounds). ``next_action`` already paid 1 when it issued the
        round's "decode" action; the remaining ``emitted - 1`` are paid
        here."""
        self.clock.advance_spec_round(draft_ticks, verify_tokens, replay)
        self._decode_debt = max(0, self._decode_debt - max(emitted - 1, 0))

    def on_idle(self) -> None:
        nxt = self._next_arrival()
        if nxt is not None:
            self.clock.advance_to(nxt)
