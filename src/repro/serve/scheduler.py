"""Admission + prefill/decode interleaving with a deterministic event clock.

The engine's control loop is intentionally sequential and replayable:
every tick the scheduler picks ONE action — admit-and-prefill a waiting
request (possibly one chunk of it), run a decode tick over the whole
slot pool, or idle until the next arrival. Virtual time advances by a
linear cost model per action, so latency distributions are exact
functions of the workload (no wall-clock noise in tests or CI), while
the engine separately measures wall time for throughput.

The interleave policy bounds tail latency the same way the paper bounds
iteration time: a long prompt is chopped into ``prefill_chunk``-token
pieces, and between consecutive prefill actions at least
``decode_per_prefill`` decode ticks run whenever sequences are active —
so a 32k-token admission can't stall every in-flight request's
inter-token latency by more than one chunk's cost.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["Request", "CostModel", "EventClock", "Scheduler", "next_bucket"]


def next_bucket(n: int, base: int = 16) -> int:
    """Smallest power-of-two multiple of ``base`` >= n (prefill shape
    bucketing: a handful of compiles cover every prompt length)."""
    b = base
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    arrival: float = 0.0
    # -- filled by the engine ------------------------------------------------
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    prefilled: int = 0            # prompt tokens already in cache (chunked)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def latency(self) -> float:
        return (self.t_done - self.arrival) if self.t_done is not None else np.inf


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Virtual seconds per engine action. Defaults are shaped like a
    fixed-batch accelerator step: a per-launch constant plus a per-token
    term for prefill; decode ticks cost the same regardless of how many
    slots are live (the whole pool is one fixed-shape jit call)."""

    prefill_base: float = 1e-3
    prefill_per_token: float = 1e-4
    decode_tick: float = 1e-3

    def prefill(self, n_tokens: int) -> float:
        return self.prefill_base + self.prefill_per_token * n_tokens

    def decode(self) -> float:
        return self.decode_tick


class EventClock:
    def __init__(self, cost: Optional[CostModel] = None):
        self.cost = cost or CostModel()
        self.now = 0.0

    def advance_prefill(self, n_tokens: int) -> None:
        self.now += self.cost.prefill(n_tokens)

    def advance_decode(self) -> None:
        self.now += self.cost.decode()

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)


class Scheduler:
    """Chooses the next engine action. Pure host logic, fully deterministic."""

    def __init__(
        self,
        n_slots: int,
        *,
        prefill_chunk: Optional[int] = None,
        decode_per_prefill: int = 4,
        clock: Optional[EventClock] = None,
    ):
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        self.decode_per_prefill = max(int(decode_per_prefill), 0)
        self.clock = clock or EventClock()
        self.waiting: List[Request] = []
        self.running: List[Request] = []   # admitted, mid-prefill (chunked)
        self._decode_debt = 0              # decode ticks owed before next prefill

    # -- queue ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))  # FIFO by arrival

    def _eligible(self) -> Optional[Request]:
        for r in self.waiting:
            if r.arrival <= self.clock.now:
                return r
        return None

    def _next_arrival(self) -> Optional[float]:
        return min((r.arrival for r in self.waiting), default=None)

    # -- policy --------------------------------------------------------------
    def next_action(
        self, n_active: int, n_free: int, can_admit=None
    ) -> Tuple[str, Optional[Request]]:
        """-> ("prefill", request) | ("decode", None) | ("idle", None) |
        ("done", None).

        Mid-prefill requests always finish their remaining chunks before
        new admissions (they hold a slot). A fresh admission needs a free
        slot, a paid-down decode debt, and — when the engine supplies a
        ``can_admit(request)`` predicate (paged pools: "enough free
        blocks for the whole token budget") — a passing budget check;
        otherwise decode if anything is active (finishing requests
        returns blocks, which is what unblocks a queued admission);
        otherwise jump the clock to the next arrival. A request that
        fails the budget check with nothing active cannot occur: submit
        rejects requests larger than the whole arena, and an idle pool
        has every block free.
        """
        if self.running:
            req = self.running[0]
            if self._decode_debt > 0 and n_active > len(self.running):
                # sequences besides the mid-prefill ones are decoding:
                # interleave before the next chunk.
                self._decode_debt -= 1
                return "decode", None
            return "prefill", req
        req = self._eligible()
        if req is not None and n_free > 0 and (can_admit is None or can_admit(req)):
            if self._decode_debt > 0 and n_active > 0:
                self._decode_debt -= 1
                return "decode", None
            return "prefill", req
        if n_active > 0:
            return "decode", None
        nxt = self._next_arrival()
        if nxt is not None:
            return "idle", None
        return "done", None

    # -- engine callbacks ----------------------------------------------------
    def chunk_for(self, req: Request) -> Tuple[int, int]:
        """(start, n_tokens) of the next prefill chunk for ``req``."""
        start = req.prefilled
        remaining = req.prompt_len - start
        if self.prefill_chunk is None:
            return start, remaining
        return start, min(self.prefill_chunk, remaining)

    def on_admit(self, req: Request) -> None:
        self.waiting.remove(req)
        self.running.append(req)
        req.t_admit = self.clock.now

    def on_prefill_chunk(self, req: Request, n_tokens: int, done: bool) -> None:
        req.prefilled += n_tokens
        self.clock.advance_prefill(n_tokens)
        if done:
            self.running.remove(req)
        self._decode_debt = self.decode_per_prefill

    def on_decode_tick(self) -> None:
        self.clock.advance_decode()

    def on_idle(self) -> None:
        nxt = self._next_arrival()
        if nxt is not None:
            self.clock.advance_to(nxt)
