"""Speculative decoding: adaptive draft length over the slot pool.

Draft-then-verify decoding is the paper's (k, beta) decision wearing
serving clothes. Each round, a cheap DRAFT model proposes ``gamma``
tokens per live slot (gamma sequential draft ticks), and the TARGET
model scores all of them in ONE fused verify call
(``Model.verify_with_cache`` — the batched-prefill machinery with
per-slot start positions). The exact-argmax acceptance rule commits the
longest draft prefix the target agrees with, plus one corrected token —
so the greedy token stream is byte-identical to non-speculative decode
by construction, and speculation is purely a throughput bet:

  * ``gamma`` is the **computation-load knob** (the paper's beta): extra
    speculative work bought per round, wasted whenever the chain breaks;
  * the accepted-prefix length is the **fastest-k outcome** (the paper's
    k): how much of the purchased work the round actually banks.

``SpecController`` adapts gamma from acceptance telemetry exactly the
way the paper's controller adapts (k, beta) from straggler telemetry:
an EWMA estimate (here: per-draft-token acceptance probability ``p``,
the serving twin of the EWMA slowdowns in
``repro.runtime.telemetry.StragglerTracker``) feeds a brute-force
minimization of expected cost per committed token. When the verify call
is dispatched over replicas, the latency term is priced with the SAME
``expected_kth`` order-statistics formula the ``HedgedRouter`` uses —
the verify window width scales the per-replica load beta, so choosing
(gamma, n_h) jointly IS the paper's (k, beta) adaptation
(``choose_hedged``, DESIGN.md §12.4).

Public API contract: everything here is SPEC-DRIVEN — ``DraftRunner``
works for any registered model family because it only talks to the
cache through ``SlotPool``/``ParamSpec`` axes metadata (snapshot/restore
targets exactly the leaves without a sequence axis, i.e. recurrent
state that cannot rewind). Nothing is specific to a model architecture;
the draft and target models may be different families as long as they
share a vocabulary.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.order_stats import expected_kth, expected_kth_derivative
from repro.models.layers import ParamSpec, slot_mask_select
from repro.obs import NULL_OBS
from repro.runtime.steps import make_slot_prefill_step, make_slot_replay_step

from .kv_pool import SlotPool, model_scoped_cache
from .scheduler import CostModel

__all__ = ["GammaPlan", "SpecController", "DraftRunner", "hedged_round_cost"]


# ---------------------------------------------------------------------------
# Gamma pricing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GammaPlan:
    gamma: int                   # draft tokens per round (0 = don't speculate)
    expected_tokens: float       # E[committed tokens per round]
    expected_cost: float         # virtual seconds per round
    cost_per_token: float        # what the brute force minimizes
    n_h: int = 1                 # verify fan-out (hedged pricing only)


def expected_round_tokens(gamma: int, p: float) -> float:
    """E[tokens committed by one round] = sum_{i=0}^{gamma} p^i under the
    geometric acceptance model (each draft token independently agrees
    with the target's argmax with probability ``p``; the round commits
    the unbroken prefix plus one corrected token)."""
    return float(sum(p ** i for i in range(gamma + 1)))


def hedged_round_cost(
    delay_model,
    n_h: int,
    gamma: int,
    *,
    draft_time: float,
    beta_unit: float,
    quorum: int = 1,
    cost_per_replica: float = 0.0,
    slowdown: float = 1.0,
) -> float:
    """Expected latency of one round whose verify call is hedged over
    ``n_h`` replicas — the explicit (k, beta) mapping:

        cost = gamma * t_draft
             + mu_{k:n_h}(beta_unit * (gamma + 1)) * slowdown
             + c_replica * n_h

    The verify window width (gamma + 1) multiplies the per-replica load
    beta exactly as the paper's per-worker batch fraction does, and the
    k-th fastest verify response is priced by the same ``expected_kth``
    closed form / quadrature the training controller uses. Unlike the
    paper's schedules, the scaled load may exceed 1 (a verify window
    wider than the reference load); the delay models' domain is
    beta <= 1, so past it the latency term extrapolates linearly from
    beta = 1 via ``expected_kth_derivative`` — exact for Def. 1 (mu is
    affine in beta), first-order for Def. 2 — so widening the window
    always costs latency; clamping at 1 would let the brute force pick
    ever larger gamma for free."""
    beta = beta_unit * (gamma + 1)
    k = min(quorum, n_h)
    if beta <= 1.0:
        lat = expected_kth(delay_model, n_h, k, beta)
    else:
        lat = expected_kth(delay_model, n_h, k, 1.0) + (
            beta - 1.0
        ) * expected_kth_derivative(delay_model, n_h, k, 1.0)
    return gamma * draft_time + lat * slowdown + cost_per_replica * n_h


class SpecController:
    """Adapts the draft length from acceptance telemetry.

    ``observe(accepted, offered)`` feeds per-token Bernoulli outcomes
    into an EWMA acceptance probability (offered - accepted is at most
    one failure: the chain stops at the first disagreement, so later
    positions are censored — the same censoring discipline as the
    router's cancelled hedges). ``choose_gamma`` brute-forces the gamma
    minimizing expected virtual cost per committed token under the
    engine's ``CostModel``; gamma = 0 means speculation currently loses
    (e.g. draft/target cost ratio near 1) and the engine falls back to
    plain decode ticks, probing with gamma = 1 every ``probe_every``
    rounds so the controller can re-enter when acceptance recovers."""

    def __init__(
        self,
        gamma_max: int = 4,
        *,
        alpha: float = 0.1,
        p0: float = 0.8,
        warmup: int = 4,
        probe_every: int = 16,
    ):
        if gamma_max < 1:
            raise ValueError("need gamma_max >= 1")
        self.gamma_max = gamma_max
        self.alpha = alpha
        self.p0 = p0
        self.warmup = warmup
        self.probe_every = probe_every
        self.p = p0                  # EWMA per-draft-token acceptance
        self.observations = 0        # Bernoulli outcomes absorbed
        self.rounds = 0              # choose_gamma calls (probe clock)
        #: set by the engine at attach: fused-prefill drafts resync by
        #: position rewind (+ one expected tick), others by replay scan.
        self.draft_fused = True
        #: accepted-prefix-length histogram: hist[a] = LANE-rounds (one
        #: entry per speculating slot per round, so sums to ~occupancy x
        #: rounds) that accepted exactly ``a`` draft tokens.
        self.hist = np.zeros(gamma_max + 1, np.int64)
        #: observability bundle, attached by the engine (same pattern as
        #: ``draft_fused``); defaults to the disabled singleton.
        self.obs = NULL_OBS
        self._last_gamma: Optional[int] = None   # decision-log dedup

    # -- telemetry -----------------------------------------------------------
    def observe(self, accepted: int, offered: int) -> None:
        if offered <= 0:
            return
        if not (0 <= accepted <= offered):
            raise ValueError(f"accepted {accepted} outside [0, {offered}]")
        self.hist[min(accepted, self.gamma_max)] += 1
        if self.obs.enabled:
            self.obs.metrics.counter("spec.offered").inc(offered)
            self.obs.metrics.counter("spec.accepted").inc(accepted)
        # Chain semantics: `accepted` successes, then at most ONE observed
        # failure; positions past the break are censored, not failures.
        outcomes = [1.0] * accepted + ([0.0] if accepted < offered else [])
        for x in outcomes:
            self.p += self.alpha * (x - self.p)
            self.observations += 1

    @property
    def p_effective(self) -> float:
        """Acceptance estimate the pricing uses (prior until warmed)."""
        return self.p if self.observations >= self.warmup else self.p0

    # -- pricing -------------------------------------------------------------
    def round_cost(self, gamma: int, cost: CostModel) -> float:
        """Expected virtual cost of one round at draft length ``gamma``.
        gamma = 0 is a plain decode tick plus the draft-lockstep tick (a
        draft-attached engine still pays to keep the draft cache on the
        committed stream — part of why a bad draft should be detached,
        not just throttled; see EXPERIMENTS.md). Fused-prefill drafts
        pay one EXTRA expected tick with probability p^gamma (the
        all-accepted resync, ``DraftRunner.resync``) instead of the
        replay scan."""
        if gamma == 0:
            return cost.decode() + cost.draft_decode()
        if self.draft_fused:
            p_all = self.p_effective ** gamma
            return (cost.spec_round(gamma, gamma + 1)
                    + p_all * cost.draft_decode())
        return cost.spec_round(gamma, gamma + 1, replay=True)

    def choose_gamma(self, cost: CostModel) -> GammaPlan:
        """Brute-force argmin over gamma of cost-per-committed-token —
        the serving analogue of the controller's (k, beta) grid step."""
        self.rounds += 1
        p = self.p_effective
        best: Optional[GammaPlan] = None
        for gamma in range(self.gamma_max + 1):
            toks = expected_round_tokens(gamma, p)
            c = self.round_cost(gamma, cost)
            plan = GammaPlan(gamma, toks, c, c / toks)
            if best is None or plan.cost_per_token < best.cost_per_token:
                best = plan
        if best.gamma == 0 and self.probe_every > 0 \
                and self.rounds % self.probe_every == 0:
            # Periodic probe: keep the acceptance estimate alive so the
            # controller can re-enter speculation when conditions change.
            toks = expected_round_tokens(1, p)
            c = self.round_cost(1, cost)
            best = GammaPlan(1, toks, c, c / toks)
        if best.gamma != self._last_gamma:
            # Log the reprice (a CHANGED gamma), not every evaluation.
            self._last_gamma = best.gamma
            self.obs.decisions.record(
                "serve.gamma",
                {"gamma": int(best.gamma), "n_h": int(best.n_h)},
                {"p": round(p, 6), "observations": self.observations,
                 "cost_per_token": round(best.cost_per_token, 9)},
                step=self.rounds,
            )
        return best

    def choose_hedged(
        self,
        delay_model,
        *,
        draft_time: float,
        beta_unit: float,
        n_max: int,
        quorum: int = 1,
        cost_per_replica: float = 0.0,
        slowdown: float = 1.0,
    ) -> GammaPlan:
        """Joint (gamma, n_h) brute force with the verify latency priced
        by ``expected_kth`` — see ``hedged_round_cost``. This is the
        composition seam with ``HedgedRouter``: pass the router's delay
        model and EWMA ``slowdown`` for the replica subset.

        Degraded fleets: pass the LIVE replica count as ``n_max`` (e.g.
        ``router.n_alive``) and the pricing re-runs over the shrunken
        fan-out range instead of assuming dead verifiers; a fleet
        smaller than the quorum clamps the quorum rather than stalling
        (same contract as ``HedgedRouter.choose_hedge``)."""
        quorum = min(quorum, max(n_max, 1))
        p = self.p_effective
        best: Optional[GammaPlan] = None
        for gamma in range(self.gamma_max + 1):
            toks = expected_round_tokens(gamma, p)
            for n in range(quorum, n_max + 1):
                c = hedged_round_cost(
                    delay_model, n, gamma,
                    draft_time=draft_time, beta_unit=beta_unit,
                    quorum=quorum, cost_per_replica=cost_per_replica,
                    slowdown=slowdown,
                )
                plan = GammaPlan(gamma, toks, c, c / toks, n_h=n)
                if best is None or plan.cost_per_token < best.cost_per_token:
                    best = plan
        return best


# ---------------------------------------------------------------------------
# Draft runner: the draft model's twin slot pool
# ---------------------------------------------------------------------------

@model_scoped_cache
def _draft_steps(model, n_slots: int, max_len: int):
    """Jitted draft-side steps, cached on the draft model instance (same
    lifetime discipline as ``engine._engine_steps``)."""
    specs = model.cache_specs(n_slots, max_len)
    prefill = make_slot_prefill_step(model)
    replay = make_slot_replay_step(model)
    decode = model.decode_step

    def draft_tick(params, tokens, caches, positions, mask):
        logits, new_caches = decode(params, tokens, caches, positions)
        caches = slot_mask_select(mask, new_caches, caches, specs)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), caches

    return jax.jit(prefill), jax.jit(draft_tick), jax.jit(replay)


class DraftRunner:
    """A second ``SlotPool`` (contiguous layout — draft caches are small)
    kept in slot-index lockstep with the target engine's pool: same
    admissions, same frees, same defrag permutation.

    Rollback discipline (per cache-leaf kind, read off the spec tree):

      * sequence-axis leaves (KV rows) rewind for free — stale rows past
        the committed position are dead and get overwritten;
      * recurrent state leaves (no sequence axis) cannot rewind, so the
        runner snapshots them (an immutable-pytree reference — zero
        copies) before drafting and, after the verify, restores the
        snapshot and REPLAYS exactly the committed tokens through one
        masked scan (``make_slot_replay_step``). The replay also repairs
        the one KV row an all-accepted round leaves unwritten, so it
        runs unconditionally for every family.
    """

    def __init__(self, model, params, n_slots: int, max_len: int):
        if model.cfg.is_encoder:
            raise ValueError("draft model must be a causal decoder")
        self.model = model
        self.params = params
        self.pool = SlotPool(model, n_slots, max_len)
        self._prefill, self._tick, self._replay = _draft_steps(
            model, n_slots, max_len
        )
        self._blank1 = model.blank_caches(1, max_len)
        self._snap = None            # caches pytree at snapshot time
        self._snap_positions = None

    # -- admission mirror ----------------------------------------------------
    def prefill_chunk(
        self, slot: int, chunk: jax.Array, n_tok: int, start: int,
        owner: Optional[int] = None,
    ) -> None:
        """Mirror one target prefill chunk into the draft cache. ``chunk``
        is the engine's already-bucketed (1, bucket) token array, so the
        draft reuses the target's compile buckets."""
        if start == 0:
            got = self.pool.allocate(owner=owner)
            assert got == slot, f"draft pool desync: slot {got} != {slot}"
            slot_caches = self._blank1
        else:
            slot_caches = self.pool.read_slot(slot)
        _, slot_caches = self._prefill(
            self.params, chunk, slot_caches,
            jnp.asarray([n_tok], jnp.int32), jnp.int32(start), None,
        )
        self.pool.write_slot(slot, slot_caches, position=start + n_tok)

    # -- draft loop ----------------------------------------------------------
    def snapshot(self) -> None:
        """Mark the committed state before drafting (leaves are immutable
        jax arrays: keeping the pytree reference IS the snapshot)."""
        self._snap = self.pool.caches
        self._snap_positions = self.pool.positions.copy()

    def decode_tick(self, tokens: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """One masked draft decode tick over the pool -> greedy proposals
        (n_slots,); advances the positions of masked-in lanes."""
        positions = jnp.asarray(np.clip(self.pool.positions, 0,
                                        self.pool.max_len - 1))
        greedy, self.pool.caches = self._tick(
            self.params, jnp.asarray(tokens[:, None]), self.pool.caches,
            positions, jnp.asarray(mask),
        )
        self.pool.positions[mask] += 1
        return np.asarray(greedy, np.int32)

    # -- post-verify resync --------------------------------------------------
    def resync(
        self, inputs: np.ndarray, n_commit: np.ndarray
    ) -> Tuple[int, bool]:
        """Roll the draft back to the committed stream: exactly
        ``n_commit[b]`` tokens of ``inputs[b]`` per lane (0 = lane
        untouched). Returns ``(extra_ticks, replayed)`` for the event
        clock.

        Pure-attention drafts rewind for free: the drafting ticks already
        wrote the K/V rows of every token they consumed, the committed
        prefix is a subset of those rows, and stale rows past the rewound
        position are dead. The one gap is an ALL-ACCEPTED lane — the
        verify committed its last draft token, which the draft proposed
        but never consumed — repaired by a single masked tick (proposal
        discarded) instead of a full replay call.

        Drafts with recurrent state leaves restore the snapshot and
        replay the committed tokens through one masked scan."""
        assert self._snap is not None, "resync without snapshot"
        starts = self._snap_positions
        live = n_commit > 0
        extra_ticks, replayed = 0, False
        if self.model.fused_prefill:
            drafted = self.pool.positions - starts      # ticks consumed/lane
            need = live & (n_commit > drafted)          # all-accepted lanes
            if need.any():
                # Feed the missing token at its (current) position.
                toks = np.take_along_axis(
                    inputs, np.maximum(n_commit - 1, 0)[:, None], axis=1
                )[:, 0]
                self.decode_tick(toks.astype(np.int32), need)
                extra_ticks = 1
            rewind = live & ~need
            self.pool.positions[rewind] = starts[rewind] + n_commit[rewind]
        else:
            self.pool.caches = jax.tree.map(
                lambda s, snap, cur: cur if "act_kv_seq" in s.axes else snap,
                self.pool.specs, self._snap, self.pool.caches,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
            self.pool.caches = self._replay(
                self.params, jnp.asarray(inputs), self.pool.caches,
                jnp.asarray(n_commit, jnp.int32),
                jnp.asarray(np.clip(starts, 0, self.pool.max_len - 1)),
                None,
            )
            self.pool.positions[live] = starts[live] + n_commit[live]
            replayed = True
        self._snap = self._snap_positions = None
        return extra_ticks, replayed
