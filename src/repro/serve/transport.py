"""Faultable message transport between the frontend and its replicas.

Until this module existed the frontend↔replica "network" was implicit
Python calls: PR 7's chaos plane could kill or slow a replica, but no
message could be dropped, duplicated, reordered, delayed, or partitioned
away. This module makes the transport an explicit, *faultable* seam —
the serving plane's messages (submit / cancel / stream-chunk /
migration-ticket and their replies) travel over per-direction
:class:`Channel` objects on the plane's deterministic tick clock, and a
declarative :class:`TransportFaults` plan says exactly what the network
does to each transmission. On top of the raw channels sits an
idempotent at-least-once delivery layer:

* **acks + retransmission** — every data message is tracked until a
  transport-level ack returns; unacked messages retransmit after a
  deterministic timeout with exponential backoff, the base timeout
  priced per destination from the router's censored straggler telemetry
  (a replica the tracker thinks is 4x slow gets a 4x retransmit
  budget before the sender burns a duplicate);
* **receiver dedup** — per-link seen-sets drop re-delivered message ids
  (retransmissions whose ack was lost, fault-injected duplicates), and
  re-ack so the sender converges; the application layer above is ALSO
  idempotent (stream chunks are position-addressed, cancels are no-ops
  on finished requests) so even with dedup deliberately disabled most
  duplicates are harmless — the chaos-search harness exploits exactly
  that gap to demonstrate what the protections buy;
* **integrity** — fault-injected corruption models what link-layer CRCs
  *cannot* catch: a corrupted data frame (submit/chunk) is detected and
  dropped by the link (indistinguishable from loss; retransmission
  recovers it), but a :class:`~repro.serve.engine.MigrationTicket`
  payload is mutated IN FLIGHT and delivered — only the ticket's
  end-to-end checksum (sealed at ``export_request``, verified at
  ``import_request``) catches it, and the frontend's policy is
  reject-and-requeue, never resume-from-garbage.

Fault plans are explicit per-transmission directives (``the 7th message
on link fe->r1 is dropped``) plus one-way partition windows, so a chaos
schedule is plain JSON: individually removable atoms that
``tools/chaos_search.py`` can delta-debug down to a minimal repro, and
a replay of the same plan is bit-for-bit the same run.

Public API contract: MODEL-AGNOSTIC and deterministic — the transport
never inspects tokens or caches, owns no RNG (fault plans are data,
sampled elsewhere), and given the same send sequence and plan produces
the same delivery sequence. Endpoint liveness enters only through
``forget_endpoint``/``revive_endpoint`` (the chaos control plane);
message POLICY (what to send, how to react) lives in
``serve.frontend`` and ``serve.replica``.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import NULL_OBS, Observability

__all__ = [
    "FE", "replica_endpoint",
    "FaultDirective", "Partition", "TransportFaults",
    "Submit", "Cancel", "Chunk", "Expired", "Ticket", "TicketReply", "Ack",
    "WireMessage", "Channel", "Transport", "TransportGaveUp",
]

#: the frontend's endpoint name; replicas are ``r0``, ``r1``, ...
FE = "fe"


def replica_endpoint(replica_id: int) -> str:
    return f"r{int(replica_id)}"


# ---------------------------------------------------------------------------
# Fault plans: explicit, JSON-serializable, individually removable
# ---------------------------------------------------------------------------

_OPS = ("drop", "dup", "delay", "reorder", "corrupt")


@dataclasses.dataclass(frozen=True)
class FaultDirective:
    """One fault on one link: the ``nth`` TRANSMISSION (0-based, counting
    retransmissions) on ``(src, dst)`` suffers ``op``.

    * ``drop``    — the transmission is lost;
    * ``dup``     — it is delivered twice;
    * ``delay``   — delivery is postponed by ``ticks`` plane ticks;
    * ``reorder`` — it stays on schedule but sorts AFTER the next
      ``ticks`` (default 2) messages that share its delivery tick;
    * ``corrupt`` — the payload is mutated in flight if it carries an
      in-band mutator (migration tickets); data frames without one are
      dropped instead — the link CRC caught the damage, which is
      exactly a loss.
    """

    src: str
    dst: str
    op: str
    nth: int
    ticks: int = 0

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown transport fault op {self.op!r}")
        if self.nth < 0:
            raise ValueError(f"directive nth must be >= 0, got {self.nth}")
        if self.ticks < 0:
            raise ValueError(f"directive ticks must be >= 0, got {self.ticks}")

    def as_dict(self) -> dict:
        return {"src": self.src, "dst": self.dst, "op": self.op,
                "nth": self.nth, "ticks": self.ticks}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultDirective":
        return cls(src=str(d["src"]), dst=str(d["dst"]), op=str(d["op"]),
                   nth=int(d["nth"]), ticks=int(d.get("ticks", 0)))


@dataclasses.dataclass(frozen=True)
class Partition:
    """A one-way partition: every transmission sent on ``(src, dst)``
    while ``t0 <= tick < t1`` is dropped. The reverse direction is
    UNAFFECTED — one-way partitions are the nasty case (acks die while
    data flows, or data dies while acks flow)."""

    src: str
    dst: str
    t0: int
    t1: int

    def __post_init__(self):
        if self.t1 <= self.t0 or self.t0 < 0:
            raise ValueError(
                f"partition window must satisfy 0 <= t0 < t1, "
                f"got [{self.t0}, {self.t1})"
            )

    def as_dict(self) -> dict:
        return {"src": self.src, "dst": self.dst, "t0": self.t0, "t1": self.t1}

    @classmethod
    def from_dict(cls, d: dict) -> "Partition":
        return cls(src=str(d["src"]), dst=str(d["dst"]),
                   t0=int(d["t0"]), t1=int(d["t1"]))


class TransportFaults:
    """A complete network-fault plan: per-link per-transmission
    directives plus one-way partition windows. Pure data — construction
    validates, ``as_dict``/``from_dict`` round-trip through JSON, and
    the chaos-search shrinker removes atoms one at a time."""

    def __init__(
        self,
        directives: Iterable[FaultDirective] = (),
        partitions: Iterable[Partition] = (),
    ):
        self.directives: List[FaultDirective] = list(directives)
        self.partitions: List[Partition] = list(partitions)
        self._by_link: Dict[Tuple[str, str, int], List[FaultDirective]] = {}
        for fd in self.directives:
            self._by_link.setdefault((fd.src, fd.dst, fd.nth), []).append(fd)

    def __len__(self) -> int:
        return len(self.directives) + len(self.partitions)

    def ops_for(self, src: str, dst: str, nth: int) -> List[FaultDirective]:
        return self._by_link.get((src, dst, nth), [])

    def partitioned(self, src: str, dst: str, tick: int) -> bool:
        return any(
            p.src == src and p.dst == dst and p.t0 <= tick < p.t1
            for p in self.partitions
        )

    def as_dict(self) -> dict:
        return {
            "directives": [fd.as_dict() for fd in self.directives],
            "partitions": [p.as_dict() for p in self.partitions],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TransportFaults":
        return cls(
            directives=[FaultDirective.from_dict(x)
                        for x in d.get("directives", ())],
            partitions=[Partition.from_dict(x)
                        for x in d.get("partitions", ())],
        )


# ---------------------------------------------------------------------------
# Message payloads (the serving plane's wire vocabulary)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Submit:
    """Dispatch one copy of a request onto a replica. ``attempt`` makes
    the copy key ``(gid, attempt)`` globally unique across hedges,
    retries, and migrations — the receiver's idempotency key."""

    gid: int
    attempt: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival: float
    deadline_budget: Optional[float]   # per-attempt vtime budget (None = none)


@dataclasses.dataclass(frozen=True)
class Cancel:
    """Tear down a copy (hedged loser, zombie migration)."""

    gid: int
    attempt: int


@dataclasses.dataclass(frozen=True)
class Chunk:
    """A position-addressed slice of one copy's token stream:
    ``tokens[i]`` is stream position ``start + i``. Position addressing
    makes chunk application idempotent and order-free — duplicates
    rewrite the same cells, reordered chunks fill different cells, and
    the stream is complete when positions ``0..total-1`` are present and
    a ``done`` chunk supplied ``total``."""

    gid: int
    attempt: int
    start: int
    tokens: Tuple[int, ...]
    done: bool = False
    total: Optional[int] = None        # stream length (done chunks only)
    elapsed: Optional[float] = None    # replica-local service time (done only)


@dataclasses.dataclass(frozen=True)
class Expired:
    """A copy's per-attempt deadline fired replica-side; ``tokens`` is
    the full partial prefix so the frontend can requeue from it."""

    gid: int
    attempt: int
    tokens: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Ticket:
    """A migration ticket in flight to its destination replica.
    ``remaining_deadline`` carries the deadline budget left on the
    SOURCE clock (absolute deadlines are clock-local); ``elapsed`` is
    the service time already accrued, so the destination's completion
    telemetry prices the whole request, not just its own share."""

    gid: int
    attempt: int
    ticket: Any                        # engine.MigrationTicket (sealed)
    remaining_deadline: Optional[float]
    elapsed: float


@dataclasses.dataclass(frozen=True)
class TicketReply:
    """Destination's verdict on a Ticket: ``ok`` (imported, decoding
    resumes), ``busy`` (no slot/blocks — try another peer), or
    ``corrupt`` (integrity checksum failed — reject-and-requeue)."""

    gid: int
    attempt: int
    status: str                        # "ok" | "busy" | "corrupt"


@dataclasses.dataclass(frozen=True)
class Ack:
    msg_id: int


@dataclasses.dataclass
class WireMessage:
    msg_id: int
    src: str
    dst: str
    kind: str                          # payload class name, lowercased
    payload: Any
    needs_ack: bool = True
    corrupted: bool = False            # in-flight mutation happened


def _corrupt_in_flight(msg: WireMessage) -> Optional[WireMessage]:
    """Mutate a payload the way a link CRC cannot catch. Only migration
    tickets are end-to-end payloads here (they transit DMA/storage paths
    between meshes); everything else returns None = "the link CRC saw
    it" and the caller drops the frame instead."""
    if msg.kind != "ticket":
        return None
    p: Ticket = msg.payload
    t = p.ticket
    # Flip the resume token: the single most dangerous corruption — a
    # byte-plausible ticket whose greedy continuation silently diverges.
    bad = dataclasses.replace(
        t,
        pending=int(t.pending) ^ 1,
        tokens=tuple(t.tokens[:-1]) + ((t.tokens[-1] ^ 1),) if t.tokens
        else t.tokens,
    )
    out = dataclasses.replace(msg, payload=dataclasses.replace(p, ticket=bad))
    out.corrupted = True
    return out


# ---------------------------------------------------------------------------
# Channels + the reliability fabric
# ---------------------------------------------------------------------------

class Channel:
    """One direction of one link. Applies the fault plan per
    transmission and delivers in deterministic ``(deliver_tick,
    order_key)`` order. No RNG — faults are the plan's explicit
    directives, nothing else."""

    def __init__(self, src: str, dst: str, faults: TransportFaults):
        self.src, self.dst = src, dst
        self.faults = faults
        self.n_sent = 0                # transmissions attempted (incl. retx)
        self.n_dropped = 0
        self.n_duplicated = 0
        self.n_delayed = 0
        self.n_corrupted = 0
        self._order = 0
        self._heap: List[Tuple[int, int, int, WireMessage]] = []
        self._tiebreak = 0

    def transmit(self, msg: WireMessage, tick: int) -> None:
        nth = self.n_sent
        self.n_sent += 1
        if self.faults.partitioned(self.src, self.dst, tick):
            self.n_dropped += 1
            return
        copies, delay, order_bump, dropped = 1, 0, 0, False
        out = msg
        for fd in self.faults.ops_for(self.src, self.dst, nth):
            if fd.op == "drop":
                dropped = True
            elif fd.op == "dup":
                copies += 1
                self.n_duplicated += 1
            elif fd.op == "delay":
                delay += max(fd.ticks, 1)
                self.n_delayed += 1
            elif fd.op == "reorder":
                order_bump += max(fd.ticks, 2)
            elif fd.op == "corrupt":
                mutated = _corrupt_in_flight(out)
                if mutated is None:
                    dropped = True        # link CRC caught it = loss
                else:
                    out = mutated
                    self.n_corrupted += 1
        if dropped:                       # drop dominates dup/delay/reorder
            self.n_dropped += 1
            return
        for _ in range(copies):
            self._order += 1
            self._tiebreak += 1
            heapq.heappush(
                self._heap,
                (tick + delay, self._order + order_bump, self._tiebreak, out),
            )

    def deliverable(self, tick: int) -> bool:
        return bool(self._heap) and self._heap[0][0] <= tick

    def next_deliver_tick(self) -> Optional[int]:
        return self._heap[0][0] if self._heap else None

    def receive(self, tick: int) -> List[WireMessage]:
        out = []
        while self._heap and self._heap[0][0] <= tick:
            out.append(heapq.heappop(self._heap)[3])
        return out

    def clear(self) -> int:
        n = len(self._heap)
        self._heap.clear()
        return n


@dataclasses.dataclass
class _Pending:
    msg: WireMessage
    attempt: int
    due_tick: int


class TransportGaveUp(RuntimeError):
    """A reliable message exhausted its retransmission budget — the
    destination is unreachable beyond anything the fault plan heals.
    Surfaced as a liveness violation by the chaos harness."""


class Transport:
    """The fabric: channels both ways between ``fe`` and every replica,
    plus the at-least-once layer (acks, dedup, deterministic
    retransmission with telemetry-priced timeouts).

    ``rto_scale(dst)`` supplies the per-destination slowdown estimate —
    the frontend wires it to the router's censored telemetry, so
    retransmit budgets track the same order-statistic view of the fleet
    every other scheduling decision prices against. ``reliable=False``
    turns the whole layer fire-and-forget and ``dedup=False`` redelivers
    duplicates — chaos-search knobs that exist so the harness can show
    the invariants FAILING without them."""

    def __init__(
        self,
        n_replicas: int,
        faults: Optional[TransportFaults] = None,
        *,
        reliable: bool = True,
        dedup: bool = True,
        base_rto_ticks: int = 16,
        backoff: float = 2.0,
        max_rto_ticks: int = 512,
        max_attempts: int = 24,
        rto_scale: Optional[Callable[[str], float]] = None,
        obs: Optional[Observability] = None,
    ):
        self.faults = faults or TransportFaults()
        self.reliable = bool(reliable)
        self.dedup = bool(dedup)
        self.base_rto_ticks = int(base_rto_ticks)
        self.backoff = float(backoff)
        self.max_rto_ticks = int(max_rto_ticks)
        self.max_attempts = int(max_attempts)
        self.rto_scale = rto_scale or (lambda dst: 1.0)
        self.endpoints = [FE] + [replica_endpoint(i) for i in range(n_replicas)]
        self.channels: Dict[Tuple[str, str], Channel] = {}
        for i in range(n_replicas):
            r = replica_endpoint(i)
            self.channels[(FE, r)] = Channel(FE, r, self.faults)
            self.channels[(r, FE)] = Channel(r, FE, self.faults)
        self._next_msg_id = 0
        self._unacked: Dict[int, _Pending] = {}
        self._seen: Dict[Tuple[str, str], set] = {
            link: set() for link in self.channels
        }
        self._dead: set = set()
        self.gave_up = 0
        self.obs = obs or NULL_OBS
        m = self.obs.metrics
        self._m_sent = m.counter("transport.sent")
        self._m_delivered = m.counter("transport.delivered")
        self._m_retx = m.counter("transport.retransmits")
        self._m_dedup = m.counter("transport.deduped")
        self._m_acked = m.counter("transport.acked")

    # -- sending -------------------------------------------------------------
    def send(
        self, src: str, dst: str, payload: Any, tick: int,
        *, needs_ack: bool = True,
    ) -> int:
        """Send ``payload`` from ``src`` to ``dst``; returns the message
        id. Reliable messages are tracked until acked; sends to a dead
        endpoint are dropped silently (the chaos plane already told us
        nobody is listening)."""
        kind = type(payload).__name__.lower()
        msg = WireMessage(self._next_msg_id, src, dst, kind, payload,
                          needs_ack=needs_ack and self.reliable)
        self._next_msg_id += 1
        self._m_sent.inc()
        if dst in self._dead:
            return msg.msg_id
        self.channels[(src, dst)].transmit(msg, tick)
        if msg.needs_ack:
            self._unacked[msg.msg_id] = _Pending(
                msg, 0, tick + self._rto(dst, 0)
            )
        return msg.msg_id

    def _rto(self, dst: str, attempt: int) -> int:
        base = self.base_rto_ticks * max(1.0, float(self.rto_scale(dst)))
        return min(int(base * self.backoff ** attempt) + 1, self.max_rto_ticks)

    def pump(self, tick: int) -> None:
        """Retransmit every overdue unacked message (deterministic order:
        by message id)."""
        if not self.reliable:
            return
        for mid in sorted(self._unacked):
            p = self._unacked[mid]
            if p.due_tick > tick:
                continue
            if p.msg.dst in self._dead:
                del self._unacked[mid]
                continue
            p.attempt += 1
            if p.attempt > self.max_attempts:
                del self._unacked[mid]
                self.gave_up += 1
                raise TransportGaveUp(
                    f"message {mid} ({p.msg.kind} {p.msg.src}->{p.msg.dst}) "
                    f"unacked after {self.max_attempts} attempts"
                )
            self._m_retx.inc()
            self.channels[(p.msg.src, p.msg.dst)].transmit(p.msg, tick)
            p.due_tick = tick + self._rto(p.msg.dst, p.attempt)

    # -- receiving -----------------------------------------------------------
    def receive(self, dst: str, tick: int) -> List[WireMessage]:
        """Drain every deliverable message addressed to ``dst``: strips
        acks, dedups (re-acking, so a lost ack converges), acks fresh
        data messages, and returns the application payloads in
        deterministic delivery order."""
        out: List[WireMessage] = []
        for (src, d), ch in self.channels.items():
            if d != dst or not ch.deliverable(tick):
                continue
            seen = self._seen[(src, d)]
            for msg in ch.receive(tick):
                if msg.kind == "ack":
                    self._unacked.pop(msg.payload.msg_id, None)
                    self._m_acked.inc()
                    continue
                if self.dedup and msg.msg_id in seen:
                    self._m_dedup.inc()
                    if msg.needs_ack:
                        self._send_ack(dst, src, msg.msg_id, tick)
                    continue
                seen.add(msg.msg_id)
                if msg.needs_ack:
                    self._send_ack(dst, src, msg.msg_id, tick)
                self._m_delivered.inc()
                out.append(msg)
        return out

    def _send_ack(self, src: str, dst: str, msg_id: int, tick: int) -> None:
        if dst in self._dead:
            return
        msg = WireMessage(self._next_msg_id, src, dst, "ack", Ack(msg_id),
                          needs_ack=False)
        self._next_msg_id += 1
        self.channels[(src, dst)].transmit(msg, tick)

    # -- liveness / progress -------------------------------------------------
    def deliverable(self, dst: str, tick: int) -> bool:
        return any(
            ch.deliverable(tick)
            for (s, d), ch in self.channels.items() if d == dst
        )

    def busy(self) -> bool:
        """Anything still in flight or awaiting ack? The frontend's run
        loop drains the fabric before declaring the plane quiescent —
        un-delivered cancels would otherwise leak slots."""
        return bool(self._unacked) or any(
            ch.next_deliver_tick() is not None for ch in self.channels.values()
        )

    def next_event_tick(self) -> Optional[int]:
        """Earliest tick at which the fabric will do something on its
        own (a delayed delivery lands, a retransmit fires) — the run
        loop jumps here when every replica is idle."""
        ticks = [t for ch in self.channels.values()
                 if (t := ch.next_deliver_tick()) is not None]
        if self.reliable:
            ticks.extend(p.due_tick for p in self._unacked.values()
                         if p.msg.dst not in self._dead)
        return min(ticks, default=None)

    # -- chaos control plane -------------------------------------------------
    def forget_endpoint(self, ep: str) -> None:
        """An endpoint died: every queued message to/from it vanishes
        with the process, every pending retransmit to it is abandoned,
        and its dedup history is wiped (a rejoin is a fresh process)."""
        self._dead.add(ep)
        for (src, dst), ch in self.channels.items():
            if src == ep or dst == ep:
                ch.clear()
                self._seen[(src, dst)].clear()
        for mid in [m for m, p in self._unacked.items()
                    if p.msg.dst == ep or p.msg.src == ep]:
            del self._unacked[mid]

    def revive_endpoint(self, ep: str) -> None:
        self._dead.discard(ep)

    # -- reporting -----------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        agg = {"sent": 0, "dropped": 0, "duplicated": 0, "delayed": 0,
               "corrupted": 0}
        for ch in self.channels.values():
            agg["sent"] += ch.n_sent
            agg["dropped"] += ch.n_dropped
            agg["duplicated"] += ch.n_duplicated
            agg["delayed"] += ch.n_delayed
            agg["corrupted"] += ch.n_corrupted
        agg["unacked"] = len(self._unacked)
        agg["gave_up"] = self.gave_up
        return agg
