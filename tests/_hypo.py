"""Hypothesis shim: property tests without a hard hypothesis dependency.

Re-exports the real library when installed (pip install -r
requirements-dev.txt). Otherwise provides a seeded-random fallback
implementing the tiny subset the test suite uses — ``@given`` with
``st.integers`` / ``st.floats`` strategies and ``@settings`` — so tier-1
collects and runs with only pytest + jax. The fallback draws
``max_examples`` pseudo-random cases from a per-test deterministic seed:
weaker than hypothesis (no shrinking, no edge-case bias) but the same
property checks.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(max_examples=25, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f

        return deco

    def given(**strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper():
                # @settings may wrap us afterwards; read the attribute off
                # the surviving function object at call time.
                n = getattr(wrapper, "_max_examples", 25)
                rng = random.Random(zlib.crc32(f.__name__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    f(**drawn)

            # pytest resolves fixture names through __wrapped__'s
            # signature; the strategy params are not fixtures.
            del wrapper.__wrapped__
            return wrapper

        return deco
