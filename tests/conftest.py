"""Shared test setup.

Must run before ANY jax import: jax locks the device count on first
backend initialization, and the mesh/sharding tests (make_test_mesh,
constrain_batch under a real mesh) need multiple devices on CPU-only CI.
The subprocess-based tests (test_sharding_and_cost, test_pipeline_parallel)
set their own XLA_FLAGS in the child process and are unaffected.
"""

import os

_FLAG = "--xla_force_host_platform_device_count=8"

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG
    ).strip()
