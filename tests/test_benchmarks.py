"""Light checks on the benchmark plumbing (no figure runs)."""

import json
import time

import numpy as np


def test_timer_uses_perf_counter(monkeypatch):
    from benchmarks.common import Timer

    # time.time is frozen; a monotonic perf_counter-based Timer still
    # measures elapsed wall clock.
    monkeypatch.setattr(time, "time", lambda: 0.0)
    with Timer() as t:
        time.sleep(0.01)
    assert t.elapsed > 0.0


def test_bench_index_requires_registered_files(tmp_path):
    from benchmarks.common import write_bench_index, write_bench_json

    write_bench_json(str(tmp_path / "BENCH_a.json"),
                     {"benchmark": "a", "mode": "fast"})

    # Unrequired files index best-effort; extras are fine.
    idx = write_bench_index(str(tmp_path))
    assert [e["file"] for e in idx["benchmarks"]] == ["BENCH_a.json"]
    assert (tmp_path / "BENCH_index.json").exists()

    # A registered bench whose JSON is missing fails loudly.
    try:
        write_bench_index(str(tmp_path),
                          required=("BENCH_a.json", "BENCH_b.json"))
        raise AssertionError("missing required bench did not raise")
    except RuntimeError as e:
        assert "BENCH_b.json: missing" in str(e)

    # ... and so does a corrupt one (silent skip would drop it).
    (tmp_path / "BENCH_b.json").write_text("{not json")
    try:
        write_bench_index(str(tmp_path), required=("BENCH_b.json",))
        raise AssertionError("corrupt required bench did not raise")
    except RuntimeError as e:
        assert "BENCH_b.json: unreadable" in str(e)

    # Unrequired corrupt files still skip quietly (best-effort index).
    idx = write_bench_index(str(tmp_path))
    assert [e["file"] for e in idx["benchmarks"]] == ["BENCH_a.json"]


def test_run_jsonable_roundtrip():
    from benchmarks.run import _jsonable

    payload = {
        "f": np.float64(1.5),
        "i": np.int64(3),
        "arr": np.array([1.0, 2.0]),
        "inf": float("inf"),
        "nan": float("nan"),
        "tup": (1, (2, 3)),
        "stage": None,
    }
    out = _jsonable(payload)
    text = json.dumps(out)  # must be strictly serializable
    back = json.loads(text)
    assert back["f"] == 1.5 and back["i"] == 3
    assert back["arr"] == [1.0, 2.0]
    assert back["inf"] == "inf" and back["nan"] == "nan"
    assert back["tup"] == [1, [2, 3]]
    assert back["stage"] is None
