"""Light checks on the benchmark plumbing (no figure runs)."""

import json
import time

import numpy as np


def test_timer_uses_perf_counter(monkeypatch):
    from benchmarks.common import Timer

    # time.time is frozen; a monotonic perf_counter-based Timer still
    # measures elapsed wall clock.
    monkeypatch.setattr(time, "time", lambda: 0.0)
    with Timer() as t:
        time.sleep(0.01)
    assert t.elapsed > 0.0


def test_run_jsonable_roundtrip():
    from benchmarks.run import _jsonable

    payload = {
        "f": np.float64(1.5),
        "i": np.int64(3),
        "arr": np.array([1.0, 2.0]),
        "inf": float("inf"),
        "nan": float("nan"),
        "tup": (1, (2, 3)),
        "stage": None,
    }
    out = _jsonable(payload)
    text = json.dumps(out)  # must be strictly serializable
    back = json.loads(text)
    assert back["f"] == 1.5 and back["i"] == 3
    assert back["arr"] == [1.0, 2.0]
    assert back["inf"] == "inf" and back["nan"] == "nan"
    assert back["tup"] == [1, [2, 3]]
    assert back["stage"] is None
