"""Chaos-search harness: campaign passes, violations found + shrunk,
repros replay deterministically.

Three layers of pins:

1. With the reliability layer ON, sampled chaos schedules pass every
   oracle (a slice of the CI campaign, same code path).
2. With retransmission or dedup deliberately disabled, the harness
   FINDS the violation the layer exists to prevent, shrinks it to a
   single fault atom, and the minimal schedule replays bit-identically.
3. Regression schedules for real bugs the harness caught during
   development stay green (the whole point of minimal repros).
"""

import json
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.chaos_search import (          # noqa: E402
    Schedule,
    Workload,
    replay_repro,
    run_schedule,
    sample_schedule,
    shrink,
    write_repro,
)

from repro.runtime.faults import FaultEvent                 # noqa: E402
from repro.serve import FaultDirective, Partition           # noqa: E402

KNOBS = {"max_ticks": 6_000}


@pytest.fixture(scope="module")
def wl():
    return Workload(n_requests=4)


# ---------------------------------------------------------------------------
# 1. The reliable plane passes sampled campaigns
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sampled_schedules_pass_all_oracles(wl):
    for i in range(6):
        sched = sample_schedule(np.random.default_rng([0, i]))
        report = run_schedule(wl, sched, **KNOBS)
        assert report.ok, (i, sched.as_dict(), report.violations)


def test_schedule_json_roundtrip():
    sched = sample_schedule(np.random.default_rng([7, 7]))
    back = Schedule.from_dict(json.loads(json.dumps(sched.as_dict())))
    assert back.as_dict() == sched.as_dict()
    assert back.size() == sched.size()


# ---------------------------------------------------------------------------
# 2. Disabling the at-least-once layer is FOUND, shrunk, and replayable
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_unreliable_drop_found_and_shrunk_to_one_atom(wl):
    """A single dropped Submit strands the singleton-dispatch plane when
    retransmission is off — and ddmin strips the noise atoms down to
    exactly that drop. The same schedule is absorbed with the layer on."""
    sched = Schedule(
        events=[FaultEvent(step=40, kind="slow", worker=2, factor=2.0)],
        directives=[
            FaultDirective("fe", "r0", "drop", 0),
            FaultDirective("r1", "fe", "delay", 50, ticks=3),
        ],
        partitions=[Partition("r2", "fe", 200, 210)],
        cost_per_replica=10.0,
    )
    report = run_schedule(wl, sched, reliable=False, **KNOBS)
    assert report.signature() == ("liveness",)

    small = shrink(wl, sched, report.signature(), reliable=False, **KNOBS)
    assert small.size() == 1
    assert small.directives and small.directives[0].op == "drop"

    # minimal repro replays deterministically
    a = run_schedule(wl, small, reliable=False, **KNOBS)
    b = run_schedule(wl, small, reliable=False, **KNOBS)
    assert a.signature() == b.signature() == ("liveness",)

    # the reliability layer absorbs the full schedule
    assert run_schedule(wl, sched, **KNOBS).ok


def test_no_dedup_duplicate_admission_found(wl):
    """A duplicated Submit double-admits on the receiving engine when
    receiver dedup is off — caught by the exactly-once oracle via the
    port's god's-eye admission log."""
    sched = Schedule(
        events=[],
        directives=[FaultDirective("fe", "r0", "dup", 0)],
        partitions=[],
        cost_per_replica=0.001,
    )
    report = run_schedule(wl, sched, dedup=False, **KNOBS)
    assert report.signature() == ("exactly_once",)
    assert run_schedule(wl, sched, **KNOBS).ok


def test_corrupt_ticket_rejected_and_requeued(wl):
    """In-flight ticket corruption survives the link CRC but not the
    end-to-end checksum: the dest rejects, the frontend requeues from
    the intact prefix, and every oracle still holds."""
    sched = Schedule(
        events=[FaultEvent(step=9, kind="drain", worker=1)],
        directives=[FaultDirective("fe", "r0", "corrupt", 8)],
        partitions=[],
        cost_per_replica=10.0,
    )
    report = run_schedule(wl, sched, **KNOBS)
    assert report.ok, report.violations
    assert report.summary["ticket_rejects"] == 1
    assert report.summary["migrations"] == 0


@pytest.mark.slow
def test_leak_blocks_found_and_shrunk_to_one_atom(wl):
    """The seeded cancel-path refcount bug (--leak-blocks) drops one
    arena block per cancel without freeing it. Under singleton dispatch
    the ONLY cancels come from node failure, so the block-conservation
    oracle trips exactly on cancel-bearing schedules and ddmin strips
    every noise atom down to the one fail event."""
    sched = Schedule(
        events=[
            FaultEvent(step=8, kind="fail", worker=1),
            FaultEvent(step=70, kind="rejoin", worker=1),
            FaultEvent(step=40, kind="slow", worker=2, factor=2.0),
        ],
        directives=[FaultDirective("r1", "fe", "delay", 50, ticks=3)],
        partitions=[],
        cost_per_replica=10.0,
    )
    report = run_schedule(wl, sched, leak_blocks=True, **KNOBS)
    assert "block_conservation" in report.signature()

    small = shrink(wl, sched, report.signature(), leak_blocks=True, **KNOBS)
    assert small.size() == 1
    assert small.events and small.events[0].kind == "fail"

    # minimal repro replays deterministically
    a = run_schedule(wl, small, leak_blocks=True, **KNOBS)
    b = run_schedule(wl, small, leak_blocks=True, **KNOBS)
    assert a.signature() == b.signature() == report.signature()

    # with the bug unseeded the same schedule passes every oracle,
    # including block_conservation
    assert run_schedule(wl, sched, **KNOBS).ok


def test_leak_blocks_knob_roundtrips_repro(tmp_path, wl):
    """A --leak-blocks repro JSON must carry the knob: replaying it
    without re-arming the seeded bug would vacuously pass."""
    sched = Schedule(
        events=[FaultEvent(step=8, kind="fail", worker=1)],
        directives=[], partitions=[], cost_per_replica=10.0,
    )
    knobs = {"reliable": True, "dedup": True, "retry_budget": 8,
             "max_ticks": 6_000, "leak_blocks": True}
    report = run_schedule(wl, sched, **knobs)
    assert "block_conservation" in report.signature()
    path = str(tmp_path / "repro_leak.json")
    write_repro(path, seed=0, index=0, wl=wl, sched=sched, report=report,
                knobs=knobs)
    assert json.load(open(path))["knobs"]["leak_blocks"] is True
    assert replay_repro(path).signature() == report.signature()


@pytest.mark.slow
def test_sharing_fleet_passes_sampled_schedules():
    """The COW ledger holds under chaos: sampled schedules on a
    prefix-sharing fleet (shared-prefix workload, hedged and singleton
    dispatch both drawn) pass every oracle including conservation."""
    swl = Workload(n_requests=4, prefix_sharing=True)
    for i in range(4):
        sched = sample_schedule(np.random.default_rng([3, i]))
        report = run_schedule(swl, sched, **KNOBS)
        assert report.ok, (i, sched.as_dict(), report.violations)


def test_repro_file_roundtrip(tmp_path, wl):
    sched = Schedule(
        events=[], partitions=[], cost_per_replica=10.0,
        directives=[FaultDirective("fe", "r0", "drop", 0)],
    )
    knobs = {"reliable": False, "dedup": True, "retry_budget": 8,
             "max_ticks": 6_000}
    report = run_schedule(wl, sched, **knobs)
    assert report.signature() == ("liveness",)
    path = str(tmp_path / "repro.json")
    write_repro(path, seed=0, index=0, wl=wl, sched=sched, report=report,
                knobs=knobs)
    replayed = replay_repro(path)
    assert replayed.signature() == report.signature()


# ---------------------------------------------------------------------------
# 3. Regression repros for real bugs the harness caught
# ---------------------------------------------------------------------------

def test_regression_drain_chunk_race_stays_clean(wl):
    """A chunk racing its copy's migration export used to be dropped as
    stale, leaving a permanent hole in the stream buffer (the ticket's
    prefix now backfills the attempt buffer at export)."""
    sched = Schedule(
        events=[FaultEvent(step=9, kind="drain", worker=1, factor=3.904)],
        directives=[], partitions=[], cost_per_replica=10.0,
    )
    assert run_schedule(wl, sched, **KNOBS).ok


def test_regression_ticket_not_offered_to_hosting_replica(wl):
    """Offering a migration ticket to a replica already hosting a hedged
    copy of the same request used to orphan that copy's router slot
    (``fr.copies`` is keyed by replica)."""
    sched = Schedule(
        events=[FaultEvent(step=4, kind="drain", worker=2, factor=2.583)],
        directives=[], partitions=[], cost_per_replica=0.001,
    )
    assert run_schedule(wl, sched, **KNOBS).ok
