"""Unit + property tests for the paper's math: order statistics (Prop. 1 /
Thm. 5), error model (Eq. 1/10), switching times (Thm. 2), beta* (Thm. 3 /
Cor. 4)."""

import math

import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or seeded fallback

from repro.core import (
    GeneralizedDelayModel,
    SGDHyperParams,
    SimplifiedDelayModel,
    beta_min_for,
    cor4_beta,
    error_after,
    error_floor,
    evaluate_schedule,
    expected_kth,
    expected_kth_derivative,
    harmonic_tail,
    numerical_beta,
    switching_interval,
    time_to_error,
    StrategyConfig,
)
from repro.core.order_stats import thm5_quadruple_sum


# ---------------------------------------------------------------------------
# Order statistics
# ---------------------------------------------------------------------------

def test_prop1_closed_form():
    m = SimplifiedDelayModel(lambda_y=2.0, x=0.3, y=0.1)
    # mu = (beta/lambda) * H(n,k) + x + y
    got = expected_kth(m, n=10, k=3, beta=0.5)
    H = sum(1.0 / j for j in range(8, 11))
    assert got == pytest.approx(0.25 * H + 0.4)


def test_simplified_matches_monte_carlo():
    m = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    rng = np.random.default_rng(0)
    n, k, beta = 20, 7, 0.6
    samples = np.sort(m.sample(rng, 100_000 * n, beta).reshape(-1, n), axis=1)
    assert expected_kth(m, n, k, beta) == pytest.approx(
        samples[:, k - 1].mean(), rel=2e-2
    )


def test_thm5_quadruple_sum_matches_quadrature():
    g = GeneralizedDelayModel(lambda_x=3.0, lambda_y=1.0, x=0.1, y=0.05)
    for (n, k, b) in [(6, 2, 0.5), (8, 3, 0.4), (10, 10, 1.0)]:
        assert expected_kth(g, n, k, b) == pytest.approx(
            thm5_quadruple_sum(g, n, k, b), rel=1e-6
        )


def test_generalized_matches_monte_carlo():
    g = GeneralizedDelayModel(lambda_x=2.0, lambda_y=0.5, x=0.2, y=0.1)
    rng = np.random.default_rng(1)
    n, k, beta = 50, 17, 0.3
    samples = np.sort(g.sample(rng, 60_000 * n, beta).reshape(-1, n), axis=1)
    assert expected_kth(g, n, k, beta) == pytest.approx(
        samples[:, k - 1].mean(), rel=2e-2
    )


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(2, 60),
    k=st.integers(1, 60),
    beta=st.floats(0.05, 1.0),
    lam=st.floats(0.05, 20.0),
    x=st.floats(0.0, 20.0),
)
def test_order_stats_monotonicity(n, k, beta, lam, x):
    """mu_{k:n} increases in k, decreases in n, increases in beta."""
    k = min(k, n)
    m = SimplifiedDelayModel(lambda_y=lam, x=x)
    mu = expected_kth(m, n, k, beta)
    assert mu >= x
    if k < n:
        assert expected_kth(m, n, k + 1, beta) > mu
    assert expected_kth(m, n + 1, k, beta) < mu
    if beta < 0.9:
        assert expected_kth(m, n, k, min(beta + 0.1, 1.0)) > mu


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 30),
    k=st.integers(1, 30),
    beta=st.floats(0.1, 1.0),
    lx=st.floats(0.1, 10.0),
    ly=st.floats(0.1, 10.0),
)
def test_generalized_dominates_simplified_shift(n, k, beta, lx, ly):
    """Adding an exponential comm component can only slow responses."""
    k = min(k, n)
    g = GeneralizedDelayModel(lambda_x=lx, lambda_y=ly, x=0.0, y=0.0)
    s = SimplifiedDelayModel(lambda_y=ly, x=0.0, y=0.0)
    assert expected_kth(g, n, k, beta) > expected_kth(s, n, k, beta)


# ---------------------------------------------------------------------------
# Error model + switching
# ---------------------------------------------------------------------------

HP = SGDHyperParams(eta=0.01, L=2.0, sigma_grad2=10.0, c=1.0, s=20)


def test_error_floor_scaling():
    assert error_floor(HP, 2.0) == pytest.approx(error_floor(HP, 1.0) / 2)


def test_error_after_converges_to_floor():
    fl = error_floor(HP, 1.0)
    assert error_after(HP, 1.0, 10.0, 10_000) == pytest.approx(fl, rel=1e-6)


def test_time_to_error_roundtrip():
    fl = error_floor(HP, 1.0)
    target = fl * 2
    t = time_to_error(HP, 1.0, mu=0.5, e0=10.0, target=target)
    iters = t / 0.5
    assert error_after(HP, 1.0, 10.0, iters) == pytest.approx(target, rel=1e-9)
    assert time_to_error(HP, 1.0, 0.5, 10.0, fl * 0.5) == math.inf


def test_switching_interval_positive_and_zero_cases():
    m = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    mu1 = expected_kth(m, 20, 1, 1.0)
    mu2 = expected_kth(m, 20, 2, 1.0)
    dt = switching_interval(
        HP, phi_cur=1.0, mu_cur=mu1, phi_next=2.0, mu_next=mu2, gap_start=10.0
    )
    assert dt > 0
    # At the floor there is nothing left to gain: switch immediately.
    fl = error_floor(HP, 1.0)
    dt0 = switching_interval(
        HP, phi_cur=1.0, mu_cur=mu1, phi_next=2.0, mu_next=mu2, gap_start=fl * 0.99
    )
    assert dt0 == 0.0


@settings(max_examples=40, deadline=None)
@given(
    gap=st.floats(0.05, 100.0),
    lam=st.floats(0.1, 10.0),
    x=st.floats(0.001, 10.0),
    k=st.integers(1, 18),
)
def test_switching_interval_nonnegative(gap, lam, x, k):
    m = SimplifiedDelayModel(lambda_y=lam, x=x)
    mu1 = expected_kth(m, 20, k, 1.0)
    mu2 = expected_kth(m, 20, k + 1, 1.0)
    dt = switching_interval(
        HP, phi_cur=float(k), mu_cur=mu1, phi_next=float(k + 1), mu_next=mu2,
        gap_start=gap,
    )
    assert dt >= 0.0 and math.isfinite(dt)


# ---------------------------------------------------------------------------
# beta* (Thm. 3 / Cor. 4)
# ---------------------------------------------------------------------------

def test_cor4_matches_numerical_grid():
    """The closed form must agree with brute-force maximization of O."""
    m = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    for (n, s, k_cur, k_next) in [(20, 20, 1, 2), (20, 20, 3, 4), (50, 40, 5, 6)]:
        closed = cor4_beta(m, n, k_cur, 1.0, k_next, s)
        brute = numerical_beta(m, n, k_cur, 1.0, k_next, s)
        assert closed == pytest.approx(brute, abs=1.0 / s + 1e-9)


def test_beta_min_guarantees_phi_growth():
    for (k_cur, k_next, s) in [(1, 2, 20), (3, 4, 20), (9, 10, 5)]:
        bmin = beta_min_for(k_cur, 1.0, k_next, s)
        assert k_next * bmin > k_cur * 1.0 - 1e-12


@settings(max_examples=40, deadline=None)
@given(
    k_cur=st.integers(1, 15),
    lam=st.floats(0.05, 20.0),
    x=st.floats(0.0, 20.0),
)
def test_cor4_beta_feasible(k_cur, lam, x):
    m = SimplifiedDelayModel(lambda_y=lam, x=x)
    n, s = 20, 20
    b = cor4_beta(m, n, k_cur, 1.0, k_cur + 1, s)
    bmin = beta_min_for(k_cur, 1.0, k_cur + 1, s)
    assert bmin - 1e-12 <= b <= 1.0
    assert (k_cur + 1) * b > k_cur  # phi strictly grows
    # Grid membership: multiple of 1/s.
    assert abs(b * s - round(b * s)) < 1e-6


def test_paper_insight_beta_drop_when_comp_dominates():
    """When computation dominates, the optimal next beta is < 1 (the
    paper's core claim). Under Def. 1 the CONSTANT comm time x cancels in
    mu_{tau+1} - mu_tau, so beta* is x-independent; the 'communication
    dominates -> keep beta = 1' regime requires Def. 2's random comm
    component (this asymmetry is exactly the paper's modeling point)."""
    comp_heavy = SimplifiedDelayModel(lambda_y=0.05, x=0.01)
    b_comp = numerical_beta(comp_heavy, 20, 2, 1.0, 3, 20)
    assert b_comp < 1.0
    # Def. 1: x plays no role in beta*.
    for x in (0.001, 1.0, 50.0):
        assert numerical_beta(
            SimplifiedDelayModel(lambda_y=1.0, x=x), 20, 2, 1.0, 3, 20
        ) == pytest.approx(b_comp if False else numerical_beta(
            SimplifiedDelayModel(lambda_y=1.0, x=0.001), 20, 2, 1.0, 3, 20
        ))
    # Def. 2 with dominant random communication: no gain from cutting
    # computation -> beta stays at 1.
    comm_heavy = GeneralizedDelayModel(lambda_x=0.05, lambda_y=20.0)
    assert numerical_beta(comm_heavy, 20, 2, 1.0, 3, 20) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Analytic schedule (theory roll-out)
# ---------------------------------------------------------------------------

def test_schedule_ours_never_slower_and_cheaper():
    """Across regimes: runtime(ours) <= runtime(adaptive-k), comp lower."""
    hp = SGDHyperParams(eta=0.01, L=2.0, sigma_grad2=10.0, c=1.0, s=20)
    for (lam, x) in [(0.05, 0.05), (1.0, 0.05), (20.0, 20.0), (0.05, 20.0)]:
        m = SimplifiedDelayModel(lambda_y=lam, x=x)
        ours = evaluate_schedule(
            StrategyConfig("adaptive_kbeta", n=50, s=20), m, hp,
            e0=10.0, target=1e-3,
        )
        ak = evaluate_schedule(
            StrategyConfig("adaptive_k", n=50, s=20), m, hp,
            e0=10.0, target=1e-3,
        )
        assert ours.reached and ak.reached
        assert ours.runtime <= ak.runtime * (1 + 1e-9)
        assert ours.comp_cost <= ak.comp_cost * (1 + 1e-9)
        # Communication can only grow (same result size, more iterations).
        assert ours.comm_cost >= ak.comm_cost * (1 - 1e-9)


def test_schedule_stages_monotone():
    hp = SGDHyperParams(eta=0.001, L=2.0, sigma_grad2=10.0, c=1.0, s=20)
    m = SimplifiedDelayModel(lambda_y=0.5, x=0.05)
    r = evaluate_schedule(
        StrategyConfig("adaptive_kbeta", n=20, s=20, k_max=10), m, hp,
        e0=20.0, target=1e-3,
    )
    phis = [st.k * st.beta for st in r.stages]
    assert all(b > a for a, b in zip(phis, phis[1:]))
    gaps = [st.gap_start for st in r.stages] + [r.stages[-1].gap_end]
    assert all(b <= a * (1 + 1e-9) for a, b in zip(gaps, gaps[1:]))
