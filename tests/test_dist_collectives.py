"""repro.dist package tests: the fastest-k masked step must be EXACTLY
the dense step run on the contributing workers (the paper's aggregation
equivalence), plus compression round-trip / error-feedback convergence
and the pure sharding-rule functions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.collectives import (
    check_worker_major,
    contributors,
    example_weights,
    masked_weighted_ce,
)
from repro.dist.compression import Int8Codec, ef_compress_tree
from repro.dist.sharding import (
    DEFAULT_RULES,
    PURE_DP_RULES,
    batch_pspec,
    logical_to_pspec,
)


# ---------------------------------------------------------------------------
# Fastest-k masked aggregation == dense-k reference
# ---------------------------------------------------------------------------

def _random_mask(rng, n, k):
    idx = rng.choice(n, size=k, replace=False)
    m = np.zeros(n, np.float32)
    m[idx] = 1.0
    return jnp.asarray(m)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [1, 3, 6])
def test_masked_loss_equals_dense_subset(seed, k):
    rng = np.random.default_rng(seed)
    n, bw, S, V = 6, 3, 5, 13
    B = n * bw
    logits = jnp.asarray(rng.normal(size=(B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)))
    mask = _random_mask(rng, n, k)

    loss_masked, denom_masked = masked_weighted_ce(logits, labels, None, mask)
    keep = np.repeat(np.asarray(mask) > 0, bw)
    loss_dense, denom_dense = masked_weighted_ce(
        logits[keep], labels[keep], None, None
    )
    assert float(loss_masked) == pytest.approx(float(loss_dense), rel=1e-6)
    assert float(denom_masked) == pytest.approx(float(denom_dense))
    assert float(denom_masked) == k * bw * S


@pytest.mark.parametrize("seed", [0, 7])
def test_masked_gradient_equals_dense_subset_gradient(seed):
    """End-to-end: parameter gradients of the masked step match the dense
    step restricted to the contributing workers, example for example."""
    from repro.configs import get_config
    from repro.models import build_model

    rng = np.random.default_rng(seed)
    n, bw, S = 4, 2, 16
    B = n * bw
    cfg = get_config("smollm-135m").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=64, max_seq_len=S,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed), dtype_override="float32")
    inputs = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)))
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)))
    mask = _random_mask(rng, n, k=2)

    def masked_loss(p):
        positions = jnp.arange(S)
        h, _ = model.hidden(p, inputs, positions)
        logits = model.logits(p, h)
        return masked_weighted_ce(logits, labels, None, mask)[0]

    keep = np.repeat(np.asarray(mask) > 0, bw)

    def dense_loss(p):
        positions = jnp.arange(S)
        h, _ = model.hidden(p, inputs[keep], positions)
        logits = model.logits(p, h)
        return masked_weighted_ce(logits, labels[keep], None, None)[0]

    g_masked = jax.grad(masked_loss)(params)
    g_dense = jax.grad(dense_loss)(params)
    for gm, gd in zip(jax.tree.leaves(g_masked), jax.tree.leaves(g_dense)):
        np.testing.assert_allclose(
            np.asarray(gm), np.asarray(gd), rtol=2e-4, atol=2e-6
        )


def test_masked_step_never_recompiles_across_masks():
    """The worker mask is data, not shape: one compiled program serves
    every fastest-k subset."""
    rng = np.random.default_rng(0)
    B, S, V, n = 8, 4, 11, 4
    logits = jnp.asarray(rng.normal(size=(B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)))

    traces = []

    @jax.jit
    def step(mask):
        traces.append(1)
        return masked_weighted_ce(logits, labels, None, mask)[0]

    for k in (1, 2, 3, 4):
        step(_random_mask(rng, n, k)).block_until_ready()
    assert len(traces) == 1


def test_example_weights_worker_major_layout():
    w = example_weights(jnp.array([0.0, 1.0, 1.0]), batch=6)
    np.testing.assert_array_equal(np.asarray(w), [0, 0, 1, 1, 1, 1])


def test_example_weights_rejects_ragged_batch():
    with pytest.raises(ValueError):
        example_weights(jnp.ones((3,)), batch=7)


def test_contributors_counts_mask():
    assert float(contributors(jnp.array([1.0, 0.0, 1.0, 1.0]))) == 3.0


def test_check_worker_major_contract():
    """Mask-vs-batch shape contract: the mask must be sized for the
    fleet that produced THIS batch."""
    assert check_worker_major(16, 4) == 4
    assert check_worker_major(16, 8) == 2
    # A stale larger-fleet batch against a shrunken fleet must fail loudly
    # instead of silently misassigning rows to the wrong workers.
    with pytest.raises(ValueError, match="not divisible"):
        check_worker_major(16, 3)
    with pytest.raises(ValueError, match="at least one"):
        check_worker_major(16, 0)


def test_example_weights_rejects_2d_mask():
    with pytest.raises(ValueError, match="1-D"):
        example_weights(jnp.ones((2, 2)), batch=8)


def test_masked_ce_with_token_mask_and_worker_mask():
    """Token masks compose with worker masks (both weights multiply)."""
    rng = np.random.default_rng(3)
    B, S, V, n = 4, 6, 9, 2
    logits = jnp.asarray(rng.normal(size=(B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)))
    tok = jnp.asarray((rng.random((B, S)) > 0.3).astype(np.float32))
    wm = jnp.array([1.0, 0.0])
    loss, denom = masked_weighted_ce(logits, labels, tok, wm)
    keep = np.repeat(np.asarray(wm) > 0, B // n)
    ref, ref_denom = masked_weighted_ce(logits[keep], labels[keep], tok[keep], None)
    assert float(loss) == pytest.approx(float(ref), rel=1e-6)
    assert float(denom) == pytest.approx(float(ref_denom))


# ---------------------------------------------------------------------------
# Int8 codec + error feedback
# ---------------------------------------------------------------------------

def test_int8_roundtrip_bounded_by_half_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 33)), jnp.float32)
    q, scale = Int8Codec.encode(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(Int8Codec.decode(q, scale)) - np.asarray(x)).max()
    assert err <= float(scale) * 0.5 + 1e-9


def test_int8_zero_tensor_is_exact():
    q, scale = Int8Codec.encode(jnp.zeros((17,)))
    assert float(scale) == 0.0
    np.testing.assert_array_equal(np.asarray(Int8Codec.decode(q, scale)), 0.0)


def test_ef_residual_is_exactly_the_quantization_error():
    x = {"a": jnp.asarray(np.random.default_rng(1).normal(size=(40,)), jnp.float32)}
    resid = {"a": jnp.zeros((40,))}
    dec, new_resid = ef_compress_tree(x, resid)
    np.testing.assert_allclose(
        np.asarray(dec["a"] + new_resid["a"]), np.asarray(x["a"]), rtol=1e-6
    )


def test_ef_compress_tree_structure_and_convergence():
    """EF-SGD on a quadratic reaches the uncompressed fixed point; the
    tree structure (nested dicts) is preserved leaf-for-leaf."""
    params = {"w": jnp.array([4.0, -2.0]), "nest": {"b": jnp.array([[1.0, -3.0]])}}
    resid = jax.tree.map(jnp.zeros_like, params)
    for _ in range(400):
        grads = jax.tree.map(lambda p: 2 * p, params)
        dec, resid = ef_compress_tree(grads, resid)
        assert jax.tree.structure(dec) == jax.tree.structure(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, dec)
    for leaf in jax.tree.leaves(params):
        assert float(jnp.abs(leaf).max()) < 1e-2


def test_ef_mismatched_trees_raise():
    with pytest.raises(ValueError):
        ef_compress_tree({"a": jnp.ones(3)}, {"a": jnp.ones(3), "b": jnp.ones(3)})


# ---------------------------------------------------------------------------
# Sharding rules (pure functions; no devices needed)
# ---------------------------------------------------------------------------

def _mesh_stub(shape_map):
    class M:
        shape = shape_map
    return M()


def test_pure_dp_rules_replicate_params_and_shard_batch():
    mesh = _mesh_stub({"data": 4, "model": 2})
    p = logical_to_pspec(("vocab", "embed"), (512, 64), mesh, PURE_DP_RULES)
    assert tuple(p) == ()
    b = logical_to_pspec(("act_batch", None), (8, 16), mesh, PURE_DP_RULES)
    assert b[0] == ("data", "model")  # pod absent; 8 % (4*2) == 0
    b2 = logical_to_pspec(("act_batch", None), (4, 16), mesh, PURE_DP_RULES)
    assert b2[0] == "data"  # 4 % 8 != 0 -> trailing model axis dropped


def test_batch_pspec_partial_and_trailing_dims():
    mesh = _mesh_stub({"pod": 2, "data": 4, "model": 2})
    p = batch_pspec(mesh, 16, 1)
    assert tuple(p) == (("pod", "data"), None)
    # 6 % (2*4) != 0 but 6 % 2 == 0: falls back to pod only.
    p2 = batch_pspec(mesh, 6, 1)
    assert tuple(p2) == ("pod", None)
    # Prime batch: fully replicated.
    assert tuple(batch_pspec(mesh, 7, 2)) == ()


def test_pipeline_forward_rejects_stage_mismatch():
    from repro.dist.pipeline_parallel import pipeline_forward, stage_params

    staged = stage_params(jnp.zeros((8, 4, 4)), 2)  # 2 stages
    mesh = _mesh_stub({"pipe": 4})                   # 4-way pipeline axis
    with pytest.raises(ValueError, match="leading dim"):
        pipeline_forward(lambda w, h: h, staged, jnp.zeros((6, 3, 4)), mesh)


def test_default_rules_never_reuse_mesh_axis():
    mesh = _mesh_stub({"data": 2, "model": 2})
    p = logical_to_pspec(
        ("expert", "embed", "expert_ffn"), (4, 64, 128), mesh, DEFAULT_RULES
    )
    assert p[0] == "model" and p[1] == "data"
    assert len(p) < 3 or p[2] is None
