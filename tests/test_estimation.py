"""Online delay-model estimation + controller integration (the oracle-free
production path)."""

import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or seeded fallback

from repro.core import (
    Controller,
    GeneralizedDelayModel,
    SimplifiedDelayModel,
    StrategyConfig,
    fit_generalized_mm,
    fit_simplified_mle,
    fit_simplified_mle_censored,
)


def test_mle_recovers_simplified_parameters():
    true = SimplifiedDelayModel(lambda_y=2.5, x=0.3)
    rng = np.random.default_rng(0)
    betas = np.repeat([0.2, 0.5, 1.0], 3000)
    zs = np.concatenate([
        true.sample(rng, 3000, 0.2),
        true.sample(rng, 3000, 0.5),
        true.sample(rng, 3000, 1.0),
    ])
    fit = fit_simplified_mle(zs, betas)
    assert fit.shift == pytest.approx(true.shift, abs=0.02)
    assert fit.lambda_y == pytest.approx(true.lambda_y, rel=0.1)


def test_mm_recovers_generalized_rates():
    true = GeneralizedDelayModel(lambda_x=4.0, lambda_y=1.5)
    rng = np.random.default_rng(1)
    betas = np.repeat([0.25, 0.5, 1.0], 20000)
    zs = np.concatenate([
        true.sample(rng, 20000, 0.25),
        true.sample(rng, 20000, 0.5),
        true.sample(rng, 20000, 1.0),
    ])
    fit = fit_generalized_mm(zs, betas)
    assert fit.lambda_x == pytest.approx(true.lambda_x, rel=0.15)
    assert fit.lambda_y == pytest.approx(true.lambda_y, rel=0.15)


@settings(max_examples=20, deadline=None)
@given(lam=st.floats(0.2, 10.0), x=st.floats(0.0, 5.0))
def test_mle_shift_never_exceeds_min_sample(lam, x):
    true = SimplifiedDelayModel(lambda_y=lam, x=x)
    rng = np.random.default_rng(42)
    z = true.sample(rng, 500, 0.7)
    fit = fit_simplified_mle(z, np.full(500, 0.7))
    assert fit.shift <= z.min() + 1e-12
    assert fit.lambda_y > 0


def _fastest_k_telemetry(true, rng, n, k, beta, rounds):
    """What a fastest-k loop actually sees: per round, the k smallest of
    n response times plus (n - k) workers censored at z_(k)."""
    zs, bs, cs = [], [], []
    for _ in range(rounds):
        z = np.sort(true.sample(rng, n, beta))[:k]
        c = np.zeros(k)
        c[-1] = n - k
        zs.append(z)
        bs.append(np.full(k, beta))
        cs.append(c)
    return np.concatenate(zs), np.concatenate(bs), np.concatenate(cs)


def test_censored_mle_recovers_from_fastest_k_telemetry():
    """The k order statistics alone are a biased-fast sample; the
    Epstein–Sobel total-time-on-test correction must undo the bias."""
    true = SimplifiedDelayModel(lambda_y=2.0, x=0.1)
    rng = np.random.default_rng(3)
    z, b, c = _fastest_k_telemetry(true, rng, n=10, k=3, beta=0.5, rounds=3000)
    fit = fit_simplified_mle_censored(z, b, c)
    assert fit.lambda_y == pytest.approx(true.lambda_y, rel=0.1)
    # The old bug: fitting the winners as if they were an i.i.d. fleet
    # sample wildly overestimates the rate (workers look too fast).
    naive = fit_simplified_mle(z, b)
    assert naive.lambda_y > 2.0 * true.lambda_y
    assert abs(fit.lambda_y - true.lambda_y) < abs(naive.lambda_y - true.lambda_y)


def test_censored_mle_reduces_to_uncensored():
    true = SimplifiedDelayModel(lambda_y=1.5, x=0.2)
    rng = np.random.default_rng(4)
    z = true.sample(rng, 2000, 0.8)
    b = np.full(2000, 0.8)
    plain = fit_simplified_mle(z, b)
    via_none = fit_simplified_mle_censored(z, b, None)
    via_zeros = fit_simplified_mle_censored(z, b, np.zeros(2000))
    for fit in (via_none, via_zeros):
        assert fit.lambda_y == pytest.approx(plain.lambda_y, rel=1e-9)
        assert fit.shift == pytest.approx(plain.shift, abs=1e-12)


def test_controller_buffers_censoring_counts():
    cfg = StrategyConfig("adaptive_kbeta", n=6, s=10, k_max=3)
    ctrl = Controller(cfg, model=None, estimate_model=True)
    true = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    rng = np.random.default_rng(5)
    for _ in range(200):
        k = ctrl.stage.k
        z = np.sort(true.sample(rng, 6, ctrl.stage.beta))[:k]
        ctrl.observe(response_times=z, n_unobserved=6 - k)
    assert sum(ctrl._rt_censored) > 0
    est = ctrl.current_model()
    assert est is not None
    assert est.lambda_y == pytest.approx(1.0, rel=0.35)


def test_controller_estimated_model_drives_beta_choice():
    """With estimate_model=True and no oracle, the controller fits the
    delay model from telemetry and still produces a feasible beta after a
    k-increment."""
    cfg = StrategyConfig(
        "adaptive_kbeta", n=8, s=10, k_max=4, beta_grid=(0.2, 0.4, 0.6, 0.8, 1.0)
    )
    ctrl = Controller(cfg, model=None, estimate_model=True)
    true = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    rng = np.random.default_rng(0)
    # Feed enough telemetry to fit, then walk stages to a k-increment.
    for _ in range(100):
        ctrl.observe(response_times=true.sample(rng, 8, ctrl.stage.beta))
    est = ctrl.current_model()
    assert est is not None
    assert est.lambda_y == pytest.approx(1.0, rel=0.4)
    # Force advancement through the beta grid to the k bump.
    for _ in range(8):
        nxt = ctrl.advance()
        if nxt is None:
            break
    ks = [s.k for _, s in ctrl.stage_history]
    assert max(ks) >= 2, "controller must have raised k using the fit"
    for _, st_ in ctrl.stage_history:
        assert 0 < st_.beta <= 1.0


def test_controller_worker_removal_repricing():
    cfg = StrategyConfig("adaptive_kbeta", n=8, s=10, k_max=8)
    true = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    ctrl = Controller(cfg, model=true)
    mu_before = ctrl.expected_iteration_time()
    ctrl.remove_worker()
    assert ctrl.cfg.n == 7
    mu_after = ctrl.expected_iteration_time()
    # Same k over fewer workers -> waiting takes longer in expectation.
    assert mu_after > mu_before
