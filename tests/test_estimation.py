"""Online delay-model estimation + controller integration (the oracle-free
production path)."""

import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or seeded fallback

from repro.core import (
    Controller,
    GeneralizedDelayModel,
    SimplifiedDelayModel,
    StrategyConfig,
    fit_generalized_mm,
    fit_simplified_mle,
)


def test_mle_recovers_simplified_parameters():
    true = SimplifiedDelayModel(lambda_y=2.5, x=0.3)
    rng = np.random.default_rng(0)
    betas = np.repeat([0.2, 0.5, 1.0], 3000)
    zs = np.concatenate([
        true.sample(rng, 3000, 0.2),
        true.sample(rng, 3000, 0.5),
        true.sample(rng, 3000, 1.0),
    ])
    fit = fit_simplified_mle(zs, betas)
    assert fit.shift == pytest.approx(true.shift, abs=0.02)
    assert fit.lambda_y == pytest.approx(true.lambda_y, rel=0.1)


def test_mm_recovers_generalized_rates():
    true = GeneralizedDelayModel(lambda_x=4.0, lambda_y=1.5)
    rng = np.random.default_rng(1)
    betas = np.repeat([0.25, 0.5, 1.0], 20000)
    zs = np.concatenate([
        true.sample(rng, 20000, 0.25),
        true.sample(rng, 20000, 0.5),
        true.sample(rng, 20000, 1.0),
    ])
    fit = fit_generalized_mm(zs, betas)
    assert fit.lambda_x == pytest.approx(true.lambda_x, rel=0.15)
    assert fit.lambda_y == pytest.approx(true.lambda_y, rel=0.15)


@settings(max_examples=20, deadline=None)
@given(lam=st.floats(0.2, 10.0), x=st.floats(0.0, 5.0))
def test_mle_shift_never_exceeds_min_sample(lam, x):
    true = SimplifiedDelayModel(lambda_y=lam, x=x)
    rng = np.random.default_rng(42)
    z = true.sample(rng, 500, 0.7)
    fit = fit_simplified_mle(z, np.full(500, 0.7))
    assert fit.shift <= z.min() + 1e-12
    assert fit.lambda_y > 0


def test_controller_estimated_model_drives_beta_choice():
    """With estimate_model=True and no oracle, the controller fits the
    delay model from telemetry and still produces a feasible beta after a
    k-increment."""
    cfg = StrategyConfig(
        "adaptive_kbeta", n=8, s=10, k_max=4, beta_grid=(0.2, 0.4, 0.6, 0.8, 1.0)
    )
    ctrl = Controller(cfg, model=None, estimate_model=True)
    true = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    rng = np.random.default_rng(0)
    # Feed enough telemetry to fit, then walk stages to a k-increment.
    for _ in range(100):
        ctrl.observe(response_times=true.sample(rng, 8, ctrl.stage.beta))
    est = ctrl.current_model()
    assert est is not None
    assert est.lambda_y == pytest.approx(1.0, rel=0.4)
    # Force advancement through the beta grid to the k bump.
    for _ in range(8):
        nxt = ctrl.advance()
        if nxt is None:
            break
    ks = [s.k for _, s in ctrl.stage_history]
    assert max(ks) >= 2, "controller must have raised k using the fit"
    for _, st_ in ctrl.stage_history:
        assert 0 < st_.beta <= 1.0


def test_controller_worker_removal_repricing():
    cfg = StrategyConfig("adaptive_kbeta", n=8, s=10, k_max=8)
    true = SimplifiedDelayModel(lambda_y=1.0, x=0.05)
    ctrl = Controller(cfg, model=true)
    mu_before = ctrl.expected_iteration_time()
    ctrl.remove_worker()
    assert ctrl.cfg.n == 7
    mu_after = ctrl.expected_iteration_time()
    # Same k over fewer workers -> waiting takes longer in expectation.
    assert mu_after > mu_before
