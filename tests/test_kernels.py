"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.ssd_scan import ssd_ref, ssd_scan

RNG = np.random.default_rng(7)


def _arr(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, Sq, Skv, H, Hkv, D, Dv, causal
    (2, 128, 128, 4, 2, 64, 64, True),
    (1, 256, 256, 8, 8, 64, 64, True),     # MHA
    (1, 200, 200, 4, 1, 64, 64, True),     # MQA, ragged seq (padding path)
    (2, 128, 128, 4, 2, 128, 128, False),  # bidirectional
    (1, 64, 64, 2, 2, 32, 32, True),       # small blocks
    (1, 384, 384, 6, 3, 64, 64, True),     # 3 q blocks
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Skv, H, Hkv, D, Dv, causal = case
    q = _arr((B, Sq, H, D), dtype)
    k = _arr((B, Skv, Hkv, D), dtype)
    v = _arr((B, Skv, Hkv, Dv), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_kv=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_block_size_invariance():
    q = _arr((1, 256, 4, 64), jnp.float32)
    k = _arr((1, 256, 2, 64), jnp.float32)
    v = _arr((1, 256, 2, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                        interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=128, block_kv=256,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 64, 4, 32, 2, 16, 16),
    (1, 100, 2, 64, 1, 32, 32),   # padding path
    (2, 256, 4, 64, 2, 64, 128),
    (1, 128, 8, 64, 8, 64, 64),   # one group per head
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_recurrence(case):
    B, S, H, P, G, N, chunk = case
    x = _arr((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = _arr((B, S, G, N), jnp.float32)
    Cm = _arr((B, S, G, N), jnp.float32)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)


def test_ssd_chunk_invariance():
    B, S, H, P, G, N = 1, 192, 2, 32, 1, 16
    x = _arr((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = _arr((B, S, G, N), jnp.float32)
    Cm = _arr((B, S, G, N), jnp.float32)
    a = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    b = ssd_scan(x, dt, A, Bm, Cm, chunk=96, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64, 128), (2, 100, 576), (1, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = _arr(shape, dtype)
    scale = _arr(shape[-1:], dtype)
    out = rmsnorm(x, scale, interpret=True)
    ref = rmsnorm_ref(x, scale)
    # bf16: the oracle rounds to bf16 BEFORE the scale multiply, the fused
    # kernel keeps f32 until the end — a few-ULP ordering difference.
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


# ---------------------------------------------------------------------------
# flash decode (single-query attention over a long cache)
# ---------------------------------------------------------------------------

from repro.kernels.decode_attention import (  # noqa: E402
    decode_ref,
    flash_decode,
    paged_decode_ref,
    paged_flash_decode,
)

DECODE_CASES = [
    # B, S, H, Hkv, D, block_kv
    (2, 256, 8, 2, 64, 64),
    (1, 320, 4, 4, 128, 64),    # non-power-of-two block count
    (3, 1024, 8, 1, 64, 512),   # MQA
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_matches_ref(case):
    B, S, H, Hkv, D, block = case
    q = _arr((B, H, D), jnp.float32)
    k = _arr((B, S, Hkv, D), jnp.float32)
    v = _arr((B, S, Hkv, D), jnp.float32)
    lengths = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    out = flash_decode(q, k, v, lengths, block_kv=block, interpret=True)
    ref = decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_decode_never_pads_the_cache():
    """Regression: the wrapper used to jnp.pad (= copy) the whole K/V
    cache in HBM on every decode tick when S % block_kv != 0. Caches are
    allocated block-aligned now (cache_specs rounds max_len up), so a
    non-dividing request clamps to the largest dividing block — same
    result, zero copies — and an unalignable cache is an error."""
    q = _arr((1, 4, 32), jnp.float32)
    k = _arr((1, 96, 2, 32), jnp.float32)
    v = _arr((1, 96, 2, 32), jnp.float32)
    lengths = jnp.array([57])
    out = flash_decode(q, k, v, lengths, block_kv=64, interpret=True)  # -> 48
    ref = decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    # An aligned default-blocked long cache also clamps instead of raising.
    k2 = _arr((1, 528, 2, 32), jnp.float32)   # 528 = round_kv_len(520)
    v2 = _arr((1, 528, 2, 32), jnp.float32)
    out2 = flash_decode(q, k2, v2, lengths, interpret=True)  # 512 -> 264
    np.testing.assert_allclose(
        np.asarray(out2), np.asarray(decode_ref(q, k2, v2, lengths)), atol=2e-5
    )
    # No divisor >= 8 (prime length): the cache violated the alignment
    # contract — refuse rather than silently copy it every tick.
    k3 = _arr((1, 97, 2, 32), jnp.float32)
    with pytest.raises(ValueError, match="block-aligned"):
        flash_decode(q, k3, k3, lengths, block_kv=64, interpret=True)


def test_flash_decode_length_masking_exact():
    """Entries beyond `lengths` must have zero influence."""
    B, S, H, Hkv, D = 1, 128, 4, 2, 32
    q = _arr((B, H, D), jnp.float32)
    k = _arr((B, S, Hkv, D), jnp.float32)
    v = _arr((B, S, Hkv, D), jnp.float32)
    L = 50
    out1 = flash_decode(q, k, v, jnp.array([L]), block_kv=64, interpret=True)
    k2 = k.at[:, L:].set(99.0)   # poison the masked tail
    v2 = v.at[:, L:].set(-99.0)
    out2 = flash_decode(q, k2, v2, jnp.array([L]), block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# paged flash decode (block-table arena)
# ---------------------------------------------------------------------------

def _scatter_to_arena(k, v, lengths, block_size, seed=0):
    """Scatter contiguous (B, S, ...) caches into a shuffled block arena
    with garbage everywhere a live block is not (the NULL sink block 0
    and all unreferenced rows), returning (k_arena, v_arena, tables)."""
    rng = np.random.default_rng(seed)
    B, S = k.shape[:2]
    T = S // block_size
    ids = rng.permutation(B * T) + 1          # blocks shuffled, 0 = sink
    k_arena = rng.normal(size=(B * T + 1, block_size, *k.shape[2:]))
    v_arena = rng.normal(size=(B * T + 1, block_size, *v.shape[2:]))
    tables = np.zeros((B, T), np.int32)
    nxt = 0
    for b in range(B):
        n_live = -(-int(lengths[b]) // block_size)
        for t in range(n_live):
            bid = int(ids[nxt]); nxt += 1
            tables[b, t] = bid
            k_arena[bid] = np.asarray(k[b, t * block_size:(t + 1) * block_size])
            v_arena[bid] = np.asarray(v[b, t * block_size:(t + 1) * block_size])
    return (jnp.asarray(k_arena, k.dtype), jnp.asarray(v_arena, v.dtype),
            jnp.asarray(tables))


PAGED_CASES = [
    # S, H, Hkv, D, block_size
    (64, 8, 2, 64, 16),    # GQA, small blocks
    (128, 8, 1, 64, 32),   # MQA
    (64, 8, 8, 32, 64),    # MHA, one block per sequence
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_flash_decode_matches_oracles(case):
    """Kernel vs the jnp paged oracle vs the contiguous oracle across the
    boundary lengths {0, 1, bs-1, bs, bs+1, max} in one ragged batch.
    Only live blocks are populated in the arena — everything else is
    garbage, so any read past a block table's live prefix shows up."""
    S, H, Hkv, D, bs = case
    B = 6
    lengths = np.array([0, 1, bs - 1, bs, min(bs + 1, S), S], np.int32)
    q = _arr((B, H, D), jnp.float32)
    k = _arr((B, S, Hkv, D), jnp.float32)
    v = _arr((B, S, Hkv, D), jnp.float32)
    k_arena, v_arena, tables = _scatter_to_arena(k, v, lengths, bs)
    lengths = jnp.asarray(lengths)

    ref = paged_decode_ref(q, k_arena, v_arena, tables, lengths)
    out = paged_flash_decode(q, k_arena, v_arena, tables, lengths,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    # The paged oracle must equal the contiguous oracle bit-for-bit on
    # live rows (this is the engine's byte-identity contract) and zero
    # the length-0 convention rows.
    contig = np.asarray(decode_ref(q, k, v, lengths))
    contig = np.where(np.asarray(lengths)[:, None, None] > 0, contig, 0.0)
    np.testing.assert_array_equal(np.asarray(ref), contig)


def test_paged_flash_decode_ragged_gqa_sweep():
    """Random ragged lengths x GQA group sizes (G in {1, 4, 8})."""
    S, D, bs, B = 96, 32, 16, 4
    for Hkv in (8, 2, 1):
        H = 8
        lengths = np.asarray(RNG.integers(1, S + 1, size=(B,)), np.int32)
        q = _arr((B, H, D), jnp.float32)
        k = _arr((B, S, Hkv, D), jnp.float32)
        v = _arr((B, S, Hkv, D), jnp.float32)
        k_arena, v_arena, tables = _scatter_to_arena(k, v, lengths, bs,
                                                     seed=Hkv)
        out = paged_flash_decode(q, k_arena, v_arena, tables,
                                 jnp.asarray(lengths), interpret=True)
        ref = decode_ref(q, k, v, jnp.asarray(lengths))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, err_msg=f"Hkv={Hkv}")
