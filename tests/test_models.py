"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; decode/prefill consistency for one arch
per family; gradient flow."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, count_params_analytic
from repro.models.layers import init_from_specs

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.input_kind == "tokens":
        inputs = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    labels = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-125m"])
def test_gradients_finite(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize(
    "arch",
    ["smollm-135m", "qwen3-moe-30b-a3b", "deepseek-v3-671b", "zamba2-1.2b",
     "xlstm-125m"],
)
def test_decode_matches_prefill_logits(arch):
    """Greedy decode step-by-step must agree with teacher-forced forward.

    MoE archs: capacity dropping is batch-size dependent (8 routed tokens
    vs 1), so the comparison is only meaningful drop-free — crank the
    capacity factor up for this test."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    model = build_model(cfg)
    params = model.init(RNG)
    B, T = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab_size)

    # Teacher-forced logits for every prefix position.
    positions = jnp.arange(T)
    h, _ = model.hidden(params, tokens, positions)
    full_logits = model.logits(params, h)  # (B, T, V)

    # Step-by-step decode with the cache.
    caches = init_from_specs(RNG, model.cache_specs(B, T + 1))
    outs = []
    for t in range(T):
        logits, caches = model.decode_step(
            params, tokens[:, t : t + 1], caches, jnp.int32(t)
        )
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-2,
        rtol=2e-2,
    )


def test_param_counts_match_spec_tree():
    for arch in list_archs():
        cfg = get_config(arch)
        n = count_params_analytic(cfg)
        na = count_params_analytic(cfg, active_only=True)
        assert n > 0 and na <= n
        if cfg.moe is not None:
            assert na < n  # MoE must have inactive experts


def test_moe_capacity_drops_gracefully():
    """With capacity factor ~0, every token is dropped -> output only the
    shared path (or zeros), still finite."""
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.01)
    )
    model = build_model(cfg)
    params = model.init(RNG)
    loss, _ = model.train_loss(params, _batch(cfg))
    assert bool(jnp.isfinite(loss))


def test_encoder_is_order_sensitive_but_not_causal():
    """hubert (bidirectional): flipping a LATE frame must change EARLY
    outputs (non-causal), unlike the causal decoders."""
    cfg = get_config("hubert-xlarge").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 16
    x = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    h1, _ = model.hidden(params, x, jnp.arange(S))
    x2 = x.at[:, -1].set(-x[:, -1])
    h2, _ = model.hidden(params, x2, jnp.arange(S))
    delta_early = float(jnp.abs(h1[:, 0] - h2[:, 0]).max())
    assert delta_early > 1e-6  # information flows backwards in an encoder


def test_causal_decoder_is_causal():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 1, 16
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    h1, _ = model.hidden(params, toks, jnp.arange(S))
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    h2, _ = model.hidden(params, toks2, jnp.arange(S))
    np.testing.assert_allclose(
        np.asarray(h1[:, :-1], np.float32), np.asarray(h2[:, :-1], np.float32),
        atol=1e-5,
    )


def test_pallas_attention_path_matches_default():
    """cfg.use_pallas routes through the flash kernel (interpret mode on
    CPU) and must agree with the chunked-jnp path."""
    cfg = get_config("smollm-135m").reduced(n_layers=2, max_seq_len=128)
    cfg_p = dataclasses.replace(cfg, use_pallas=True)
    m0, m1 = build_model(cfg), build_model(cfg_p)
    params = m0.init(RNG)
    toks = jax.random.randint(RNG, (2, 128), 0, cfg.vocab_size)
    h0, _ = m0.hidden(params, toks, jnp.arange(128))
    h1, _ = m1.hidden(params, toks, jnp.arange(128))
    np.testing.assert_allclose(
        np.asarray(h0, np.float32), np.asarray(h1, np.float32),
        atol=2e-3, rtol=2e-3,
    )
