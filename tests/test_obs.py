"""Observability plane: tracer, metrics registry, decision log,
structured log — and their integration with the serve + train planes.

Pinned contracts (docs/observability.md):

* DISABLED is free and inert: ``NULL_OBS`` hands out no-op instruments,
  ``begin_span`` returns 0, nothing is recorded anywhere.
* The default trace export is a pure function of virtual execution —
  identical seeds produce BYTE-IDENTICAL JSON, chaos included, and
  tracing does not perturb greedy token streams.
* Span hygiene survives chaos: cancel, deadline expiry, failover, and
  migration all CLOSE the request span (and bump the matching counter);
  ``open_spans`` is empty after every clean run.
* ``validate_trace`` catches the failure modes it claims to: orphan
  ends, unclosed spans, inverted spans, negative durations,
  non-monotone per-track timestamps.
* Metrics are deterministic: the histogram's reservoir decimation uses
  no RNG; counters refuse negative increments; gauges track high-water.
* The decision log is bounded (drops are counted, never silent) and
  records on CHANGE only for repriced (gamma, hedge) plans.
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.delay_models import SimplifiedDelayModel
from repro.models import build_model
from repro.obs import (
    NULL_OBS,
    DecisionLog,
    MetricsRegistry,
    Observability,
    StructuredLog,
    Tracer,
    validate_trace,
)
from repro.runtime.faults import FaultEvent
from repro.serve import Frontend, Replica, ServeEngine, generate_offline

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64
DELAY = SimplifiedDelayModel(lambda_y=2.0)


def _model():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    return model, model.init(RNG)


def _prompts(vocab, n=8, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        p = int(rng.integers(4, 16))
        m = int(rng.integers(6, 14))
        out.append((rng.integers(0, vocab, size=p).astype(np.int32), m, i * 0.002))
    return out


def _chaos_run(model, params, obs):
    """3-replica plane, kill 1 mid-flight, rejoin later; returns token
    streams so callers can assert determinism alongside hygiene."""
    reqs = _prompts(model.cfg.vocab_size, n=8, seed=5)
    replicas = [
        Replica(i, model, params, n_slots=2, max_len=MAX_LEN,
                block_size=8, obs=obs)
        for i in range(3)
    ]
    fe = Frontend(
        replicas, DELAY, cost_per_replica=0.001,
        events=[FaultEvent(step=12, kind="fail", worker=1),
                FaultEvent(step=60, kind="rejoin", worker=1)],
        deadline=0.5, retry_budget=3, obs=obs,
    )
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert fe.summary()["dropped"] == 0
    return [out[g].tokens for g in gids]


# ---------------------------------------------------------------------------
# Disabled mode: free and inert
# ---------------------------------------------------------------------------

def test_null_obs_is_inert():
    obs = NULL_OBS
    assert not obs.enabled
    assert obs.tracer.register_process("x") == 0
    sid = obs.tracer.begin_span("request", 0, 1.0)
    assert sid == 0
    obs.tracer.end_span(sid, 2.0)            # no-op, no raise
    obs.tracer.complete("decode", 0, 1.0, 2.0)
    obs.tracer.instant("cancel", 0, 1.0)
    obs.tracer.counter("occupancy", 0, 1.0, {"slots": 1})
    assert obs.tracer.events == [] and obs.tracer.open_spans == []

    c = obs.metrics.counter("a")
    c.inc(5)                                 # null instrument: writes vanish
    assert obs.metrics.snapshot() == {}
    # Null instruments are shared singletons — no per-name allocation.
    assert obs.metrics.counter("a") is obs.metrics.counter("b")
    assert obs.metrics.histogram("h") is obs.metrics.histogram("h2")

    obs.decisions.record("serve.gamma", {"gamma": 2}, {"p": 0.5})
    assert obs.decisions.to_jsonable()["entries"] == []

    rec = obs.log.emit("x", a=1)
    assert rec.kind == "x" and obs.log.records == []


def test_disabled_obs_engine_records_nothing():
    model, params = _model()
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN)  # NULL_OBS
    prompt = np.arange(5, dtype=np.int32)
    eng.submit(prompt, 4)
    eng.run()
    assert eng.obs is NULL_OBS
    assert eng.obs.tracer.events == []
    assert eng.obs.metrics.snapshot() == {}


# ---------------------------------------------------------------------------
# Trace determinism + non-perturbation
# ---------------------------------------------------------------------------

def test_trace_byte_identical_across_identical_seeds():
    model, params = _model()
    obs1, obs2 = Observability(), Observability()
    s1 = _chaos_run(model, params, obs1)
    s2 = _chaos_run(model, params, obs2)
    assert s1 == s2
    j1, j2 = obs1.tracer.to_json(), obs2.tracer.to_json()
    assert j1 == j2, "identical seeds must export byte-identical traces"
    # Wall-time merge is opt-in and changes the payload.
    assert obs1.tracer.to_json(include_wall=True) != j1


def test_tracing_does_not_perturb_streams():
    model, params = _model()
    reqs = _prompts(model.cfg.vocab_size, n=8, seed=5)  # _chaos_run workload
    refs = [generate_offline(model, params, p, m, MAX_LEN)
            for p, m, _ in reqs]
    traced = _chaos_run(model, params, Observability())
    plain = _chaos_run(model, params, NULL_OBS)
    # Chaos + tracing vs untraced vs per-request offline: same bytes.
    assert traced == plain == refs


# ---------------------------------------------------------------------------
# Span hygiene under chaos
# ---------------------------------------------------------------------------

def test_chaos_closes_every_span_and_trace_validates():
    model, params = _model()
    obs = Observability()
    _chaos_run(model, params, obs)
    assert obs.tracer.open_spans == [], "spans leaked across kill-1-of-3"
    assert validate_trace(obs.tracer.events) == []
    # Chaos left its marks: fault instants + cancel counters exist.
    snap = obs.metrics.snapshot()
    assert snap["replica.fault.fail"] >= 1
    assert snap["replica.fault.rejoin"] >= 1
    names = {ev["name"] for ev in obs.tracer.events}
    assert {"request", "prefill", "decode", "fault", "dispatch"} <= names


def test_cancel_closes_span_and_counts():
    model, params = _model()
    obs = Observability()
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, obs=obs)
    rid = eng.submit(np.arange(6, dtype=np.int32), 8)
    eng.step()                               # prefill begins the lifecycle
    assert obs.tracer.open_spans == ["request"]
    eng.cancel(rid, reason="cancelled")
    assert obs.tracer.open_spans == []
    assert obs.metrics.snapshot()["engine.cancel.cancelled"] == 1
    ends = [ev for ev in obs.tracer.events if ev["ph"] == "e"]
    assert ends and ends[-1]["args"]["outcome"] == "cancelled"


def test_migration_closes_source_span_opens_dest_span():
    model, params = _model()
    obs = Observability()
    src = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, obs=obs,
                      obs_name="src")
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, obs=obs,
                      obs_name="dst")
    prompt = np.arange(6, dtype=np.int32)
    ref = generate_offline(model, params, prompt, 8, MAX_LEN)
    rid = src.submit(prompt, 8)
    for _ in range(3):
        src.step()
    ticket = src.export_request(rid)
    assert src.obs.tracer.open_spans == []   # "migrated" closed it...
    rid2 = dst.import_request(ticket)
    assert obs.tracer.open_spans == ["request"]   # ...and dest reopened
    out = dst.run()
    assert obs.tracer.open_spans == []
    assert out[rid2].tokens == ref
    snap = obs.metrics.snapshot()
    assert snap["engine.migrated_out"] == 1
    assert snap["engine.migrated_in"] == 1
    kinds = [ev["name"] for ev in obs.tracer.events if ev["ph"] == "i"]
    assert "migrate_out" in kinds and "migrate_in" in kinds


# ---------------------------------------------------------------------------
# validate_trace: the invariants actually trip
# ---------------------------------------------------------------------------

def test_validate_trace_catches_violations():
    ok = [
        {"ph": "b", "cat": "c", "name": "s", "pid": 1, "tid": 0, "id": 1,
         "ts": 1.0},
        {"ph": "e", "cat": "c", "name": "s", "pid": 1, "tid": 0, "id": 1,
         "ts": 2.0},
        {"ph": "X", "name": "x", "pid": 1, "tid": 0, "ts": 2.0, "dur": 1.0},
        {"ph": "i", "name": "i", "pid": 1, "tid": 0, "ts": 3.0, "s": "p"},
    ]
    assert validate_trace(ok) == []

    orphan = [{"ph": "e", "cat": "c", "name": "s", "pid": 1, "id": 9,
               "ts": 1.0}]
    assert any("orphan" in e for e in validate_trace(orphan))

    unclosed = [{"ph": "b", "cat": "c", "name": "s", "pid": 1, "id": 1,
                 "ts": 1.0}]
    assert any("unclosed" in e for e in validate_trace(unclosed))

    inverted = [
        {"ph": "b", "cat": "c", "name": "s", "pid": 1, "id": 1, "ts": 5.0},
        {"ph": "e", "cat": "c", "name": "s", "pid": 1, "id": 1, "ts": 1.0},
    ]
    assert any("before it begins" in e for e in validate_trace(inverted))

    negdur = [{"ph": "X", "name": "x", "pid": 1, "ts": 1.0, "dur": -0.5}]
    assert any("negative duration" in e for e in validate_trace(negdur))

    backwards = [
        {"ph": "X", "name": "x", "pid": 1, "tid": 0, "ts": 5.0, "dur": 1.0},
        {"ph": "i", "name": "i", "pid": 1, "tid": 0, "ts": 2.0, "s": "p"},
    ]
    assert any("non-monotone" in e for e in validate_trace(backwards))


def test_tracer_end_span_twice_raises():
    tr = Tracer()
    pid = tr.register_process("p")
    sid = tr.begin_span("s", pid, 1.0)
    tr.end_span(sid, 2.0)
    with pytest.raises(ValueError):
        tr.end_span(sid, 3.0)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_basics():
    m = MetricsRegistry()
    c = m.counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    assert m.counter("c") is c               # same name -> same instrument
    with pytest.raises(TypeError):
        m.gauge("c")                         # kind mismatch

    g = m.gauge("g")
    g.set(2.0)
    g.set(7.0)
    g.set(3.0)
    assert g.value == 3.0 and g.high_water == 7.0

    h = m.histogram("h")
    for v in [1.0, 2.0, 3.0, 4.0, 100.0]:
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5 and s["max"] == 100.0 and s["min"] == 1.0
    assert h.percentile(50) == 3.0

    snap = m.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["c"] == 4
    assert snap["g"] == {"value": 3.0, "high_water": 7.0}


def test_histogram_deterministic_under_decimation():
    def fill(seed):
        h = MetricsRegistry().histogram("h")
        rng = np.random.default_rng(seed)
        for v in rng.exponential(1.0, size=20_000):
            h.observe(float(v))
        return h

    h1, h2 = fill(3), fill(3)
    assert h1.snapshot() == h2.snapshot()    # no RNG in the reservoir
    assert h1.snapshot()["count"] == 20_000
    # Decimated percentile stays close to the exact one.
    exact = float(np.percentile(np.random.default_rng(3).exponential(
        1.0, size=20_000), 99))
    assert abs(h1.percentile(99) - exact) / exact < 0.1


def test_empty_histogram_snapshot_is_json_safe():
    h = MetricsRegistry().histogram("h")
    assert json.dumps(h.snapshot())          # "nan" strings, not float nan


# ---------------------------------------------------------------------------
# Decision log
# ---------------------------------------------------------------------------

def test_decision_log_bounded_with_counted_drops():
    d = DecisionLog(cap=10)
    for i in range(25):
        d.record("serve.gamma", {"gamma": i}, {"p": 0.5}, step=i)
    out = d.to_jsonable()
    assert len(out["entries"]) == 10
    assert out["dropped"] == 15
    assert [x["decision"]["gamma"] for x in out["entries"]] == list(range(10))


def test_spec_controller_records_gamma_changes_only():
    from repro.serve import SpecController
    from repro.serve.scheduler import CostModel

    obs = Observability()
    ctl = SpecController(gamma_max=4)
    ctl.obs = obs
    cost = CostModel()
    for _ in range(40):
        ctl.observe(3, 4)                    # high acceptance
        ctl.choose_gamma(cost)
    recs = obs.decisions.by_domain("serve.gamma")
    assert recs, "at least the first plan must be recorded"
    gammas = [r.decision["gamma"] for r in recs]
    assert all(a != b for a, b in zip(gammas, gammas[1:])), \
        "decision log must record on change only"
    assert {"p", "observations", "cost_per_token"} <= set(recs[0].inputs)


# ---------------------------------------------------------------------------
# Structured log
# ---------------------------------------------------------------------------

def test_structured_log_echo_is_a_view_of_records(capsys):
    log = StructuredLog(echo=True)
    log.emit("step", t=1.5, loss=0.25, k=3)
    log.emit("done", ok=True)
    out = capsys.readouterr().out.splitlines()
    assert out[0] == log.records[0].format()
    assert out[1] == log.records[1].format()
    assert log.last("step").fields["k"] == 3
    assert [r["kind"] for r in log.to_jsonable()] == ["step", "done"]


def test_structured_log_silent_still_records(capsys):
    log = StructuredLog(echo=False)
    log.emit("step", loss=1.0)
    assert capsys.readouterr().out == ""
    assert len(log.by_kind("step")) == 1


# ---------------------------------------------------------------------------
# Snapshot export
# ---------------------------------------------------------------------------

def test_observability_snapshot_roundtrip(tmp_path):
    model, params = _model()
    obs = Observability()
    _chaos_run(model, params, obs)
    path = tmp_path / "snap.json"
    obs.export_snapshot(str(path))
    snap = json.loads(path.read_text())
    assert snap["open_spans"] == []
    assert snap["trace_events"] == len(obs.tracer.events)
    assert "engine.generated_tokens" in snap["metrics"]
