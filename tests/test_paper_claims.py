"""Regression tests pinning the paper's claims (fast versions).

These encode the reproduction contract: if a refactor breaks the theory
or the simulator, these fail. Bands are deliberately generous — they
guard the CLAIMS, not exact numbers.
"""

import numpy as np
import pytest

from repro.core import (
    LinregProblem,
    SGDHyperParams,
    SimplifiedDelayModel,
    StrategyConfig,
    evaluate_schedule,
    simulate,
)

GRID = (0.2, 0.4, 0.6, 0.8, 1.0)


@pytest.fixture(scope="module")
def paper_setting():
    problem = LinregProblem.generate(v=400, d=10, n_workers=20, seed=1)
    model = SimplifiedDelayModel(lambda_y=1.0, x=0.01)
    lam = np.linalg.eigvalsh(2.0 * problem.X.T @ problem.X / problem.v)
    c = float(2.0 * lam.min())
    fl1 = 0.1846 * problem.eta / 9.284e-6
    hp = SGDHyperParams(
        eta=problem.eta, L=2.0,
        sigma_grad2=fl1 * 2 * c * problem.s / (problem.eta * 2.0),
        c=c, s=problem.s,
    )
    e0 = problem.gap(np.zeros(problem.d))
    return problem, model, hp, e0


def _schedules(model, hp, e0):
    out = {}
    for strat in ("adaptive_kbeta", "adaptive_k"):
        cfg = StrategyConfig(strat, n=20, s=20, k_max=10, beta_grid=GRID)
        out[strat] = evaluate_schedule(cfg, model, hp, e0=e0, target=2e-2)
    return out["adaptive_kbeta"], out["adaptive_k"]


def test_fig4_theory_runtime_roughly_halved(paper_setting):
    _, model, hp, e0 = paper_setting
    ours, ak = _schedules(model, hp, e0)
    ratio = ours.runtime / ak.runtime
    assert 0.40 <= ratio <= 0.70, f"runtime ratio {ratio} (paper ~0.5)"


def test_fig4_theory_comp_reduction(paper_setting):
    _, model, hp, e0 = paper_setting
    ours, ak = _schedules(model, hp, e0)
    red = 1 - ours.comp_cost / ak.comp_cost
    assert 0.45 <= red <= 0.75, f"comp reduction {red} (paper 59.9%)"


def test_fig4_theory_comm_overhead_modest(paper_setting):
    _, model, hp, e0 = paper_setting
    ours, ak = _schedules(model, hp, e0)
    ovh = ours.comm_cost / ak.comm_cost - 1
    assert 0.0 <= ovh <= 0.30, f"comm overhead {ovh} (paper 15.7%)"


def test_fig4_sim_runtime_halved_with_diagnostics(paper_setting):
    """Even with run-time stationarity detection (no oracle), the halving
    shows up on mean curves. Reduced seeds/iters for CI speed."""
    problem, model, _, _ = paper_setting
    tgrid = np.linspace(0, 600, 600)
    mean_gap = {}
    for strat in ("adaptive_kbeta", "adaptive_k"):
        gs = []
        for seed in range(4):
            cfg = StrategyConfig(strat, n=20, s=20, k_max=10, beta_grid=GRID)
            r = simulate(problem, cfg, model, seed=seed, max_iters=12_000,
                         eval_every=10)
            gs.append(np.interp(tgrid, r.times, r.gaps))
        mean_gap[strat] = np.mean(gs, 0)

    def cross(g, target=5e-2):  # coarser target: 12k iters, 4 seeds
        idx = np.nonzero(g <= target)[0]
        return tgrid[idx[0]] if idx.size else np.inf

    t_ours = cross(mean_gap["adaptive_kbeta"])
    t_ak = cross(mean_gap["adaptive_k"])
    assert np.isfinite(t_ours) and np.isfinite(t_ak)
    assert t_ours < 0.8 * t_ak, f"ours {t_ours} vs ak {t_ak}"


def test_fig1_runtime_gain_largest_when_compute_dominates():
    hp = SGDHyperParams(eta=0.01, L=2.0, sigma_grad2=10.0, c=1.0, s=20)

    def gain(lam, x):
        m = SimplifiedDelayModel(lambda_y=lam, x=x)
        ours = evaluate_schedule(
            StrategyConfig("adaptive_kbeta", n=50, s=20), m, hp,
            e0=10.0, target=1e-3)
        ak = evaluate_schedule(
            StrategyConfig("adaptive_k", n=50, s=20), m, hp,
            e0=10.0, target=1e-3)
        return 1 - ours.runtime / ak.runtime

    comp_dom = gain(0.05, 0.05)   # slow computation, fast communication
    comm_dom = gain(20.0, 20.0)   # fast computation, slow communication
    assert comp_dom > 0.10
    assert comm_dom < 0.02
    assert comp_dom > comm_dom
