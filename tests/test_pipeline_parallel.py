"""GPipe pipeline-parallel stage: subprocess (needs 4 forced devices)."""

import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.dist.pipeline_parallel import pipeline_forward, stage_params

    mesh = jax.make_mesh((4,), ("pipe",))
    L, D = 8, 16
    rng = jax.random.PRNGKey(0)
    Ws = jax.random.normal(rng, (L, D, D)) * 0.2

    def layer_fn(W, x):
        return jnp.tanh(x @ W)

    n_micro, mb = 6, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, D))

    with jax.set_mesh(mesh):
        out = pipeline_forward(layer_fn, stage_params(Ws, 4), x, mesh)

    # Sequential reference.
    def ref_fwd(h):
        for i in range(L):
            h = jnp.tanh(h @ Ws[i])
        return h
    ref = jax.vmap(ref_fwd)(x)
    err = float(jnp.abs(out - ref).max())
    print(json.dumps({"err": err}))
    """
)


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["err"] < 1e-5
