"""Copy-on-write prefix sharing + preempt-and-requeue (DESIGN.md §16).

Two layers of defense:

1. A RANDOMIZED arena-invariant machine: thousands of random
   admit/append/adopt/fork/free/preempt sequences against BlockManager,
   checked after EVERY op against an independent host mirror:
     * refcount[b] == live table references to b, for every block;
     * no block is doubly owned by writers (a write target has
       refcount 1 — shared blocks are read-only until forked);
     * a freed block returns to the free list EXACTLY once, when its
       last reference drops (free ∪ referenced == {1..N}, disjoint);
     * ``used_high_water`` == running max of UNIQUE live blocks.
   (Runs through tests/_hypo.py: real hypothesis when installed, seeded
   random fallback otherwise.)

2. Byte-identity pins: shared-prefix and preempted-then-requeued
   requests emit token streams identical to a solo offline decode across
   all four model families — sharing and preemption are memory/latency
   moves, never math changes — including preemption racing an in-flight
   hedge copy at the frontend.

Failure-semantics clauses pinned here are cross-linked from
docs/serving.md ("Prefix sharing + preemption").
"""

import dataclasses

import jax
import numpy as np
import pytest

from _hypo import given, settings, st
from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    ArenaExhausted,
    BlockManager,
    Frontend,
    PrefixIndex,
    Replica,
    Scheduler,
    ServeEngine,
    generate_offline,
)
from repro.core.delay_models import SimplifiedDelayModel

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64
ARCHS = ["smollm-135m", "deepseek-v3", "xlstm-125m", "zamba2"]
DELAY = SimplifiedDelayModel(lambda_y=2.0)


def _model(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # Prefix sharing changes suffix-prefill token counts; only
        # dropless (inference-mode) routing is chunk-geometry-invariant.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dropless=True)
        )
    model = build_model(cfg)
    return model, model.init(RNG)


# ---------------------------------------------------------------------------
# Layer 1: randomized arena-invariant machine (host-only, no jax)
# ---------------------------------------------------------------------------

class _Mirror:
    """Independent reference model of the refcounted arena: per-slot
    block lists + a bid->refcount dict, plus a ledger counting how many
    times each bid entered the free list (must equal times allocated)."""

    def __init__(self, n_slots, num_blocks):
        self.tables = [[] for _ in range(n_slots)]
        self.ref = {}
        self.num_blocks = num_blocks
        self.freed_count = {b: 1 for b in range(1, num_blocks + 1)}
        self.alloc_count = {b: 0 for b in range(1, num_blocks + 1)}
        self.high_water = 0

    def note_alloc(self, bid):
        self.alloc_count[bid] += 1

    def note_free(self, bid):
        self.freed_count[bid] += 1

    def touch_high_water(self):
        self.high_water = max(self.high_water, len(self.ref))

    def check_against(self, mgr: BlockManager):
        errs = mgr.audit()
        assert errs == [], errs
        # refcounts == live table references (vs OUR book, not mgr's)
        refs = {}
        for t in self.tables:
            for b in t:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self.ref
        for b in range(1, self.num_blocks + 1):
            assert int(mgr.refcount[b]) == self.ref.get(b, 0), b
        # every block's tables match the manager's
        for s, t in enumerate(self.tables):
            assert mgr._owned[s] == t, f"slot {s}"
        # freed exactly once per allocation (ledger balance): a block is
        # either live (allocated one more time than freed) or free
        # (balanced) — never freed twice for one allocation.
        for b in range(1, self.num_blocks + 1):
            live = 1 if b in self.ref else 0
            assert self.alloc_count[b] + 1 - self.freed_count[b] == live, b
        # high-water == running max of unique live blocks
        assert mgr.used_high_water == self.high_water


def _random_machine(seed, n_slots=4, num_blocks=12, block_size=4, n_ops=150):
    rng = np.random.default_rng(seed)
    rows = num_blocks * block_size          # table wide enough for all
    mgr = BlockManager(n_slots, rows, block_size, num_blocks, sharing=True)
    mir = _Mirror(n_slots, num_blocks)
    active = set()

    for _ in range(n_ops):
        op = rng.choice(["admit", "append", "adopt", "fork", "free"])
        if op == "admit" and len(active) < n_slots:
            slot = int(rng.choice([s for s in range(n_slots)
                                   if s not in active]))
            mgr.commit(slot, rows)          # table-width budget
            active.add(slot)
        elif op == "append" and active:
            slot = int(rng.choice(sorted(active)))
            want = len(mgr._owned[slot]) * block_size + int(
                rng.integers(1, 2 * block_size)
            )
            if mgr.blocks_for(want) > mgr.table_width:
                continue
            try:
                before = list(mgr._owned[slot])
                mgr.append(slot, want)
            except ArenaExhausted:
                assert mgr.n_free_blocks == 0
            fresh = mgr._owned[slot][len(before):]
            for b in fresh:
                mir.note_alloc(b)
                mir.ref[b] = 1
                mir.tables[slot].append(b)
            mir.touch_high_water()
        elif op == "adopt" and active:
            # adopt another slot's chain into a fresh slot
            free_slots = [s for s in range(n_slots) if s not in active]
            donors = [s for s in active if mgr._owned[s]]
            if not free_slots or not donors:
                continue
            slot = int(rng.choice(free_slots))
            donor = int(rng.choice(donors))
            k = int(rng.integers(1, len(mgr._owned[donor]) + 1))
            chain = list(mgr._owned[donor][:k])
            mgr.commit(slot, rows)
            mgr.adopt(slot, chain)
            active.add(slot)
            for b in chain:
                mir.ref[b] += 1
                mir.tables[slot].append(b)
            mir.touch_high_water()
        elif op == "fork" and active:
            cands = [
                (s, i)
                for s in active
                for i, b in enumerate(mgr._owned[s])
                if mgr.refcount[b] > 1
            ]
            if not cands:
                continue
            slot, idx = cands[int(rng.integers(len(cands)))]
            try:
                src, dst = mgr.fork(slot, idx)
            except ArenaExhausted:
                assert mgr.n_free_blocks == 0
                continue
            mir.ref[src] -= 1
            mir.note_alloc(dst)
            mir.ref[dst] = 1
            mir.tables[slot][idx] = dst
            mir.touch_high_water()
            # the writer's block is now exclusively its own
            assert not mgr.is_shared(dst)
        elif op == "free" and active:     # free == preempt at this layer
            slot = int(rng.choice(sorted(active)))
            released = mgr.free(slot)
            active.discard(slot)
            for b in mir.tables[slot]:
                mir.ref[b] -= 1
                if mir.ref[b] == 0:
                    del mir.ref[b]
                    mir.note_free(b)
                    assert b in released
            assert all(mir.ref.get(b, 0) == 0 for b in released)
            mir.tables[slot] = []
        mir.check_against(mgr)

    for slot in sorted(active):
        released = mgr.free(slot)
        for b in mir.tables[slot]:
            mir.ref[b] -= 1
            if mir.ref[b] == 0:
                del mir.ref[b]
                mir.note_free(b)
        mir.tables[slot] = []
        mir.check_against(mgr)
    assert mgr.n_free_blocks == num_blocks


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_randomized_arena_invariants(seed):
    """~4500 random admit/append/adopt/fork/free ops, every one checked
    against the mirror + the manager's own audit."""
    _random_machine(seed)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_randomized_arena_invariants_tiny_arena(seed):
    """Same machine at 5 blocks: constant exhaustion pressure exercises
    the ArenaExhausted paths on almost every append/fork."""
    _random_machine(seed, n_slots=3, num_blocks=5, block_size=2, n_ops=120)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_randomized_trie_matches_dict_mirror(seed):
    """PrefixIndex vs a naive dict of full-block prefixes: identical
    match results under random register/forget interleavings."""
    rng = np.random.default_rng(seed)
    bs = 4
    trie = PrefixIndex(bs)
    mirror = {}                 # tuple(chunk path) -> bid
    live = set()                # bids holding a trie node (matchable or not)
    next_bid = 1
    for _ in range(120):
        if rng.random() < 0.6 or not mirror:
            toks = list(rng.integers(0, 5, size=int(rng.integers(0, 14))))
            n_full = len(toks) // bs
            bids = list(range(next_bid, next_bid + n_full))
            next_bid += n_full
            trie.register(toks, bids)
            path = ()
            for k in range(n_full):
                path = path + (tuple(toks[k * bs:(k + 1) * bs]),)
                if path not in mirror:                # incumbent wins
                    mirror[path] = bids[k]
                    live.add(bids[k])
        else:
            path = list(mirror)[int(rng.integers(len(mirror)))]
            bid = mirror[path]
            trie.forget(bid)
            live.discard(bid)
            # forgetting a mid-chain node orphans its descendants from
            # MATCHING (the walk stops at the detached node) — they keep
            # their index entries until individually forgotten, exactly
            # how the pool forgets blocks one at a time as they free.
            for p in [p for p in mirror if p[:len(path)] == path]:
                del mirror[p]
        probe = list(rng.integers(0, 5, size=int(rng.integers(0, 14))))
        got = trie.match(probe)
        path, want = (), []
        for k in range(len(probe) // bs):
            path = path + (tuple(probe[k * bs:(k + 1) * bs]),)
            if path not in mirror:
                break
            want.append(mirror[path])
        assert got == want, (probe, got, want)
    assert len(trie) == len(live)


def test_fork_requires_shared_and_exhaustion_raises():
    mgr = BlockManager(2, 16, 4, 4, sharing=True)
    mgr.commit(0, 16)
    mgr.append(0, 8)                        # slot0: 2 blocks
    with pytest.raises(ValueError):
        mgr.fork(0, 0)                      # not shared — nothing to fork
    mgr.commit(1, 16)
    mgr.adopt(1, mgr._owned[0])
    mgr.append(0, 16)                       # slot0 grows to 4 blocks: arena full
    with pytest.raises(ArenaExhausted):
        mgr.fork(1, 0)                      # shared, but no free block
    mgr.check()


def test_adopt_only_before_append_and_only_resident():
    mgr = BlockManager(2, 16, 4, 4, sharing=True)
    mgr.commit(0, 16)
    mgr.append(0, 4)
    mgr.commit(1, 16)
    with pytest.raises(ValueError):
        mgr.adopt(1, [3])                   # block 3 is not resident
    mgr.adopt(1, mgr._owned[0])
    with pytest.raises(ValueError):
        mgr.adopt(1, mgr._owned[0])         # table no longer empty
    mgr.check()


def test_legacy_mode_never_raises_arena_exhausted():
    """Commit-at-admission still guarantees exhaustion-free appends —
    the sharing semantics are strictly opt-in."""
    mgr = BlockManager(2, 16, 4, 4)
    assert not mgr.sharing
    mgr.commit(0, 8)
    mgr.commit(1, 8)
    mgr.append(0, 8)
    mgr.append(1, 8)                        # exactly fills the arena
    assert mgr.n_free_blocks == 0
    mgr.check()
    with pytest.raises(ValueError):
        mgr.commit(0, 16)                   # over-commit rejected up front


def test_audit_reports_instead_of_raising():
    mgr = BlockManager(1, 16, 4, 4, sharing=True)
    mgr.commit(0, 16)
    mgr.append(0, 8)
    assert mgr.audit() == []
    bid = mgr._owned[0].pop()               # seed a leak by hand
    mgr.tables[0, 1] = 0
    mgr.refcount[bid] -= 1
    msgs = mgr.audit()
    assert any("leaked" in m for m in msgs)
    with pytest.raises(AssertionError):
        mgr.check()


# ---------------------------------------------------------------------------
# Layer 2: byte-identity pins (all four families)
# ---------------------------------------------------------------------------

def _shared_prefix_reqs(vocab, shared_len=24, n=6, seed=11):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=shared_len).astype(np.int32)
    out = []
    for i in range(n):
        suf = rng.integers(
            0, vocab, size=int(rng.integers(2, 6))
        ).astype(np.int32)
        out.append((np.concatenate([shared, suf]), 8, i * 0.002))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_shared_prefix_matches_offline(arch):
    """90%-shared prompts under prefix sharing: every stream identical
    to solo offline decode. Fully-paged families (smollm, deepseek MLA)
    must actually share blocks; recurrent hybrids (xlstm, zamba) must
    NOT (running state cannot stand in for skipped compute) but stay
    byte-identical through the same engine."""
    model, params = _model(arch)
    reqs = _shared_prefix_reqs(model.cfg.vocab_size)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
        block_size=8, prefix_sharing=True,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    res = eng.run()
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert res[rid].tokens == ref, f"{arch} rid={rid} diverged"
    if eng.pool._any_contiguous:
        assert eng.stats.prefix_hits == 0       # recurrent: preempt-only
    else:
        assert eng.stats.prefix_hits > 0
        assert eng.stats.prefix_rows_shared >= 16
    eng.pool.manager.check()
    assert eng.pool.manager.n_used_blocks == 0  # full teardown at drain


@pytest.mark.parametrize("arch", ARCHS)
def test_preempted_requeued_matches_offline(arch):
    """A 2-slot engine over a 7-block arena (each request wants ~5):
    sustained pressure forces evictions, and every evicted request's
    final stream is byte-identical to never having been preempted."""
    model, params = _model(arch)
    rng = np.random.default_rng(5)
    V = model.cfg.vocab_size
    reqs = []
    for i in range(4):
        p = rng.integers(0, V, size=int(rng.integers(18, 30))).astype(np.int32)
        reqs.append((p, 10, i * 0.001))
    eng = ServeEngine(
        model, params, n_slots=2, max_len=MAX_LEN,
        scheduler=Scheduler(2, prefill_chunk=8, decode_per_prefill=2),
        block_size=8, arena_blocks=7, prefix_sharing=True,
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    res = eng.run()
    assert eng.stats.preempted_requests > 0, "workload failed to preempt"
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert res[rid].tokens == ref, f"{arch} rid={rid} diverged"
    eng.pool.manager.check()
    assert eng.pool.manager.n_used_blocks == 0


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v3"])
def test_identical_prompts_full_match_refeed(arch):
    """Block-aligned identical prompts: the adopter matches its WHOLE
    prompt, so the engine re-feeds the last token through a forked tail
    block — the one case a prefill write targets a shared block."""
    model, params = _model(arch)
    rng = np.random.default_rng(9)
    p0 = rng.integers(0, model.cfg.vocab_size, size=16).astype(np.int32)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
        block_size=8, prefix_sharing=True,
    )
    r0 = eng.submit(p0, 6, arrival=0.0)
    r1 = eng.submit(p0, 6, arrival=0.001)
    r2 = eng.submit(p0, 6, arrival=0.002)
    res = eng.run()
    ref = generate_offline(model, params, p0, 6, MAX_LEN)
    for rid in (r0, r1, r2):
        assert res[rid].tokens == ref
    assert eng.stats.prefix_hits >= 2
    eng.pool.manager.check()


def test_sharing_multiplies_concurrency_vs_committed():
    """The memory win, pinned at the engine level: a shared-prefix
    workload that commit-at-admission serves 2-at-a-time fits 4
    concurrent lanes under sharing (unique high-water stays under the
    same arena), with identical streams."""
    model, params = _model("smollm-135m")
    reqs = _shared_prefix_reqs(model.cfg.vocab_size, shared_len=32, n=4)
    refs = [generate_offline(model, params, p, m, MAX_LEN)
            for p, m, _ in reqs]

    def run(sharing):
        eng = ServeEngine(
            model, params, n_slots=4, max_len=MAX_LEN,
            scheduler=Scheduler(4, prefill_chunk=8, decode_per_prefill=2),
            block_size=8, arena_blocks=13, prefix_sharing=sharing,
        )
        rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
        peak = 0
        while eng.has_work:
            eng.step()
            peak = max(peak, sum(r is not None for r in eng.pool.owner))
        res = {r: eng.request(r) for r in rids}
        assert [res[r].tokens for r in rids] == refs
        return eng, peak

    unshared, peak_unshared = run(False)
    shared, peak_shared = run(True)
    # every budget is 5-6 blocks: 13 blocks commit only 2 lanes at once,
    # but 4 adopted lanes (4 shared prefix blocks + ~2 unique each) fit.
    assert peak_unshared <= 2
    assert peak_shared >= 2 * peak_unshared
    assert shared.stats.prefix_hits >= 3
    assert shared.sched.clock.now < unshared.sched.clock.now


def test_restore_slot_busy_under_arena_pressure():
    """A migration landing on a sharing-mode pool without enough free
    blocks reports busy (None) instead of crashing — the frontend
    requeues, local preemption opens space later."""
    model, params = _model("smollm-135m")
    rng = np.random.default_rng(2)
    V = model.cfg.vocab_size
    src = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                      block_size=8, prefix_sharing=True)
    p = rng.integers(0, V, size=20).astype(np.int32)
    rid = src.submit(p, 8, arrival=0.0)
    for _ in range(30):
        if len(src.request(rid).tokens) >= 3:
            break
        src.step()
    ticket = src.export_request(rid)
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                      block_size=8, arena_blocks=7, prefix_sharing=True)
    filler = dst.submit(rng.integers(0, V, size=40).astype(np.int32), 8)
    while dst.request(filler).prefilled < 40:
        dst.step()
    assert dst.import_request(ticket) is None      # busy, not a crash
    dst.cancel(filler)
    assert dst.import_request(ticket) is not None  # space freed → lands
    dst.pool.manager.check()


def test_prefix_sharing_rejects_speculative():
    model, params = _model("smollm-135m")
    with pytest.raises(ValueError, match="prefix_sharing"):
        ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                    block_size=8, prefix_sharing=True,
                    draft_model=model, draft_params=params)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                    prefix_sharing=True)


def test_prefix_sharing_rejects_capacity_dropped_moe():
    """Capacity-dropped MoE logits depend on how many tokens share one
    forward call, so adoption (which shrinks the suffix prefill) would
    silently break byte-identity — the engine refuses up front. The same
    config with ``dropless=True`` is accepted (and pinned byte-identical
    in the parametrized tests above)."""
    cfg = get_config("deepseek-v3").reduced()
    assert cfg.moe is not None and not cfg.moe.dropless
    model = build_model(cfg)
    params = model.init(RNG)
    with pytest.raises(ValueError, match="dropless"):
        ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                    block_size=8, prefix_sharing=True)


@pytest.mark.slow
def test_preemption_races_inflight_hedge_copy():
    """Fleet-level pin: sharing replicas with starved arenas preempt
    while hedge copies of the same request are in flight on other
    replicas; loser cancellation, retries, and preemption replay all
    interleave — zero drops, streams byte-identical to offline."""
    model, params = _model("smollm-135m")
    rng = np.random.default_rng(21)
    V = model.cfg.vocab_size
    shared = rng.integers(0, V, size=16).astype(np.int32)
    reqs = []
    for i in range(8):
        suf = rng.integers(0, V, size=int(rng.integers(2, 6))).astype(np.int32)
        reqs.append((np.concatenate([shared, suf]), 14, i * 0.002))
    refs = [generate_offline(model, params, p, m, MAX_LEN)
            for p, m, _ in reqs]
    fleet = [
        Replica(i, model, params, n_slots=2, max_len=MAX_LEN,
                block_size=8, arena_blocks=6, prefix_sharing=True)
        for i in range(3)
    ]
    fe = Frontend(fleet, DELAY, cost_per_replica=0.001)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert all(out[g].done and not out[g].dropped for g in gids)
    assert [out[g].tokens for g in gids] == refs
    s = fe.summary()
    assert s["preemptions"] > 0, "fleet never preempted — loosen the arena"
    for rep in fe.replicas:
        mgr = rep.engine.pool.manager
        assert mgr.n_free_blocks == mgr.num_blocks
        mgr.check()
