"""Multi-replica serving plane: chaos failover, deadlines, cancellation
that actually frees memory, and in-flight KV migration.

Pinned contracts (docs/serving.md "Failure semantics"):

* ``FaultEvent`` is one shared schema for both planes
  (``repro.runtime.faults``), still importable from its old home.
* Hedged-loser cancellation releases engine slots AND paged arena
  blocks — a queued request admits the moment a loser is cancelled.
* Deadlines are stamped at admission, police every step, and free what
  the expired request held; the expiry is censored telemetry.
* A rejoining replica is priced at the neutral prior, and its first
  real observation seeds its estimate directly (no crawl-up from zero).
* Quorum degrades with the ALIVE fleet (re-price, don't stall) while a
  fully-alive-but-busy fleet still stalls (capacity is not liveness).
* Migration moves a decoding request's cache state between engines with
  byte-identical greedy continuation — for every registered family, in
  both contiguous and paged layouts.
* The full chaos loop (kill / drain / rejoin under load) completes every
  request with streams byte-identical to a fault-free run.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.delay_models import SimplifiedDelayModel
from repro.models import build_model
from repro.runtime.faults import FaultEvent, schedule_by_step
from repro.serve import (
    Frontend,
    HedgedRouter,
    Replica,
    Scheduler,
    ServeEngine,
    generate_offline,
)

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64
DELAY = SimplifiedDelayModel(lambda_y=2.0)


def _model(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return model, model.init(RNG)


def _prompts(vocab, n=8, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        p = int(rng.integers(4, 16))
        m = int(rng.integers(6, 14))
        out.append((rng.integers(0, vocab, size=p).astype(np.int32), m, i * 0.002))
    return out


# ---------------------------------------------------------------------------
# Shared FaultEvent schema
# ---------------------------------------------------------------------------

def test_fault_event_shared_schema():
    """The chaos schema lives in runtime.faults and is re-exported from
    its original home (train_loop) — one schema, both planes."""
    from repro.runtime import train_loop

    assert train_loop.FaultEvent is FaultEvent
    ev = [FaultEvent(step=3, kind="fail", worker=1),
          FaultEvent(step=3, kind="slow", worker=0, factor=2.0)]
    sched = schedule_by_step(ev)
    assert sched == {3: ev} and train_loop.schedule_by_step(ev) == {3: ev}
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="explode", worker=0)


def test_fault_event_validates_at_construction():
    """Malformed chaos events fail where the schedule is WRITTEN, not
    deep inside the consuming plane's event loop."""
    with pytest.raises(ValueError):
        FaultEvent(step=-1, kind="fail", worker=0)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="fail", worker=-2)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="slow", worker=0, factor=0.0)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="slow", worker=0, factor=-3.0)
    # JSON round-trip (chaos-search repro schedules)
    ev = FaultEvent(step=7, kind="slow", worker=2, factor=2.5)
    assert FaultEvent.from_dict(ev.as_dict()) == ev


# ---------------------------------------------------------------------------
# Router: degraded fleets + rejoin cold start
# ---------------------------------------------------------------------------

def test_router_degraded_fleet_reprices_quorum():
    """Losing replicas clamps the quorum to the live fleet instead of
    stalling; a fully-alive-but-busy fleet still returns None (capacity
    pressure is resolved by completions, not by lowering k)."""
    router = HedgedRouter(DELAY, 4, quorum=3, cost_per_replica=0.05)
    plan = router.choose_hedge()
    assert plan is not None and plan.k == 3

    router.mark_failed(2)
    router.mark_failed(3)
    plan = router.choose_hedge()
    assert plan is not None and plan.k == 2          # re-priced, not stalled
    assert set(plan.replicas) <= {0, 1}

    # Busy != dead: occupy one of the two live replicas; now the live
    # quorum (2) exceeds availability (1) -> stall until a completion.
    router.inflight[0] = router.slots_per_replica
    assert router.choose_hedge() is None


def test_router_rejoin_cold_start_seeding():
    """mark_joined resets history: the rejoined replica prices at the
    neutral prior (not its stale pre-failure estimate), and its first
    real observation seeds the tracker estimate directly instead of
    EWMA-crawling up from zero (PR 6's training-side fix, mirrored)."""
    router = HedgedRouter(DELAY, 3, warmup=1)
    # Replica 2 builds a slow history: always observed at 8x.
    for _ in range(12):
        t = np.array([1.0, 1.0, 8.0])
        router.record(t, participants=[0, 1, 2])
    assert router._slowdowns()[2] > 4.0

    router.mark_failed(2)
    assert router.available() == [0, 1]
    router.mark_joined(2)
    assert router.available() == [0, 1, 2]
    # History gone: neutral prior, back in the dispatch order.
    assert router._slowdowns()[2] == pytest.approx(1.0)

    # First post-rejoin observation seeds directly at the observed value.
    router.record(np.array([0.0, 0.0, 2.5]), participants=[2])
    assert router.tracker.mean_estimate()[2] == pytest.approx(2.5)


def test_router_unbounded_censored_estimate_prices_last():
    """A replica whose every interaction was censored (all deadline
    expiries, zero real observations) has only lower bounds — it must
    price LAST, not at the neutral prior, yet stay finite so later real
    observations can recover it."""
    router = HedgedRouter(DELAY, 3, warmup=1)
    for _ in range(4):
        router.record(np.array([1.0, 1.0, 0.0]), participants=[0, 1])
        router.record(np.zeros(3), [2], observed=[], censor_level=3.0)
    slow = router._slowdowns()
    assert np.isfinite(slow).all()
    assert slow[2] == router.slow_cap > slow[0]
    plan = router.choose_hedge()
    assert plan is not None and 2 not in plan.replicas[: 2]


def test_router_release_occupy_roundtrip():
    router = HedgedRouter(DELAY, 2, slots_per_replica=2)
    plan = router.choose_hedge()
    router.begin(plan)
    before = router.inflight.copy()
    router.occupy(1)
    router.release(1)
    assert (router.inflight == before).all()
    with pytest.raises(ValueError):
        for _ in range(10):
            router.release(0)


# ---------------------------------------------------------------------------
# Scheduler + engine: deadlines and cancellation that frees memory
# ---------------------------------------------------------------------------

def test_deadline_stamped_at_admission_and_expires():
    """deadline_ticks stamps at ADMISSION (queueing doesn't count),
    expiry cancels with reason "deadline", and everything the request
    held — slot and paged blocks — is free afterwards."""
    model, params = _model("smollm-135m")
    sched = Scheduler(2, deadline_ticks=3)
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                      scheduler=sched, block_size=8)
    rng = np.random.default_rng(0)
    rid = eng.submit(rng.integers(0, model.cfg.vocab_size, 8).astype(np.int32), 30)
    out = eng.run()
    req = out[rid]
    assert req.cancelled and req.cancel_reason == "deadline"
    assert req.deadline == pytest.approx(
        req.t_admit + 3 * sched.clock.cost.decode_tick
    )
    assert 0 < len(req.tokens) < 30          # partial stream kept
    assert eng.pool.n_active == 0
    mgr = eng.pool.manager
    assert mgr.n_free_blocks == mgr.num_blocks
    assert eng.stats.cancelled_requests == 1


def test_cancel_releases_paged_blocks_under_pressure():
    """The tentpole's memory contract: cancelling a request under arena
    pressure returns its blocks, which is exactly what lets the queued
    request admit. (Before this PR cancellation was telemetry-only.)"""
    model, params = _model("smollm-135m")
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN,
                      block_size=8, arena_blocks=8)
    rng = np.random.default_rng(0)
    p = lambda n: rng.integers(0, model.cfg.vocab_size, n).astype(np.int32)
    r1 = eng.submit(p(20), 30)               # budget 50 -> 7 of 8 blocks
    r2 = eng.submit(p(20), 30)               # cannot admit alongside r1
    for _ in range(6):
        eng.step()
    assert eng.request(r2).t_admit is None   # starved by the arena
    free_before = eng.pool.manager.n_free_blocks
    assert eng.cancel(r1)
    assert eng.pool.manager.n_free_blocks > free_before
    out = eng.run()
    assert out[r2].t_done is not None        # cancel unblocked admission
    assert out[r1].cancelled and out[r1].cancel_reason == "cancelled"
    assert not eng.cancel(r1)                # idempotent: already cancelled


# ---------------------------------------------------------------------------
# Migration byte-identity: every family, both layouts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "arch", ["smollm-135m", "deepseek-v3", "xlstm-125m", "zamba2"]
)
@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True], ids=["contig", "paged"])
def test_migration_byte_identity(arch, paged):
    """Export a mid-decode request from one engine, import into another,
    finish there: the stitched greedy stream must equal offline decode
    exactly — the block handoff moves state, never math. Covers KV
    (smollm), MLA latent (deepseek), recurrent lanes (xlstm), and the
    hybrid layers-axis layout (zamba), contiguous and paged."""
    model, params = _model(arch)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, model.cfg.vocab_size, 12).astype(np.int32)
    ref = generate_offline(model, params, prompt, 10, MAX_LEN)

    kw = dict(block_size=8) if paged else {}
    src = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, **kw)
    dst = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, **kw)
    rid = src.submit(prompt, 10)
    while len(src.request(rid).tokens) < 4:
        src.step()
    ticket = src.export_request(rid)
    assert src.request(rid).cancel_reason == "migrated"
    assert src.pool.n_active == 0            # source fully released
    if paged:
        mgr = src.pool.manager
        assert mgr.n_free_blocks == mgr.num_blocks
    new_rid = dst.import_request(ticket)
    assert new_rid is not None
    out = dst.run()
    assert out[new_rid].tokens == ref        # byte-identical, no re-prefill
    assert dst.stats.migrated_in == 1 and src.stats.migrated_out == 1


def test_migration_backpressure_returns_none():
    """import_request under a full pool returns None (caller requeues)
    instead of corrupting state; after capacity frees it succeeds."""
    model, params = _model("smollm-135m")
    rng = np.random.default_rng(0)
    p = lambda n: rng.integers(0, model.cfg.vocab_size, n).astype(np.int32)
    src = ServeEngine(model, params, n_slots=1, max_len=MAX_LEN, block_size=8)
    dst = ServeEngine(model, params, n_slots=1, max_len=MAX_LEN, block_size=8)
    blocker = dst.submit(p(8), 20)
    while not dst.request(blocker).tokens:
        dst.step()
    rid = src.submit(p(8), 10)
    while len(src.request(rid).tokens) < 3:
        src.step()
    ticket = src.export_request(rid)
    assert dst.import_request(ticket) is None   # pool full -> requeue
    dst.cancel(blocker)
    assert dst.import_request(ticket) is not None


# ---------------------------------------------------------------------------
# Frontend: hedging with real loser teardown, chaos zero-drop identity
# ---------------------------------------------------------------------------

def _fleet(model, params, n=3, n_slots=2):
    return [
        Replica(i, model, params, n_slots=n_slots, max_len=MAX_LEN,
                block_size=8)
        for i in range(n)
    ]


@pytest.mark.slow
def test_frontend_fault_free_matches_offline():
    model, params = _model("smollm-135m")
    reqs = _prompts(model.cfg.vocab_size)
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]
    fe = Frontend(_fleet(model, params), DELAY, cost_per_replica=0.001)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert all(out[g].done and not out[g].dropped for g in gids)
    assert [out[g].tokens for g in gids] == refs
    # Hedged losers were actually torn down, not leaked: every pool is
    # empty and every paged arena fully free at drain.
    for rep in fe.replicas:
        assert rep.engine.pool.n_active == 0
        mgr = rep.engine.pool.manager
        assert mgr.n_free_blocks == mgr.num_blocks
    assert (fe.router.inflight == 0).all()


@pytest.mark.slow
def test_frontend_chaos_kill_rejoin_zero_drop():
    """Kill 1 of 3 replicas mid-saturation, rejoin later: every request
    completes, none drop, and all streams are byte-identical to the
    fault-free run (the acceptance gate of this PR)."""
    model, params = _model("smollm-135m")
    reqs = _prompts(model.cfg.vocab_size)
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]
    events = [FaultEvent(step=12, kind="fail", worker=1),
              FaultEvent(step=60, kind="rejoin", worker=1)]
    fe = Frontend(_fleet(model, params), DELAY, cost_per_replica=0.001,
                  events=events)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert all(out[g].done and not out[g].dropped for g in gids)
    assert [out[g].tokens for g in gids] == refs
    assert not fe.replicas[1].alive or fe.replicas[1].engine.pool.n_active == 0


@pytest.mark.slow
def test_frontend_drain_migrates_in_flight():
    """Graceful decommission under single-copy dispatch (replica cost
    high enough that hedging never covers a request twice): decoding
    requests MUST move via KV handoff, and streams stay identical."""
    model, params = _model("smollm-135m")
    reqs = _prompts(model.cfg.vocab_size)
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]
    events = [FaultEvent(step=20, kind="drain", worker=0),
              FaultEvent(step=90, kind="rejoin", worker=0)]
    fe = Frontend(_fleet(model, params), DELAY, cost_per_replica=10.0,
                  events=events)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert all(out[g].done and not out[g].dropped for g in gids)
    assert [out[g].tokens for g in gids] == refs
    assert fe.migrations > 0                 # real block handoffs happened


@pytest.mark.slow
def test_frontend_deadline_retry_requeues_elsewhere():
    """A 40x-slowed replica with a tight per-attempt deadline: copies
    expire, requeue on healthy replicas (resuming from the longest
    emitted prefix, not from scratch), and finish byte-identical."""
    model, params = _model("smollm-135m")
    reqs = _prompts(model.cfg.vocab_size)
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]
    events = [FaultEvent(step=0, kind="slow", worker=0, factor=40.0)]
    fe = Frontend(_fleet(model, params), DELAY, cost_per_replica=10.0,
                  events=events, deadline=0.06, retry_budget=4)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert all(out[g].done and not out[g].dropped for g in gids)
    assert [out[g].tokens for g in gids] == refs
    s = fe.summary()
    assert s["retries"] > 0
    # The expiries were fed back as censored telemetry against the slow
    # replica — at least one censored-only round on worker 0.
    assert fe.router.tracker.rounds[0] > fe.router.tracker.wins[0]


def test_frontend_retry_budget_drops_and_reports():
    """With every replica effectively unusable, the retry budget bounds
    the futile requeue loop and the request is reported dropped, not
    spun forever."""
    model, params = _model("smollm-135m")
    events = [FaultEvent(step=0, kind="slow", worker=i, factor=500.0)
              for i in range(2)]
    fe = Frontend(_fleet(model, params, n=2), DELAY, cost_per_replica=10.0,
                  events=events, deadline=0.02, retry_budget=1)
    rng = np.random.default_rng(0)
    gid = fe.submit(rng.integers(0, model.cfg.vocab_size, 8).astype(np.int32), 12)
    out = fe.run()
    assert out[gid].dropped and not out[gid].done
    assert fe.summary()["dropped"] == 1


@pytest.mark.slow
@pytest.mark.parametrize("drain_step", [6, 9, 12, 15])
def test_deadline_expiry_racing_drain_resolves_exactly_once(drain_step):
    """A drain exporting copies off a slowed replica while their
    deadline expiries are in flight: whichever side of the race wins at
    each step offset, every request resolves exactly once (done XOR
    dropped, never both, never neither) and every slot, paged block,
    and router count is freed."""
    model, params = _model("smollm-135m")
    reqs = _prompts(model.cfg.vocab_size, n=6)
    refs = [generate_offline(model, params, p, m, MAX_LEN) for p, m, _ in reqs]
    events = [FaultEvent(step=0, kind="slow", worker=0, factor=40.0),
              FaultEvent(step=drain_step, kind="drain", worker=0),
              FaultEvent(step=drain_step + 40, kind="rejoin", worker=0)]
    fe = Frontend(_fleet(model, params), DELAY, cost_per_replica=10.0,
                  events=events, deadline=0.06, retry_budget=6,
                  max_ticks=20_000)
    gids = [fe.submit(p, m, arrival=a) for p, m, a in reqs]
    out = fe.run()
    assert set(out) == set(gids)
    for g in gids:
        assert out[g].done != out[g].dropped       # exactly one terminal
        if out[g].done:
            assert out[g].tokens == refs[g]        # byte identity holds
    for rep in fe.replicas:
        assert rep.engine.live_rids() == []
        assert rep.engine.pool.n_active == 0
        if rep.engine.pool.manager is not None:
            assert rep.engine.pool.manager.n_used_blocks == 0
    assert (fe.router.inflight == 0).all()
    assert not fe.transport.busy()
    s = fe.summary()
    assert s["completed"] + s["dropped"] == len(gids)
