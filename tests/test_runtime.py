"""Runtime substrate: optimizers, checkpointing (atomic/async/resume),
data pipeline, collectives math, compression, telemetry, and the
end-to-end adaptive train loop with failure injection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimplifiedDelayModel, StrategyConfig
from repro.core.diagnostics import DiagnosticConfig
from repro.data import StagedBatcher, TokenStream
from repro.dist.collectives import example_weights, masked_weighted_ce
from repro.dist.compression import Int8Codec, ef_compress_tree
from repro.optim.optimizers import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    get_optimizer,
    momentum,
    sgd,
)
from repro.runtime.checkpoint import (
    CHECKPOINT_SCHEMA,
    CheckpointError,
    CheckpointManager,
)
from repro.runtime.telemetry import StragglerTracker


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    w = {"a": jnp.array([3.0, -2.0]), "b": jnp.array([[1.5]])}

    def loss(p):
        return jnp.sum(p["a"] ** 2) + jnp.sum(p["b"] ** 2)

    return w, loss


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw", "adafactor"])
def test_optimizers_descend(name):
    params, loss = _quad_problem()
    opt = get_optimizer(name)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params, jnp.float32(0.05))
        params = apply_updates(params, updates)
    assert float(loss(params)) < l0 * 0.2


def test_adafactor_factored_memory_shape():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((7,))}
    opt = adafactor(min_dim_factored=128)
    state = opt.init(params)
    assert set(state.states["w"].keys()) == {"row", "col"}
    assert state.states["w"]["row"].shape == (256,)
    assert state.states["w"]["col"].shape == (512,)
    assert set(state.states["b"].keys()) == {"v"}


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Fastest-k masked aggregation math
# ---------------------------------------------------------------------------

def test_example_weights_layout():
    mask = jnp.array([1.0, 0.0, 1.0, 0.0])
    w = example_weights(mask, batch=8)
    np.testing.assert_array_equal(
        np.asarray(w), [1, 1, 0, 0, 1, 1, 0, 0]
    )


def test_masked_ce_equals_subset_ce():
    """Masked CE over all workers == plain CE over the kept workers."""
    rng = jax.random.PRNGKey(0)
    B, S, V, n = 8, 4, 11, 4
    logits = jax.random.normal(rng, (B, S, V))
    labels = jax.random.randint(rng, (B, S), 0, V)
    mask = jnp.array([1.0, 0.0, 1.0, 1.0])
    loss_masked, _ = masked_weighted_ce(logits, labels, None, mask)
    keep = np.repeat(np.asarray(mask) > 0, B // n)
    loss_subset, _ = masked_weighted_ce(
        logits[keep], labels[keep], None, None
    )
    assert float(loss_masked) == pytest.approx(float(loss_subset), rel=1e-6)


def test_masked_gradient_unbiasedness():
    """E over random k-subsets of the masked gradient == full gradient."""
    rng = np.random.default_rng(0)
    B, S, V, n = 8, 4, 7, 8
    logits = jnp.asarray(rng.normal(size=(B, S, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)))

    def grad_for(mask):
        f = lambda lg: masked_weighted_ce(lg, labels, None, mask)[0]
        return np.asarray(jax.grad(f)(logits))

    full = grad_for(jnp.ones((n,)))
    acc = np.zeros_like(full)
    trials = 400
    k = 3
    for _ in range(trials):
        idx = rng.choice(n, size=k, replace=False)
        m = np.zeros(n, np.float32)
        m[idx] = 1
        acc += grad_for(jnp.asarray(m))
    np.testing.assert_allclose(acc / trials, full, atol=2e-2)


# ---------------------------------------------------------------------------
# Compression + error feedback
# ---------------------------------------------------------------------------

def test_int8_roundtrip_small_error():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)
    q, scale = Int8Codec.encode(x)
    err = np.abs(np.asarray(Int8Codec.decode(q, scale) - x)).max()
    assert err <= float(scale) * 0.5 + 1e-9


def test_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + EF still converges."""
    w = jnp.array([5.0, -3.0, 2.0, -1.0])
    resid = {"w": jnp.zeros_like(w)}
    params = {"w": w}
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        dec, resid = ef_compress_tree(grads, resid)
        params = {"w": params["w"] - 0.05 * dec["w"]}
    assert float(jnp.abs(params["w"]).max()) < 1e-2


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "n": {"m": jnp.ones((4,))}}
    mgr.save(10, state, extras={"stage": {"k": 3, "beta": 0.6}})
    mgr.save(20, state)
    mgr.save(30, state)
    # retention: only last 2 kept
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step"))
    assert steps == ["step_000000020", "step_000000030"]
    assert mgr.latest_step() == 30

    restored = mgr.restore_latest(state)
    assert restored is not None
    step, restored_state, extras = restored
    assert step == 30
    np.testing.assert_array_equal(
        np.asarray(restored_state["w"]), np.asarray(state["w"])
    )


def test_checkpoint_async_and_extras(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((8, 8))}
    mgr.save_async(5, state, extras={"stage": {"k": 2, "beta": 1.0}})
    mgr.wait()
    step, restored, extras = mgr.restore_latest(state)
    assert step == 5 and extras["stage"]["k"] == 2


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((2,))})
    leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".tmp")]
    assert not leftovers


def test_checkpoint_truncated_arrays_names_offending_path(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=10)
    state = {"w": jnp.ones((4,))}
    mgr.save(1, state)
    bad = tmp_path / "step_000000001" / "arrays.npz"
    bad.write_bytes(bad.read_bytes()[: 20])        # truncate mid-archive
    with pytest.raises(CheckpointError) as e:
        mgr.restore(1, state)
    assert str(bad) in str(e.value)


def test_checkpoint_corrupt_meta_names_offending_path(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=10)
    state = {"w": jnp.ones((4,))}
    mgr.save(2, state)
    bad = tmp_path / "step_000000002" / "meta.json"
    bad.write_text('{"step": 2, "time":')           # truncated JSON
    with pytest.raises(CheckpointError) as e:
        mgr.restore(2, state)
    assert str(bad) in str(e.value)


def test_checkpoint_unknown_schema_refused(tmp_path):
    import json as _json

    mgr = CheckpointManager(tmp_path, keep_last=10)
    state = {"w": jnp.ones((4,))}
    mgr.save(3, state)
    meta_path = tmp_path / "step_000000003" / "meta.json"
    meta = _json.loads(meta_path.read_text())
    meta["schema"] = CHECKPOINT_SCHEMA + 1
    meta_path.write_text(_json.dumps(meta))
    with pytest.raises(CheckpointError) as e:
        mgr.restore(3, state)
    msg = str(e.value)
    assert str(meta_path) in msg and str(CHECKPOINT_SCHEMA + 1) in msg


def test_checkpoint_missing_dir_and_corrupt_latest(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((2,))}
    with pytest.raises(CheckpointError) as e:
        mgr.restore(77, state)
    assert "step_000000077" in str(e.value)
    mgr.save(5, state)
    # a LATEST pointing at an existing entry whose name is not a step
    # directory is corrupt (a dangling pointer, by contrast, just means
    # "no checkpoint" — pruning can legitimately leave one)
    (tmp_path / "not-a-step-dir").mkdir()
    (tmp_path / "LATEST").write_text("not-a-step-dir")
    with pytest.raises(CheckpointError) as e:
        mgr.latest_step()
    assert "LATEST" in str(e.value)


def test_checkpoint_pre_schema_checkpoints_still_load(tmp_path):
    """Checkpoints written before the schema field existed load as
    version 1 — hardening must not orphan old runs."""
    import json as _json

    mgr = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(4.0)}
    mgr.save(8, state, extras={"stage": {"k": 2}})
    meta_path = tmp_path / "step_000000008" / "meta.json"
    meta = _json.loads(meta_path.read_text())
    del meta["schema"]
    meta_path.write_text(_json.dumps(meta))
    restored, extras = mgr.restore(8, state)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert extras["stage"]["k"] == 2


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_staged_batcher_beta_scaling():
    stream = TokenStream(vocab_size=97, seed=0)
    b = StagedBatcher(stream, n_workers=4, global_batch=16, seq_len=8)
    full = b.batch_for_stage(1.0)
    half = b.batch_for_stage(0.5)
    assert full["inputs"].shape == (16, 8)
    assert half["inputs"].shape == (8, 8)
    assert full["labels"].shape == full["inputs"].shape
    # labels are next-token shifted views of the same stream
    assert (full["inputs"][:, 1:] == full["labels"][:, :-1]).all()


def test_token_stream_learnable_structure():
    stream = TokenStream(vocab_size=97, seed=0, noise=0.0)
    arr = stream.sequences(4, 16)
    nxt = (31 * arr[:, :-1] + 17) % 97
    assert (nxt == arr[:, 1:]).mean() == 1.0


# ---------------------------------------------------------------------------
# Telemetry / straggler demotion
# ---------------------------------------------------------------------------

def test_straggler_tracker_flags_persistent_straggler():
    n = 8
    tr = StragglerTracker(n, warmup=4)
    rng = np.random.default_rng(0)
    alive = np.ones(n, bool)
    for _ in range(50):
        z = rng.exponential(1.0, n)
        z[3] *= 10.0  # worker 3 is 10x slower on average
        tr.observe(z, alive)
    assert tr.persistent_stragglers(4.0) == [3]


def test_straggler_tracker_late_joiner_seeds_from_own_data():
    """Regression: seeding must be per-worker, not on the tracker's first
    observation globally. A worker first observed late must start from
    ITS first sample, not crawl up from the zero init (which made late
    joiners look artificially fast and immune to demotion)."""
    n = 4
    tr = StragglerTracker(n, warmup=4)
    alive = np.ones(n, bool)
    late = np.array([False, False, False, True])
    for _ in range(20):
        tr.observe(np.array([1.0, 1.0, 1.0, np.inf]), alive & ~late)
    # worker 3 joins, persistently 8x slower
    for _ in range(10):
        tr.observe(np.array([1.0, 1.0, 1.0, 8.0]), alive)
    est = tr.mean_estimate()
    assert est[3] == pytest.approx(8.0, rel=0.05), \
        "late joiner's estimate must be seeded from its own first sample"
    assert tr.persistent_stragglers(4.0) == [3]


def test_straggler_tracker_censored_never_observed_worker():
    """Under fastest-k the straggler is NEVER observed — only censored at
    z_(k). The time-on-test estimate must still grow past any threshold,
    but only be flagged once the expected-wins fairness guard is met.
    (Default warmup: with k/n = 1/4, transient estimates of unlucky
    normal workers need ~16 rounds to settle.)"""
    n = 4
    tr = StragglerTracker(n, min_expected_wins=4.0)
    alive = np.ones(n, bool)
    rng = np.random.default_rng(1)
    flagged_at = None
    for t in range(40):
        z = rng.exponential(1.0, n)
        z[0] = np.inf  # the straggler never makes the fastest k
        observed = np.zeros(n, bool)
        observed[np.argmin(z)] = True  # k = 1
        level = float(z[observed][0])
        tr.observe(np.where(observed, z, np.nan), alive,
                   observed=observed, censor_level=level)
        flags = tr.persistent_stragglers(3.0)
        if flagged_at is None and flags:
            flagged_at = t
            assert flags == [0]
    assert flagged_at is not None, "censored straggler must be caught"
    # k/n = 1/4 per round: expected wins reach 4.0 only at round 16
    assert flagged_at >= 15, "fairness guard must delay the verdict"


def test_straggler_tracker_state_roundtrip():
    n = 3
    tr = StragglerTracker(n, warmup=2)
    rng = np.random.default_rng(2)
    alive = np.ones(n, bool)
    for _ in range(10):
        tr.observe(rng.exponential(1.0, n) * np.array([1, 1, 6.0]), alive)
    tr2 = StragglerTracker(n, warmup=2)
    tr2.load_state_dict(tr.state_dict())
    np.testing.assert_array_equal(tr2.mean_estimate(), tr.mean_estimate())
    assert tr2.persistent_stragglers(3.0) == tr.persistent_stragglers(3.0)
    with pytest.raises(ValueError):
        StragglerTracker(n + 1).load_state_dict(tr.state_dict())


def test_tracker_reset_worker_forgets_history():
    n = 4
    tr = StragglerTracker(n, warmup=2)
    alive = np.ones(n, bool)
    for _ in range(10):
        tr.observe(np.array([1.0, 1.0, 1.0, 9.0]), alive)
    assert tr.persistent_stragglers(4.0) == [3]
    tr.reset_worker(3)  # recovered + rejoined: stale slowness must not demote
    assert tr.persistent_stragglers(4.0) == []
    assert np.isnan(tr.mean_estimate()[3])
