"""repro.serve correctness: continuous batching must be invisible.

The contract that makes the slot-pool machinery trustable is exact
token equivalence: a request served by the continuous-batching engine —
joining mid-flight, sharing decode ticks with strangers, surviving
chunked prefill and masked dead lanes — must emit the identical greedy
token stream as a lone offline run of the same model. Checked across an
attention family and a recurrent family (the two cache disciplines).

Plus: slot-pool allocate/free/reuse/defrag/reset invariants, scheduler
determinism, and the hedged router's order-statistics pricing
(brute-force ``expected_kth`` match, loser cancellation freeing slots,
EWMA straggler demotion).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.delay_models import GeneralizedDelayModel, SimplifiedDelayModel
from repro.core.order_stats import expected_kth
from repro.models import build_model
from repro.models.layers import ParamSpec
from repro.serve import (
    HedgedRouter,
    ReplicaSet,
    Scheduler,
    ServeEngine,
    SlotPool,
    generate_offline,
    run_static,
)

RNG = jax.random.PRNGKey(0)
MAX_LEN = 64


def _model(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    return model, model.init(RNG)


def _workload(vocab, n=6, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        p = int(rng.integers(3, 20))
        m = int(rng.integers(1, 12))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        reqs.append((prompt, m, i * 0.004))
    return reqs


# ---------------------------------------------------------------------------
# Token equivalence: continuous batching == offline decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-135m", "xlstm-125m"])
def test_continuous_batching_matches_offline(arch):
    """Staggered arrivals, mixed lengths, chunked prefill, 3 slots for 6
    requests — every request's greedy tokens must be identical to a
    per-request offline decode (attention + recurrent cache families)."""
    model, params = _model(arch)
    reqs = _workload(model.cfg.vocab_size)
    eng = ServeEngine(
        model, params, n_slots=3, max_len=MAX_LEN,
        scheduler=Scheduler(3, prefill_chunk=8, decode_per_prefill=2),
    )
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    results = eng.run()
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert results[rid].tokens == ref, f"{arch} rid={rid} diverged"
        assert results[rid].t_done is not None


def test_static_baseline_matches_offline():
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=3)
    results, stats = run_static(model, params, reqs, n_slots=2, max_len=MAX_LEN)
    for rid, (p, m, _) in zip(sorted(results), reqs):
        assert results[rid].tokens == generate_offline(model, params, p, m, MAX_LEN)
    assert stats.generated_tokens == sum(m for _, m, _ in reqs)


def test_slots_reused_across_requests():
    """More requests than slots forces mid-flight reuse of freed slots."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=7, seed=5)
    eng = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN)
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    results = eng.run()
    assert eng.pool.n_active == 0
    for rid, (p, m, _) in zip(rids, reqs):
        assert results[rid].tokens == generate_offline(model, params, p, m, MAX_LEN)


def test_engine_event_log_is_deterministic():
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=6, seed=1)

    def go():
        eng = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN)
        for p, m, a in reqs:
            eng.submit(p, m, arrival=a)
        eng.run()
        return eng.events

    assert go() == go()


def test_prefill_bucket_capped_at_max_len():
    """Regression: the pad bucket must never exceed the slot capacity past
    the chunk start — an oversized dynamic_update_slice either crashes or
    gets its start clamped by XLA, silently overwriting valid cache rows."""
    model, params = _model("smollm-135m")
    rng = np.random.default_rng(11)
    # (a) bucket(24) = 32 > max_len = 29: would crash unclamped.
    prompt = rng.integers(0, model.cfg.vocab_size, size=24).astype(np.int32)
    eng = ServeEngine(model, params, n_slots=1, max_len=29)
    rid = eng.submit(prompt, 4)
    assert eng.run()[rid].tokens == generate_offline(model, params, prompt, 4, 29)
    # (b) chunked: last chunk start=30, bucket 16 would clamp to start 24
    # and corrupt rows 24-29 — tokens must still match offline exactly.
    prompt = rng.integers(0, model.cfg.vocab_size, size=34).astype(np.int32)
    eng = ServeEngine(
        model, params, n_slots=1, max_len=40,
        scheduler=Scheduler(1, prefill_chunk=5),
    )
    rid = eng.submit(prompt, 5)
    assert eng.run()[rid].tokens == generate_offline(model, params, prompt, 5, 40)


def test_engine_defrag_mid_flight_keeps_equivalence():
    """Defragging while requests are generating must remap the engine's
    per-slot decode state along with the pool rows."""
    model, params = _model("smollm-135m")
    reqs = _workload(model.cfg.vocab_size, n=5, seed=9)
    eng = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN)
    rids = [eng.submit(p, m, arrival=a) for p, m, a in reqs]
    defragged = 0
    while eng.step() != "done":
        # Defrag whenever the pool fragments (a freed slot below a live one).
        act = eng.pool.active
        if act.any() and not act[: eng.pool.n_active].all():
            assert eng.defrag()
            defragged += 1
    assert defragged > 0, "workload never fragmented the pool; weak test"
    results = dict(eng._requests)
    for rid, (p, m, _) in zip(rids, reqs):
        ref = generate_offline(model, params, p, m, MAX_LEN)
        assert results[rid].tokens == ref, f"rid={rid} diverged after defrag"


# ---------------------------------------------------------------------------
# Slot pool invariants
# ---------------------------------------------------------------------------

def test_slot_pool_allocate_free_reuse():
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=3, max_len=8)
    slots = [pool.allocate(owner=i) for i in range(3)]
    assert slots == [0, 1, 2] and pool.n_free == 0
    assert pool.allocate() is None          # full
    pool.free(1)
    assert pool.allocate(owner=9) == 1      # lowest free slot reused
    with pytest.raises(ValueError):
        pool.free(1)
        pool.free(1)                        # double free rejected


def test_slot_pool_defrag_compacts_and_preserves():
    model, _ = _model("smollm-135m")
    pool = SlotPool(model, n_slots=4, max_len=8)
    for i in range(4):
        pool.allocate(owner=i)
    # Stamp recognizable content via per-slot writes.
    for s in range(4):
        one = jax.tree.map(
            lambda spec: np.full([1 if a == "act_batch" else d
                                  for a, d in zip(spec.axes, spec.shape)],
                                 float(s + 1), np.float32),
            pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        pool.write_slot(s, one, position=s + 1)
    pool.free(0)
    pool.free(2)
    moves = pool.defrag()
    # Active slots 1,3 compact to 0,1 with contents and positions intact.
    assert moves == {1: 0, 3: 1}
    assert pool.active.tolist() == [True, True, False, False]
    assert pool.owner[:2] == [1, 3]
    assert pool.positions[:2].tolist() == [2, 4]
    leaf = jax.tree.leaves(pool.caches)[0]
    ax = jax.tree.leaves(
        pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0].axes.index("act_batch")
    got = np.moveaxis(np.asarray(leaf, np.float32), ax, 0).reshape(4, -1)[:, 0]
    assert got[:2].tolist() == [2.0, 4.0]


def test_slot_pool_reset_restores_spec_init():
    """Reset must restore spec-defined fills — notably ONES for the sLSTM
    normalizer state, not a blanket zero. (The 2-layer reduced xlstm has
    no sLSTM block, so force one in — the pool never needs params.)"""
    import dataclasses

    cfg = get_config("xlstm-125m").reduced()
    cfg = dataclasses.replace(
        cfg, xlstm=dataclasses.replace(cfg.xlstm, slstm_every=2)
    )
    model = build_model(cfg)
    pool = SlotPool(model, n_slots=2, max_len=8)
    # Scribble over both slots.
    junk = jax.tree.map(
        lambda spec: np.full(spec.shape, 7.0, np.float32),
        pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    pool.caches = jax.tree.map(lambda c, j: j.astype(np.asarray(c).dtype),
                               pool.caches, junk)
    pool.reset_slot(0)
    specs = jax.tree.leaves(pool.specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    leaves = jax.tree.leaves(pool.caches)
    assert any(s.init == "ones" for s in specs), "xlstm must carry a ones-init state"
    for spec, leaf in zip(specs, leaves):
        ax = spec.axes.index("act_batch")
        arr = np.moveaxis(np.asarray(leaf, np.float32), ax, 0)
        want = 1.0 if spec.init == "ones" else 0.0
        assert np.all(arr[0] == want), f"slot 0 of {spec} not reset to {want}"
        assert np.all(arr[1] == 7.0), "reset must not touch other slots"


# ---------------------------------------------------------------------------
# Hedged router
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("delay_model", [
    SimplifiedDelayModel(lambda_y=2.0, x=0.05),
    GeneralizedDelayModel(lambda_x=4.0, lambda_y=2.0, x=0.02),
])
@pytest.mark.parametrize("quorum,c", [(1, 0.08), (2, 0.05)])
def test_hedge_choice_matches_bruteforce(delay_model, quorum, c):
    n_rep = 8
    router = HedgedRouter(delay_model, n_rep, quorum=quorum, cost_per_replica=c)
    plan = router.choose_hedge()
    brute = min(
        range(quorum, n_rep + 1),
        key=lambda n: expected_kth(delay_model, n, min(quorum, n), 1.0) + c * n,
    )
    assert plan.n_h == brute
    assert plan.k == min(quorum, plan.n_h)
    assert len(plan.replicas) == plan.n_h
    assert plan.expected_cost == pytest.approx(
        expected_kth(delay_model, plan.n_h, plan.k, 1.0) + c * plan.n_h
    )


def test_hedge_cancellation_frees_slots():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 6, quorum=1, cost_per_replica=0.08)
    rs = ReplicaSet(dm, [1.0] * 6, seed=2)
    out = router.dispatch(rs, auto_complete=False)
    assert out.plan.n_h > 1, "this pricing must actually hedge"
    assert router.inflight.sum() == out.plan.n_h
    # A concurrent hedge must avoid the busy replicas.
    out2 = router.dispatch(rs, auto_complete=False)
    assert set(out2.plan.replicas).isdisjoint(out.plan.replicas)
    # Completion releases the winner AND every cancelled loser.
    assert len(out.completed) == out.plan.k
    assert len(out.cancelled) == out.plan.n_h - out.plan.k
    router.complete(out)
    router.complete(out2)
    assert router.inflight.sum() == 0
    assert sorted(router.available()) == list(range(6))


def test_router_demotes_persistent_straggler():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 5, quorum=1, cost_per_replica=0.05)
    rs = ReplicaSet(dm, [1.0, 1.0, 1.0, 1.0, 8.0], seed=3)
    for _ in range(300):
        router.dispatch(rs)
    plan = router.choose_hedge()
    assert 4 not in plan.replicas, "EWMA-slow replica must stop being chosen"


def test_router_respects_quorum_capacity():
    dm = SimplifiedDelayModel(lambda_y=2.0, x=0.05)
    router = HedgedRouter(dm, 3, quorum=2, cost_per_replica=0.0, n_max=3)
    rs = ReplicaSet(dm, [1.0] * 3, seed=4)
    out = router.dispatch(rs, auto_complete=False)
    assert out is not None
    # Fewer free replicas than the quorum -> no feasible hedge.
    assert router.dispatch(rs, auto_complete=False) is None
    router.complete(out)
    assert router.dispatch(rs) is not None
